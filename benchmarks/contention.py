"""Contention sweep: storage-side group commit under hot-partition skew.

Sweeps clients × zipf partition skew × protocol × batch mode on a
replicated (R=3) storage service whose per-partition log device is SERIAL
(one write round trip in flight at a time — the premise of group commit).
Three batch modes bracket the design space:

  nobatch    – serial lane, max_batch=1: every request pays its own queued
               round trip (the window=0 baseline of the speedup claim).
  piggyback  – window=0, max_batch=64: requests that arrive while a flush
               is in flight coalesce into the next one; zero added latency
               when idle.
  window2ms  – a 2 ms formation window on top: deeper batches, bounded
               added latency.
  windowauto – load-proportional window (real log-daemon style): an idle
               lane never delays, a busy lane waits up to the 4 ms clamp
               to fill the batch.

Emits ``name,value,derived`` CSV rows (latency AND throughput per config,
plus batched-vs-unbatched speedups and storage round-trip counts) so one
run yields the latency-vs-throughput trade-off curve.

Standalone entry point with a CI regression gate::

    python -m benchmarks.contention --quick --check-baseline
    python -m benchmarks.contention --quick --write-baseline

The baseline (``BENCH_contention.json`` at the repo root) pins quick-mode
committed-txn throughput per configuration; ``--check-baseline`` exits
non-zero when any tracked throughput regresses more than 15%.
"""
from __future__ import annotations

import os
from typing import Dict, List

from repro.core import AZURE_REDIS
from repro.txn import BenchConfig, YCSBWorkload, run_bench

from benchmarks._baseline import (REGRESSION_TOLERANCE, Row, check_baseline,
                                  gate_main, write_baseline)

BASELINE_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_contention.json")

BATCH_MODES = {
    "nobatch": dict(storage_serial=True, batch_max=1),
    "piggyback": dict(storage_serial=True, batch_max=64),
    "window2ms": dict(storage_serial=True, batch_max=64,
                      batch_window_ms=2.0),
    "windowauto": dict(storage_serial=True, batch_max=64,
                       batch_window_ms="auto"),
}


def run_one(proto: str, clients: int, theta: float, mode: str,
            replication: int = 3, horizon_ms: float = 600.0, seed: int = 3):
    n_nodes = 4
    assert clients % n_nodes == 0

    def wl(nodes, seed):
        # Few accesses per txn + zipf-skewed partition choice: the hot
        # partition's serial log lane, not execution, is the bottleneck.
        return YCSBWorkload(nodes, accesses_per_txn=4, partition_theta=theta,
                            keys_per_partition=10_000, seed=seed)

    cfg = BenchConfig(protocol=proto, n_nodes=n_nodes,
                      threads_per_node=clients // n_nodes,
                      horizon_ms=horizon_ms, replication=replication,
                      seed=seed, **BATCH_MODES[mode])
    return run_bench(wl, AZURE_REDIS, cfg)


def sweep(quick: bool = False, replication: int = 3) -> List[Row]:
    """clients × zipf partition skew × protocol × batch mode."""
    grid_clients = (32,) if quick else (16, 32, 64)
    grid_theta = (0.9,) if quick else (0.0, 0.9)
    protos = ("cornus", "2pc") if quick else (
        "cornus", "2pc", "cornus-opt1", "paxos-commit")
    horizon = 600.0 if quick else 900.0

    rows: List[Row] = []
    for clients in grid_clients:
        for theta in grid_theta:
            tput: Dict[str, Dict[str, float]] = {}
            for proto in protos:
                tput[proto] = {}
                for mode in BATCH_MODES:
                    r = run_one(proto, clients, theta, mode,
                                replication=replication, horizon_ms=horizon)
                    tput[proto][mode] = r.throughput_tps
                    key = (f"contention/r{replication}/{proto}/{mode}/"
                           f"c{clients}/theta{theta}")
                    derived = (f"commits={r.commits} aborts={r.aborts} "
                               f"gaveups={r.gaveups} "
                               f"rtrips={r.storage_round_trips}")
                    rows.append((f"{key}/tput_tps", r.throughput_tps, derived))
                    rows.append((f"{key}/avg_ms", r.avg_latency_ms,
                                 f"p99={r.p99_latency_ms:.2f}"))
                for mode in ("piggyback", "window2ms", "windowauto"):
                    base = max(tput[proto]["nobatch"], 1e-9)
                    rows.append(
                        (f"contention/r{replication}/{proto}/{mode}/"
                         f"c{clients}/theta{theta}/batch_speedup",
                         tput[proto][mode] / base,
                         "committed-txn throughput vs window=0 serial"))
    return rows


# ---------------------------------------------------------------------------
# Baseline gate (CI) — shared machinery in benchmarks/_baseline.py
# ---------------------------------------------------------------------------
def main() -> None:
    gate_main(description=__doc__.splitlines()[0],
              sweep=lambda quick: sweep(quick=quick),
              baseline_path=BASELINE_PATH,
              bench_name="benchmarks.contention --quick",
              error_msg="contention throughput regressed >15% "
                        "against BENCH_contention.json")


if __name__ == "__main__":
    main()
