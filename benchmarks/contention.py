"""Contention sweep: storage-side group commit under hot-partition skew.

Sweeps clients × zipf partition skew × protocol × batch mode on a
replicated (R=3) storage service whose per-partition log device is SERIAL
(one write round trip in flight at a time — the premise of group commit).
Three batch modes bracket the design space:

  nobatch    – serial lane, max_batch=1: every request pays its own queued
               round trip (the window=0 baseline of the speedup claim).
  piggyback  – window=0, max_batch=64: requests that arrive while a flush
               is in flight coalesce into the next one; zero added latency
               when idle.
  window2ms  – a 2 ms formation window on top: deeper batches, bounded
               added latency.
  windowauto – load-proportional window (real log-daemon style): an idle
               lane never delays, a busy lane waits up to the 4 ms clamp
               to fill the batch.

Every configuration runs with the termination-storm controls ON (adaptive
EWMA timeouts via ``timeout_ms=None``, storage decision cache +
singleflight + push, compute-side termination dedup, fresh retry ids):
without them the serial ``nobatch`` lanes push latency past the static
timeouts and timed-out participants race LogOnce termination rounds
against the queue — the storm that used to invert the paper's ordering
(cornus 28 tps vs 2PC 168 tps on the c32/theta0.9 nobatch row).

Emits ``name,value,derived`` CSV rows (latency AND throughput per config,
plus batched-vs-unbatched speedups, storage round-trip counts and the
termination-storm counters) so one run yields the latency-vs-throughput
trade-off curve.

Standalone entry point with a CI regression gate::

    python -m benchmarks.contention --quick --check-baseline
    python -m benchmarks.contention --quick --write-baseline

The baseline (``BENCH_contention.json`` at the repo root) pins quick-mode
committed-txn throughput per configuration; ``--check-baseline`` exits
non-zero when any tracked throughput regresses more than 15% — and also
when any configuration's cornus throughput drops below its 2PC twin (the
paper ordering the storm controls restore).
"""
from __future__ import annotations

import os
import sys
from typing import Dict, List

from repro.core import AZURE_REDIS
from repro.txn import BenchConfig, YCSBWorkload, run_bench

from benchmarks._baseline import (REGRESSION_TOLERANCE, Row, check_baseline,
                                  gate_main, tracked, write_baseline)

BASELINE_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_contention.json")

BATCH_MODES = {
    "nobatch": dict(storage_serial=True, batch_max=1),
    "piggyback": dict(storage_serial=True, batch_max=64),
    "window2ms": dict(storage_serial=True, batch_max=64,
                      batch_window_ms=2.0),
    "windowauto": dict(storage_serial=True, batch_max=64,
                       batch_window_ms="auto"),
}

# Termination-storm controls (all default-off in BenchConfig; the sweep is
# exactly the deployment they exist for).  timeout_ms stays None, which
# attaches the adaptive EWMA timeout policy on top of the static floor.
STORM_CONTROL = dict(decision_cache=True, termination_singleflight=True,
                     decision_push=True, termination_dedup=True,
                     retry_fresh_ids=True)


def run_one(proto: str, clients: int, theta: float, mode: str,
            replication: int = 3, horizon_ms: float = 600.0, seed: int = 3):
    n_nodes = 4
    assert clients % n_nodes == 0

    def wl(nodes, seed):
        # Few accesses per txn + zipf-skewed partition choice: the hot
        # partition's serial log lane, not execution, is the bottleneck.
        return YCSBWorkload(nodes, accesses_per_txn=4, partition_theta=theta,
                            keys_per_partition=10_000, seed=seed)

    cfg = BenchConfig(protocol=proto, n_nodes=n_nodes,
                      threads_per_node=clients // n_nodes,
                      horizon_ms=horizon_ms, replication=replication,
                      seed=seed, **STORM_CONTROL, **BATCH_MODES[mode])
    return run_bench(wl, AZURE_REDIS, cfg)


def sweep(quick: bool = False, replication: int = 3) -> List[Row]:
    """clients × zipf partition skew × protocol × batch mode."""
    grid_clients = (32,) if quick else (16, 32, 64)
    grid_theta = (0.9,) if quick else (0.0, 0.9)
    protos = ("cornus", "2pc", "cornus-opt1", "paxos-commit")
    horizon = 600.0 if quick else 900.0

    rows: List[Row] = []
    for clients in grid_clients:
        for theta in grid_theta:
            tput: Dict[str, Dict[str, float]] = {}
            for proto in protos:
                tput[proto] = {}
                for mode in BATCH_MODES:
                    r = run_one(proto, clients, theta, mode,
                                replication=replication, horizon_ms=horizon)
                    tput[proto][mode] = r.throughput_tps
                    key = (f"contention/r{replication}/{proto}/{mode}/"
                           f"c{clients}/theta{theta}")
                    derived = (f"commits={r.commits} aborts={r.aborts} "
                               f"gaveups={r.gaveups} "
                               f"rtrips={r.storage_round_trips} "
                               f"term={r.terminations} "
                               f"dedup={r.dedup_hits} "
                               f"cache={r.decision_cache_hits} "
                               f"sf={r.singleflight_hits} "
                               f"push={r.decisions_pushed} "
                               f"scrub={r.scrub_repairs} "
                               f"quar={r.quarantines} "
                               f"gc={r.gc_truncations} "
                               f"wml={r.watermark_lag}")
                    rows.append((f"{key}/tput_tps", r.throughput_tps, derived))
                    rows.append((f"{key}/avg_ms", r.avg_latency_ms,
                                 f"p50={r.p50_latency_ms:.2f} "
                                 f"p95={r.p95_latency_ms:.2f} "
                                 f"p99={r.p99_latency_ms:.2f}"))
                for mode in ("piggyback", "window2ms", "windowauto"):
                    base = max(tput[proto]["nobatch"], 1e-9)
                    rows.append(
                        (f"contention/r{replication}/{proto}/{mode}/"
                         f"c{clients}/theta{theta}/batch_speedup",
                         tput[proto][mode] / base,
                         "committed-txn throughput vs window=0 serial"))
    return rows


# ---------------------------------------------------------------------------
# Baseline gate (CI) — shared machinery in benchmarks/_baseline.py
# ---------------------------------------------------------------------------
def check_cornus_vs_2pc(rows: List[Row]) -> bool:
    """Paper-ordering gate: for every tracked configuration, cornus commits
    at least as much as 2PC.  The nobatch rows are where the termination
    storm used to invert this (28 vs 168 tps)."""
    got = tracked(rows)
    ok = True
    for name in sorted(got):
        if "/cornus/" not in name:
            continue
        peer = name.replace("/cornus/", "/2pc/")
        if peer not in got:
            continue
        good = got[name] >= got[peer] * (1.0 - 1e-9)
        verdict = "ok" if good else "ORDERING-INVERTED"
        if not good:
            ok = False
        print(f"# ordering {verdict}: {name} {got[name]:.1f} "
              f"vs 2pc {got[peer]:.1f}", file=sys.stderr)
    return ok


def main() -> None:
    gate_main(description=__doc__.splitlines()[0],
              sweep=lambda quick: sweep(quick=quick),
              baseline_path=BASELINE_PATH,
              bench_name="benchmarks.contention --quick",
              error_msg="contention throughput regressed >15% against "
                        "BENCH_contention.json (or cornus fell behind 2pc)",
              extra_check=check_cornus_vs_2pc)


if __name__ == "__main__":
    main()
