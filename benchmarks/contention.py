"""Contention sweep: storage-side group commit under hot-partition skew.

Sweeps clients × zipf partition skew × protocol × batch mode on a
replicated (R=3) storage service whose per-partition log device is SERIAL
(one write round trip in flight at a time — the premise of group commit).
Three batch modes bracket the design space:

  nobatch    – serial lane, max_batch=1: every request pays its own queued
               round trip (the window=0 baseline of the speedup claim).
  piggyback  – window=0, max_batch=64: requests that arrive while a flush
               is in flight coalesce into the next one; zero added latency
               when idle.
  window2ms  – a 2 ms formation window on top: deeper batches, bounded
               added latency.

Emits ``name,value,derived`` CSV rows (latency AND throughput per config,
plus batched-vs-unbatched speedups and storage round-trip counts) so one
run yields the latency-vs-throughput trade-off curve.

Standalone entry point with a CI regression gate::

    python -m benchmarks.contention --quick --check-baseline
    python -m benchmarks.contention --quick --write-baseline

The baseline (``BENCH_contention.json`` at the repo root) pins quick-mode
committed-txn throughput per configuration; ``--check-baseline`` exits
non-zero when any tracked throughput regresses more than 15%.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Tuple

from repro.core import AZURE_REDIS
from repro.txn import BenchConfig, YCSBWorkload, run_bench

Row = Tuple[str, float, str]

BASELINE_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_contention.json")
REGRESSION_TOLERANCE = 0.15     # CI fails below 85% of baseline throughput

BATCH_MODES = {
    "nobatch": dict(storage_serial=True, batch_max=1),
    "piggyback": dict(storage_serial=True, batch_max=64),
    "window2ms": dict(storage_serial=True, batch_max=64,
                      batch_window_ms=2.0),
}


def run_one(proto: str, clients: int, theta: float, mode: str,
            replication: int = 3, horizon_ms: float = 600.0, seed: int = 3):
    n_nodes = 4
    assert clients % n_nodes == 0

    def wl(nodes, seed):
        # Few accesses per txn + zipf-skewed partition choice: the hot
        # partition's serial log lane, not execution, is the bottleneck.
        return YCSBWorkload(nodes, accesses_per_txn=4, partition_theta=theta,
                            keys_per_partition=10_000, seed=seed)

    cfg = BenchConfig(protocol=proto, n_nodes=n_nodes,
                      threads_per_node=clients // n_nodes,
                      horizon_ms=horizon_ms, replication=replication,
                      seed=seed, **BATCH_MODES[mode])
    return run_bench(wl, AZURE_REDIS, cfg)


def sweep(quick: bool = False, replication: int = 3) -> List[Row]:
    """clients × zipf partition skew × protocol × batch mode."""
    grid_clients = (32,) if quick else (16, 32, 64)
    grid_theta = (0.9,) if quick else (0.0, 0.9)
    protos = ("cornus", "2pc") if quick else (
        "cornus", "2pc", "cornus-opt1", "paxos-commit")
    horizon = 600.0 if quick else 900.0

    rows: List[Row] = []
    for clients in grid_clients:
        for theta in grid_theta:
            tput: Dict[str, Dict[str, float]] = {}
            for proto in protos:
                tput[proto] = {}
                for mode in BATCH_MODES:
                    r = run_one(proto, clients, theta, mode,
                                replication=replication, horizon_ms=horizon)
                    tput[proto][mode] = r.throughput_tps
                    key = (f"contention/r{replication}/{proto}/{mode}/"
                           f"c{clients}/theta{theta}")
                    derived = (f"commits={r.commits} aborts={r.aborts} "
                               f"gaveups={r.gaveups} "
                               f"rtrips={r.storage_round_trips}")
                    rows.append((f"{key}/tput_tps", r.throughput_tps, derived))
                    rows.append((f"{key}/avg_ms", r.avg_latency_ms,
                                 f"p99={r.p99_latency_ms:.2f}"))
                for mode in ("piggyback", "window2ms"):
                    base = max(tput[proto]["nobatch"], 1e-9)
                    rows.append(
                        (f"contention/r{replication}/{proto}/{mode}/"
                         f"c{clients}/theta{theta}/batch_speedup",
                         tput[proto][mode] / base,
                         "committed-txn throughput vs window=0 serial"))
    return rows


# ---------------------------------------------------------------------------
# Baseline gate (CI)
# ---------------------------------------------------------------------------
def _tracked(rows: List[Row]) -> Dict[str, float]:
    return {name: value for name, value, _ in rows
            if name.endswith("/tput_tps")}


def write_baseline(rows: List[Row], path: str = BASELINE_PATH) -> None:
    payload = {
        "schema": 1,
        "bench": "benchmarks.contention --quick",
        "note": "quick-mode committed-txn throughput per configuration; "
                "CI fails when a tracked value drops below "
                f"{1 - REGRESSION_TOLERANCE:.0%} of this baseline "
                "(deterministic sim: genuine drift means a code change).",
        "tput_tps": _tracked(rows),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def check_baseline(rows: List[Row], path: str = BASELINE_PATH) -> bool:
    with open(path) as f:
        baseline = json.load(f)["tput_tps"]
    got = _tracked(rows)
    ok = True
    for name, want in sorted(baseline.items()):
        have = got.get(name)
        if have is None:
            print(f"# baseline MISSING from sweep: {name}", file=sys.stderr)
            ok = False
            continue
        floor = want * (1.0 - REGRESSION_TOLERANCE)
        verdict = "ok" if have >= floor else "REGRESSION"
        if have < floor:
            ok = False
        print(f"# baseline {verdict}: {name} {have:.1f} vs {want:.1f} "
              f"(floor {floor:.1f})", file=sys.stderr)
    return ok


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid / issue windows (CI, <60s)")
    ap.add_argument("--write-baseline", action="store_true",
                    help=f"pin current quick-mode throughput "
                         f"to {os.path.basename(BASELINE_PATH)}")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail (exit 1) on >15%% throughput regression "
                         "against the pinned baseline")
    ap.add_argument("--baseline", default=BASELINE_PATH)
    args = ap.parse_args()

    t0 = time.time()
    rows = sweep(quick=args.quick or args.write_baseline
                 or args.check_baseline)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.4f},{derived}")
    print(f"# sweep took {time.time() - t0:.1f}s", file=sys.stderr)

    if args.write_baseline:
        write_baseline(rows, args.baseline)
        print(f"# baseline written to {args.baseline}", file=sys.stderr)
    if args.check_baseline:
        if not check_baseline(rows, args.baseline):
            print("::error::contention throughput regressed >15% "
                  "against BENCH_contention.json", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
