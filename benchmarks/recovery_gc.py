"""Bounded-time recovery: restart-scan time vs. history length, with GC.

The durable-state lifecycle's promise is that crash recovery is bounded by
the RETAINED log, not by everything the deployment ever wrote: the GC
low-watermark truncates slots whose transactions are settled (terminal
decision durable on a quorum), so a restarting node's in-doubt scan only
probes the post-watermark suffix.  Without GC the scan grows linearly with
history; with GC it stays flat.

Grid: {cornus, 2pc} × gc ∈ {off, on} × history ∈ {short, long} (the long
window is 4× the short one), mostly at R=1 plus one replicated cell.  Each
cell crashes one node near the end of the issue window and restarts it just
before the horizon; the measured value is the durable restart scan's wall
time (``BenchResult.recovery_spans``) and the number of slots it probed.

The ``--check-baseline`` gate asserts, beyond the usual throughput pins:

  * GC-enabled recovery stays BOUNDED: the long-history scan takes at most
    ``GC_FLAT_BOUND``× the short-history scan (flat in history length),
  * GC-disabled recovery GROWS: the long-history scan probes at least
    ``NOGC_GROWTH_FLOOR``× the slots of the short one (the bound is real,
    not an artifact of a scan that never grew),
  * every run is machine-certified: zero checker violations (AC1–AC3,
    writer-of, recoverability, AC-GC) in every cell.

Standalone entry points::

    python -m benchmarks.recovery_gc --quick --check-baseline
    python -m benchmarks.recovery_gc --quick --write-baseline
"""
from __future__ import annotations

import os
import sys
from typing import List

from repro.core import AZURE_REDIS
from repro.txn import BenchConfig, YCSBWorkload, run_bench

from benchmarks._baseline import Row, gate_main

BASELINE_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_recovery.json")
PROTOS = ("cornus", "2pc")
GC_FLAT_BOUND = 1.5      # gc-on: long-history scan time <= 1.5x short
NOGC_GROWTH_FLOOR = 1.3  # gc-off: long-history probed slots >= 1.3x short
LIFECYCLE_GC = dict(checksums=True, gc=True, gc_interval_ms=25.0)
LIFECYCLE_NOGC = dict(checksums=True, gc=False)


def _wl(nodes, seed):
    return YCSBWorkload(nodes, seed=seed)


def run_one(proto: str, gc: bool, horizon_ms: float, replication: int = 1,
            seed: int = 7):
    """One cell: run ``horizon_ms`` of traffic, crash n1 late, restart it
    just before the horizon, measure the durable restart scan."""
    crash_at = 0.85 * horizon_ms
    restart_at = 0.90 * horizon_ms
    cfg = BenchConfig(protocol=proto, n_nodes=4, threads_per_node=2,
                      horizon_ms=horizon_ms, seed=seed,
                      replication=replication, retry_fresh_ids=True,
                      record_history=True,
                      lifecycle=dict(LIFECYCLE_GC if gc else LIFECYCLE_NOGC),
                      crash_restarts=(("n1", crash_at, restart_at),))
    return run_bench(_wl, AZURE_REDIS, cfg)


def _scan(res) -> tuple:
    """(scan_ms, slots_scanned) of n1's durable restart (0, 0 if absent)."""
    for node, t0, t1, scanned in res.recovery_spans:
        if node == "n1":
            return (t1 - t0, scanned)
    return (0.0, 0)


def sweep(quick: bool = False) -> List[Row]:
    short = 400.0 if quick else 800.0
    long_ = 4.0 * short
    rows: List[Row] = []
    for proto in PROTOS:
        for gc in (False, True):
            for label, horizon in (("short", short), ("long", long_)):
                res = run_one(proto, gc, horizon)
                scan_ms, scanned = _scan(res)
                cell = f"recovery/{proto}/gc{'on' if gc else 'off'}/{label}"
                derived = (f"commits={res.commits} scanned={scanned} "
                           f"recov={res.recoveries_run} "
                           f"gc={res.gc_truncations} "
                           f"wml={res.watermark_lag} "
                           f"viol={res.violations}")
                rows.append((f"{cell}/tput_tps", res.throughput_tps,
                             derived))
                rows.append((f"{cell}/scan_ms", scan_ms,
                             f"durable restart wall time, {scanned} slots"))
                rows.append((f"{cell}/scanned", float(scanned),
                             "slots probed by the restart scan"))
                rows.append((f"{cell}/violations", float(res.violations),
                             "AC1-AC3 + writer-of + recoverability + AC-GC"))
    # One replicated cell: the watermark census must settle through the
    # quorum rule, not single-volume presence.
    res = run_one("cornus", True, short, replication=3)
    scan_ms, scanned = _scan(res)
    rows.append(("recovery/cornus/r3/gcon/tput_tps", res.throughput_tps,
                 f"commits={res.commits} scanned={scanned} "
                 f"gc={res.gc_truncations} viol={res.violations}"))
    rows.append(("recovery/cornus/r3/gcon/scan_ms", scan_ms,
                 f"durable restart wall time, {scanned} slots"))
    rows.append(("recovery/cornus/r3/gcon/violations",
                 float(res.violations), "checker verdict"))
    return rows


def _vals(rows: List[Row], suffix: str) -> dict:
    return {name: value for name, value, _ in rows
            if name.endswith(suffix)}


def _check_bounds(rows: List[Row]) -> bool:
    ok = True
    scans = _vals(rows, "/scan_ms")
    scanned = _vals(rows, "/scanned")
    for name, value in sorted(_vals(rows, "/violations").items()):
        if value != 0:
            print(f"# safety REGRESSION: {name} = {value:.0f} (must be 0)",
                  file=sys.stderr)
            ok = False
    for proto in PROTOS:
        s = scans.get(f"recovery/{proto}/gcon/short/scan_ms", 0.0)
        l = scans.get(f"recovery/{proto}/gcon/long/scan_ms", 0.0)
        bound = GC_FLAT_BOUND * max(s, 1e-9)
        if l > bound:
            print(f"# recovery-bound REGRESSION: {proto} gc-on long scan "
                  f"{l:.2f}ms > {GC_FLAT_BOUND}x short ({s:.2f}ms)",
                  file=sys.stderr)
            ok = False
        ns = scanned.get(f"recovery/{proto}/gcoff/short/scanned", 0.0)
        nl = scanned.get(f"recovery/{proto}/gcoff/long/scanned", 0.0)
        if nl < NOGC_GROWTH_FLOOR * max(ns, 1.0):
            print(f"# growth-control REGRESSION: {proto} gc-off long scan "
                  f"probed {nl:.0f} slots, expected >= "
                  f"{NOGC_GROWTH_FLOOR}x short ({ns:.0f})", file=sys.stderr)
            ok = False
    if ok:
        print("# recovery bounds ok: gc-on scans flat in history length, "
              "gc-off scans grow, zero violations", file=sys.stderr)
    return ok


def main() -> None:
    gate_main(
        description=__doc__.splitlines()[0],
        sweep=sweep,
        baseline_path=BASELINE_PATH,
        bench_name="benchmarks.recovery_gc --quick",
        error_msg="recovery/GC sweep regressed against BENCH_recovery.json "
                  "or broke the bounded-recovery invariant",
        extra_check=_check_bounds)


if __name__ == "__main__":
    main()
