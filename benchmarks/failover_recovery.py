"""Failover recovery: leader leases restore the batched fast path.

Kills the initial storage leader (replica 0) against an R=3 replicated,
group-committed (serial piggyback) deployment and measures how leadership
leases recover the phase-1-free fast path:

  prefail   – no failure: the initial leader's implicit epoch-1 lease.
  postfail  – replica 0 down from t=0: the whole run is post-failover
              steady state on the epoch-2+ lease (one bulk prepare round
              per epoch, then owner-ballot single accepts, batched).
  midrun    – replica 0 dies a third of the way in: time-to-fast-path is
              when the new leader's first lease acquisition lands.

The headline claim (gated in ``tests/test_leases.py`` and via the pinned
baseline here): post-failover steady-state committed-txn throughput stays
within 1.2x of the pre-failover fast path, instead of the unbounded
per-op 2-RTT prepare+accept fallback this deployment used to pay.

Both sides of the comparison run with the same explicit protocol timeout
(``TIMEOUT_MS``): losing a replica costs a replica's worth of tail
absorption, and a timeout tuned to the no-failure p99 self-amplifies into
termination storms — the paper's deployments tune timeouts per service.

Standalone entry point with a CI regression gate::

    python -m benchmarks.failover_recovery --quick --check-baseline
    python -m benchmarks.failover_recovery --quick --write-baseline

The baseline (``BENCH_failover.json`` at the repo root) pins quick-mode
committed-txn throughput per configuration; ``--check-baseline`` exits
non-zero when any tracked throughput regresses more than 15%.
"""
from __future__ import annotations

import os
import sys
from typing import Dict, List

from repro.core import AZURE_REDIS
from repro.txn import BenchConfig, YCSBWorkload, run_bench

from benchmarks._baseline import Row, gate_main

BASELINE_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_failover.json")
RECOVERY_RATIO_BOUND = 1.2      # prefail tput / postfail tput acceptance
TIMEOUT_MS = 60.0               # above the degraded post-failover p99


def _wl(nodes, seed):
    return YCSBWorkload(nodes, accesses_per_txn=4, partition_theta=0.9,
                        keys_per_partition=10_000, seed=seed)


def run_one(proto: str, scenario: str, horizon_ms: float,
            replication: int = 3, seed: int = 3):
    fail_at = {"prefail": None, "postfail": 0.0,
               "midrun": horizon_ms / 3.0}[scenario]
    cfg = BenchConfig(protocol=proto, n_nodes=4, threads_per_node=8,
                      horizon_ms=horizon_ms, replication=replication,
                      seed=seed, storage_serial=True, batch_max=64,
                      timeout_ms=TIMEOUT_MS,
                      replica_failures=(() if fail_at is None
                                        else ((0, fail_at),)))
    return run_bench(_wl, AZURE_REDIS, cfg), fail_at


def time_to_fast_path_ms(res, fail_at: float) -> float:
    """Sim time from the leader's death to the first lease acquisition —
    when fast-path (and batched) service resumes on the new leader."""
    acquired = [t for (_epoch, _holder, t) in res.lease_history
                if t >= fail_at]
    return (acquired[0] - fail_at) if acquired else float("nan")


def sweep(quick: bool = False, replication: int = 3) -> List[Row]:
    protos = ("cornus", "2pc")
    horizon = 600.0 if quick else 1500.0
    rows: List[Row] = []
    for proto in protos:
        tput: Dict[str, float] = {}
        for scenario in ("prefail", "postfail", "midrun"):
            r, fail_at = run_one(proto, scenario, horizon,
                                 replication=replication)
            tput[scenario] = r.throughput_tps
            key = f"failover/r{replication}/{proto}/{scenario}"
            derived = (f"commits={r.commits} gaveups={r.gaveups} "
                       f"leases={r.lease_acquisitions} "
                       f"fast={r.fast_path_ops} fallback={r.fallback_ops}")
            rows.append((f"{key}/tput_tps", r.throughput_tps, derived))
            rows.append((f"{key}/avg_ms", r.avg_latency_ms,
                         f"p99={r.p99_latency_ms:.2f}"))
            if scenario == "midrun":
                rows.append((f"{key}/ttfp_ms",
                             time_to_fast_path_ms(r, fail_at),
                             "leader death -> first lease acquisition"))
        ratio = tput["prefail"] / max(tput["postfail"], 1e-9)
        rows.append((f"failover/r{replication}/{proto}/recovery_ratio",
                     ratio,
                     f"prefail/postfail tput; bound {RECOVERY_RATIO_BOUND}"))
    return rows


# ---------------------------------------------------------------------------
# Baseline gate (CI) — shared machinery in benchmarks/_baseline.py
# ---------------------------------------------------------------------------
def _check_recovery_ratios(rows: List[Row]) -> bool:
    ok = True
    for name, ratio, _ in rows:
        if not name.endswith("/recovery_ratio"):
            continue
        verdict = "ok" if ratio <= RECOVERY_RATIO_BOUND else "REGRESSION"
        if ratio > RECOVERY_RATIO_BOUND:
            ok = False
        print(f"# recovery {verdict}: {name} {ratio:.3f} "
              f"(bound {RECOVERY_RATIO_BOUND})", file=sys.stderr)
    return ok


def main() -> None:
    gate_main(description=__doc__.splitlines()[0],
              sweep=lambda quick: sweep(quick=quick),
              baseline_path=BASELINE_PATH,
              bench_name="benchmarks.failover_recovery --quick",
              error_msg="failover recovery regressed against "
                        "BENCH_failover.json",
              extra_check=_check_recovery_ratios)


if __name__ == "__main__":
    main()
