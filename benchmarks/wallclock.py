"""Wall-clock commit bench: the six Table-3 rows on the THREADED stores.

Every other bench in this directory runs the discrete-event sim; this one
runs ``repro.txn.threaded`` — real closed-loop worker threads against
``MemoryStore`` (the three "leader" rows) and the quorum-replicated
``ReplicatedStore`` (the three "coloc" rows), measured with the wall
clock.  It is the proof that the unified control plane of ``core.control``
— decision cache, singleflight, decision push, leadership leases — works
on the stores a real deployment would use, not just in simulation:

  * the straggler storm produces real ``decision_cache_hits`` /
    ``singleflight_hits`` / ``decisions_pushed`` on the threaded plane;
  * the replicated cornus rows commit through the lease holder's
    phase-1-free fast path (``fast_path_ops``);
  * cornus out-commits 2PC in every configuration, because 2PC pays one
    extra forced write (the eager commit record) per transaction.

Each row runs in its OWN subprocess, sequentially — process isolation
without cross-row CPU interference distorting the wall clock — and takes
the best of ``TRIALS`` runs (wall-clock noise only ever slows a run).
The injected per-op service delay dominates elapsed time, so throughput
is a property of the protocol's write count, not of the host machine.

Standalone entry point with a CI regression gate::

    python -m benchmarks.wallclock --quick --check-baseline
    python -m benchmarks.wallclock --quick --write-baseline

The baseline (``BENCH_wallclock.json`` at the repo root) pins quick-mode
committed-txn throughput per row; ``--check-baseline`` exits non-zero
when any tracked throughput regresses more than 15%, when any cornus row
falls behind its 2PC twin, or when the storm-control / fast-path
counters come back zero (the control plane silently disengaging is a
bug, not a slowdown).
"""
from __future__ import annotations

import multiprocessing
import os
import sys
from typing import Dict, List, Optional

from repro.txn.threaded import (WallclockConfig, WallclockResult,
                                run_wallclock, wallclock_rows)

from benchmarks._baseline import Row, gate_main, tracked

BASELINE_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_wallclock.json")

TRIALS = 3

# Per-op service delay large enough that OS sleep overshoot (the only
# machine-dependent term) stays a few percent of it; the straggler stall
# must outlast the racers' full pass over the txn's slots (each racer
# round pays one service delay).
SERVICE_DELAY_MS = 2.0
STRAGGLER_DELAY_MS = 20.0


def _row_config(protocol: str, backend: str, quick: bool) -> WallclockConfig:
    return WallclockConfig(
        protocol=protocol, backend=backend,
        workers=4 if quick else 8,
        txns_per_worker=24 if quick else 80,
        service_delay_ms=SERVICE_DELAY_MS,
        straggler_every=8,
        straggler_delay_ms=STRAGGLER_DELAY_MS,
        terminators=2, seed=7)


def _run_row(cfg: WallclockConfig,
             queue: "multiprocessing.Queue") -> None:
    best: Optional[WallclockResult] = None
    for _ in range(TRIALS):
        r = run_wallclock(cfg)
        if best is None or r.throughput_tps > best.throughput_tps:
            best = r
    queue.put(best)


def _run_isolated(cfg: WallclockConfig) -> WallclockResult:
    """Best-of-TRIALS in a fresh subprocess (falls back to inline when the
    platform can't fork, e.g. a sandbox)."""
    try:
        ctx = multiprocessing.get_context("fork")
        queue: "multiprocessing.Queue" = ctx.Queue()
        proc = ctx.Process(target=_run_row, args=(cfg, queue))
        proc.start()
        result = queue.get(timeout=300)
        proc.join()
        return result
    except (OSError, ValueError) as e:
        print(f"# wallclock: subprocess unavailable ({e!r}), "
              f"running row inline", file=sys.stderr)
        best: Optional[WallclockResult] = None
        for _ in range(TRIALS):
            r = run_wallclock(cfg)
            if best is None or r.throughput_tps > best.throughput_tps:
                best = r
        return best


def sweep(quick: bool = False) -> List[Row]:
    rows: List[Row] = []
    for row, (protocol, backend) in wallclock_rows().items():
        r = _run_isolated(_row_config(protocol, backend, quick))
        key = f"wallclock/{row}"
        derived = (f"backend={backend} commits={r.commits} "
                   f"term={r.terminated} elapsed_s={r.elapsed_s:.3f} "
                   f"cache={r.decision_cache_hits} "
                   f"sf={r.singleflight_hits} push={r.decisions_pushed} "
                   f"fast={r.fast_path_ops} leases={r.lease_acquisitions}")
        rows.append((f"{key}/tput_tps", r.throughput_tps, derived))
        for counter in ("decision_cache_hits", "singleflight_hits",
                        "decisions_pushed", "fast_path_ops"):
            rows.append((f"{key}/{counter}", float(getattr(r, counter)),
                         "threaded control-plane counter"))
    return rows


# ---------------------------------------------------------------------------
# Baseline gate (CI) — shared machinery in benchmarks/_baseline.py
# ---------------------------------------------------------------------------
ORDERING_PAIRS = (("wallclock/cornus/tput_tps", "wallclock/2pc/tput_tps"),
                  ("wallclock/cornus-coloc/tput_tps",
                   "wallclock/2pc-coloc/tput_tps"))

# Counters that must be NONZERO summed across rows; a zero means the
# threaded control plane (or the lease fast path) silently disengaged.
REQUIRED_COUNTERS = ("decision_cache_hits", "singleflight_hits",
                     "fast_path_ops")


def check_wallclock(rows: List[Row]) -> bool:
    got: Dict[str, float] = {name: value for name, value, _ in rows}
    ok = True
    for cornus, twopc in ORDERING_PAIRS:
        if cornus not in got or twopc not in got:
            print(f"# ordering MISSING: {cornus} vs {twopc}",
                  file=sys.stderr)
            ok = False
            continue
        good = got[cornus] >= got[twopc] * (1.0 - 1e-9)
        verdict = "ok" if good else "ORDERING-INVERTED"
        if not good:
            ok = False
        print(f"# ordering {verdict}: {cornus} {got[cornus]:.1f} "
              f"vs 2pc {got[twopc]:.1f}", file=sys.stderr)
    for counter in REQUIRED_COUNTERS:
        total = sum(v for name, v, _ in rows
                    if name.endswith(f"/{counter}"))
        verdict = "ok" if total > 0 else "ZERO"
        if total <= 0:
            ok = False
        print(f"# counter {verdict}: {counter} total={total:.0f}",
              file=sys.stderr)
    return ok


def main() -> None:
    gate_main(description=__doc__.splitlines()[0],
              sweep=sweep,
              baseline_path=BASELINE_PATH,
              bench_name="benchmarks.wallclock --quick",
              error_msg="wall-clock throughput regressed >15% against "
                        "BENCH_wallclock.json (or cornus fell behind 2pc, "
                        "or a control-plane counter came back zero)",
              extra_check=check_wallclock)


if __name__ == "__main__":
    main()
