"""Roofline analysis (deliverable g): read artifacts/dryrun JSONs, derive
the three terms per (arch × shape × mesh), name the bottleneck.

  compute_s    = HLO_FLOPs/device   / 197e12   (TPU v5e bf16 peak)
  memory_s     = HLO_bytes/device   / 819e9    (HBM BW)
  collective_s = wire_bytes/device  / 50e9     (ICI per-link)

roofline_fraction = compute_s / max(all three): the fraction of peak the
cell can reach if the dominant term is perfectly pipelined.  The
MODEL/HLO-flops ratio flags remat and redundant-compute waste.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    model_ratio: float = 0.0
    skipped: str = ""
    error: str = ""
    raw: Optional[dict] = None

    @property
    def bottleneck(self) -> str:
        if self.skipped or self.error:
            return "-"
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        m = max(self.compute_s, self.memory_s, self.collective_s)
        return self.compute_s / m if m > 0 else 0.0


def load_cells(dryrun_dir: str = "artifacts/dryrun") -> List[Cell]:
    cells = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        c = Cell(rec["arch"], rec["shape"], rec["mesh"],
                 skipped=rec.get("skipped", ""), error=rec.get("error", ""),
                 raw=rec)
        if not c.skipped and not c.error:
            n = rec["n_devices"]
            c.compute_s = rec["flops_per_device"] / PEAK_FLOPS
            c.memory_s = rec["hbm_bytes_per_device"] / HBM_BW
            c.collective_s = rec["collective_bytes_per_device"] / ICI_BW
            c.model_ratio = rec["model_flops_total"] / n / max(
                rec["flops_per_device"], 1e-9)
        cells.append(c)
    return cells


def rows(dryrun_dir: str = "artifacts/dryrun"):
    out = []
    for c in load_cells(dryrun_dir):
        tag = f"roofline/{c.arch}/{c.shape}/{c.mesh}"
        if c.skipped:
            out.append((tag, 0.0, f"SKIP:{c.skipped[:60]}"))
        elif c.error:
            out.append((tag, 0.0, f"ERROR:{c.error[:60]}"))
        else:
            out.append((
                tag, c.roofline_fraction,
                f"bottleneck={c.bottleneck} compute={c.compute_s:.3f}s "
                f"mem={c.memory_s:.3f}s coll={c.collective_s:.3f}s "
                f"model/hlo={c.model_ratio:.2f}"))
    return out


def table(dryrun_dir: str = "artifacts/dryrun", mesh: str = "single") -> str:
    lines = [f"| arch | shape | compute s | memory s | collective s | "
             f"bottleneck | roofline frac | model/HLO |",
             "|---|---|---|---|---|---|---|---|"]
    for c in load_cells(dryrun_dir):
        if c.mesh != mesh:
            continue
        if c.skipped:
            lines.append(f"| {c.arch} | {c.shape} | — | — | — | skipped | — | — |")
            continue
        lines.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.4f} | {c.memory_s:.4f} "
            f"| {c.collective_s:.4f} | {c.bottleneck} "
            f"| {c.roofline_fraction:.3f} | {c.model_ratio:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(table())
