"""Benchmark entry point: one function per paper table/figure + the
framework's roofline + checkpoint-commit benches.

Prints ``name,value,derived`` CSV (value is ms / ratio / fraction as the
name indicates).  ``python -m benchmarks.run [--quick]``.
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on bench name")
    ap.add_argument("--quick", action="store_true",
                    help="reduced issue windows / txn counts (CI smoke)")
    ap.add_argument("--dryrun-dir", default="artifacts/dryrun")
    args = ap.parse_args()

    from . import contention, paper_figs, roofline, ckpt_bench

    paper_figs.QUICK = args.quick

    benches = [(f.__name__, f) for f in paper_figs.ALL]
    benches.append(("contention_sweep",
                    lambda: contention.sweep(quick=args.quick)))
    benches.append(("ckpt_commit", ckpt_bench.run))
    benches.append(("roofline", lambda: roofline.rows(args.dryrun_dir)))

    print("name,value,derived")
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # report, keep going
            print(f"{name},ERROR,{e!r}"[:300])
            continue
        for rname, val, derived in rows:
            print(f"{rname},{val:.4f},{derived}")
        print(f"# {name} took {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == '__main__':
    main()
