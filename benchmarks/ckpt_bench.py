"""Beyond-paper benchmark: Cornus checkpoint-commit latency vs a
2PC-style manifest commit, over the live FileStore.

2PC-style = every host writes its shard + vote, then a coordinator writes a
MANIFEST (decision record) and the commit is the manifest write — one extra
serialized fsync'd write on the critical path, and a restart cannot trust an
epoch without it.  Cornus = commit is the collective votes (no manifest).
"""
from __future__ import annotations

import os
import tempfile
import threading
import time
from typing import List, Tuple

import numpy as np

from repro.ckpt import CornusCheckpointer, pack_tree, partition_leaves
from repro.core.state import Decision, Vote
from repro.core.storage import FileStore


def _payloads(n_hosts: int, mb_per_host: float):
    rng = np.random.RandomState(0)
    tree = {f"w{i}": rng.randn(int(mb_per_host * 131072 / 4), 2
                               ).astype(np.float32)
            for i in range(n_hosts)}
    hosts = [f"h{i}" for i in range(n_hosts)]
    parts = partition_leaves(tree, n_hosts)
    return hosts, {h: pack_tree(tree, keys) for h, keys in zip(hosts, parts)}


def _run_epoch(store, hosts, payloads, epoch, style: str) -> float:
    t0 = time.monotonic()
    cks = {h: CornusCheckpointer(store, h, hosts, straggler_timeout_s=30.0)
           for h in hosts}
    threads = [threading.Thread(target=cks[h].vote, args=(epoch, payloads[h]))
               for h in hosts]
    [t.start() for t in threads]
    [t.join() for t in threads]
    if style == "cornus":
        d, _ = cks[hosts[0]].resolve(epoch, deadline_s=30.0)
        assert d == Decision.COMMIT
    else:  # 2pc-style: decision manifest write on the critical path
        d, _ = cks[hosts[0]].resolve(epoch, deadline_s=30.0)
        assert d == Decision.COMMIT
        store.log(f"coord", f"manifest-{epoch}", Vote.COMMIT, writer="coord")
        store.put_data("coord", f"manifest-{epoch}",
                       b"epoch-manifest:" + str(epoch).encode())
    return (time.monotonic() - t0) * 1e3


def run(n_hosts=8, mb_per_host=4.0, trials=5) -> List[Tuple[str, float, str]]:
    rows = []
    with tempfile.TemporaryDirectory() as d:
        store = FileStore(d)
        hosts, payloads = _payloads(n_hosts, mb_per_host)
        lat = {"cornus": [], "2pc-manifest": []}
        epoch = 0
        for t in range(trials):
            for style in ("cornus", "2pc-manifest"):
                epoch += 1
                lat[style].append(
                    _run_epoch(store, hosts, payloads, epoch, style))
        for style, xs in lat.items():
            xs = sorted(xs)[1:-1] if len(xs) > 2 else xs  # trim outliers
            rows.append((f"ckpt/{style}_commit_ms", sum(xs) / len(xs),
                         f"hosts={n_hosts} {mb_per_host}MB/host"))
        sp = (sum(lat['2pc-manifest']) / len(lat['2pc-manifest'])) / \
            max(sum(lat['cornus']) / len(lat['cornus']), 1e-9)
        rows.append(("ckpt/speedup", sp, "cornus removes manifest write"))
    return rows
