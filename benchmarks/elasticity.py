"""Elastic membership: live quorum reconfiguration under sustained load.

Scales the replicated, group-committed deployment while a YCSB commit
workload runs against it — membership changes are epoch bumps whose bulk
``prepare_epoch`` carries the new config (Marlin-style), joiners catch up
via recovery-driven state transfer before they count in quorums, and the
lease hands over so the batched fast path survives the change:

  steady    – R=3, no reconfiguration: the control arm (bit-identical to
              the pre-elasticity store).
  scaleout  – R 3→5 a third of the way in: two fresh joiners state-
              transfer in the background, then one joint-quorum bump.
  scalein   – R 5→3: the two highest member ids retire (their ids are
              never reused, so their stale writes can never be chosen).
  cycle     – R 3→5→3 in one run: scale-out then scale-in, serialized by
              the store's single-flight reconfiguration guard.

Per reconfiguration the store records (started, cutover, installed,
old_n, new_n): started→cutover is non-disruptive background state
transfer under the OLD config; cutover→installed is the disruptive
window (the epoch bump + lease handover) and must stay under
``DISRUPTION_BOUND_MS``.  The gate also holds the paper ordering
(cornus ≥ 2pc per cell) and that every scheduled change completed with
zero given-up transactions — no committed txn is lost across configs.

Standalone entry point with a CI regression gate::

    python -m benchmarks.elasticity --quick --check-baseline
    python -m benchmarks.elasticity --quick --write-baseline

The baseline (``BENCH_elastic.json`` at the repo root) pins quick-mode
committed-txn throughput per cell; ``--check-baseline`` exits non-zero
on a >15% throughput regression, a disruption window over the bound, an
incomplete reconfiguration schedule, or inverted cornus/2pc ordering.
"""
from __future__ import annotations

import os
import sys
from typing import Dict, List

from repro.core import AZURE_REDIS
from repro.txn import BenchConfig, YCSBWorkload, run_bench

from benchmarks._baseline import Row, gate_main, tracked

BASELINE_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_elastic.json")
DISRUPTION_BOUND_MS = 25.0      # cutover -> installed, per config change
TIMEOUT_MS = 60.0               # same tuned timeout as the failover bench

# scenario -> (initial R, ((at_frac, new_R), ...))
SCENARIOS = {
    "steady":   (3, ()),
    "scaleout": (3, ((1 / 3, 5),)),
    "scalein":  (5, ((1 / 3, 3),)),
    "cycle":    (3, ((1 / 3, 5), (2 / 3, 3))),
}


def _wl(nodes, seed):
    return YCSBWorkload(nodes, accesses_per_txn=4, partition_theta=0.9,
                        keys_per_partition=10_000, seed=seed)


def run_one(proto: str, scenario: str, horizon_ms: float, seed: int = 3):
    r0, schedule = SCENARIOS[scenario]
    cfg = BenchConfig(protocol=proto, n_nodes=4, threads_per_node=8,
                      horizon_ms=horizon_ms, replication=r0,
                      seed=seed, storage_serial=True, batch_max=64,
                      timeout_ms=TIMEOUT_MS,
                      reconfigurations=tuple(
                          (frac * horizon_ms, n) for frac, n in schedule))
    return run_bench(_wl, AZURE_REDIS, cfg)


def disruption_ms(res) -> float:
    """Worst disruptive window across the run's config changes: epoch-bump
    start (cutover) to new-config install, background transfer excluded."""
    if not res.reconfig_history:
        return 0.0
    return max(installed - cutover
               for (_started, cutover, installed, _o, _n)
               in res.reconfig_history)


def sweep(quick: bool = False) -> List[Row]:
    protos = ("cornus", "2pc")
    horizon = 600.0 if quick else 1500.0
    rows: List[Row] = []
    for proto in protos:
        for scenario in SCENARIOS:
            r = run_one(proto, scenario, horizon)
            key = f"elastic/{proto}/{scenario}"
            derived = (f"commits={r.commits} gaveups={r.gaveups} "
                       f"reconfigs={len(r.reconfig_history)} "
                       f"leases={r.lease_acquisitions} "
                       f"degraded={r.lease_degradations} "
                       f"fast={r.fast_path_ops} fallback={r.fallback_ops}")
            rows.append((f"{key}/tput_tps", r.throughput_tps, derived))
            rows.append((f"{key}/gaveups", float(r.gaveups),
                         "txns abandoned after max_attempts (must be 0)"))
            rows.append((f"{key}/reconfigs", float(len(r.reconfig_history)),
                         f"completed config changes (scheduled "
                         f"{len(SCENARIOS[scenario][1])})"))
            if SCENARIOS[scenario][1]:
                rows.append((f"{key}/disruption_ms", disruption_ms(r),
                             f"worst cutover->install window; bound "
                             f"{DISRUPTION_BOUND_MS}"))
    return rows


# ---------------------------------------------------------------------------
# Baseline gate (CI) — shared machinery in benchmarks/_baseline.py
# ---------------------------------------------------------------------------
def check_elasticity(rows: List[Row]) -> bool:
    """Beyond the throughput floor: bounded disruption, completed
    schedules, zero lost txns, and the paper ordering per cell."""
    byname: Dict[str, float] = {name: value for name, value, _ in rows}
    ok = True
    for name, value in sorted(byname.items()):
        if name.endswith("/disruption_ms"):
            good = value <= DISRUPTION_BOUND_MS
            verdict = "ok" if good else "DISRUPTION-UNBOUNDED"
            print(f"# disruption {verdict}: {name} {value:.2f}ms "
                  f"(bound {DISRUPTION_BOUND_MS})", file=sys.stderr)
            ok = good and ok
        elif name.endswith("/reconfigs"):
            scenario = name.split("/")[-2]
            want = float(len(SCENARIOS[scenario][1]))
            good = value == want
            verdict = "ok" if good else "RECONFIG-INCOMPLETE"
            print(f"# schedule {verdict}: {name} {value:.0f}/{want:.0f}",
                  file=sys.stderr)
            ok = good and ok
        elif name.endswith("/gaveups"):
            good = value == 0.0
            verdict = "ok" if good else "TXNS-LOST"
            print(f"# gaveups {verdict}: {name} {value:.0f}",
                  file=sys.stderr)
            ok = good and ok
    got = tracked(rows)
    for name in sorted(got):
        if "/cornus/" not in name:
            continue
        peer = name.replace("/cornus/", "/2pc/")
        if peer not in got:
            continue
        good = got[name] >= got[peer] * (1.0 - 1e-9)
        verdict = "ok" if good else "ORDERING-INVERTED"
        if not good:
            ok = False
        print(f"# ordering {verdict}: {name} {got[name]:.1f} "
              f"vs 2pc {got[peer]:.1f}", file=sys.stderr)
    return ok


def main() -> None:
    gate_main(description=__doc__.splitlines()[0],
              sweep=lambda quick: sweep(quick=quick),
              baseline_path=BASELINE_PATH,
              bench_name="benchmarks.elasticity --quick",
              error_msg="elastic reconfiguration regressed against "
                        "BENCH_elastic.json (throughput, disruption "
                        "window, schedule completion, or ordering)",
              extra_check=check_elasticity)


if __name__ == "__main__":
    main()
