"""Chaos sweep: fault injection × protocol × replication, machine-checked.

Runs the closed-loop YCSB executor under seeded ``FaultSchedule`` chaos
(message drop/duplication/delay/reorder, network partitions with timed
heals, clock skew, torn partial-scatter writes, crash–restart with durable
logs) and validates EVERY run with the history checker
(``repro.core.history``): AC1–AC3, writer-of consistency and
recoverability must hold with zero violations — the gate is a safety
certificate, not just a throughput pin.

Grid: fault mix × R ∈ {1, 3} × {cornus, 2pc}.  Per cell the gate asserts

  * zero checker violations (any violation writes a failure-repro bundle
    to ``$CHAOS_REPRO_DIR`` and fails the run),
  * bounded gaveups (chaos may abort txns, not strand them),
  * cornus goodput ≥ 2pc goodput under the identical fault schedule
    (the paper's claim survives adversity, not just fair weather),

plus the usual pinned-throughput regression check (BENCH_chaos.json).

Standalone entry points::

    python -m benchmarks.chaos --quick --check-baseline
    python -m benchmarks.chaos --quick --write-baseline
    python -m benchmarks.chaos --verify-schedules 200
    python -m benchmarks.chaos --replay chaos-failures/chaos-seed7-cornus.json

``--verify-schedules N`` runs N distinct seeded schedules round-robin over
EVERY registered protocol at R ∈ {1, 3} and fails on any violation;
``--replay`` re-runs a failure bundle bit-for-bit.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import Dict, List, Tuple

from repro.core import AZURE_REDIS, FaultSchedule
from repro.core.chaos import load_repro_bundle, write_repro_bundle
from repro.core.protocols import registered_protocols
from repro.txn import BenchConfig, YCSBWorkload, run_bench

from benchmarks._baseline import Row, check_baseline, write_baseline

BASELINE_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_chaos.json")
MIXES = ("messages", "partition", "crash", "full")
PROTOS = ("cornus", "2pc")
GAVEUP_FRAC_BOUND = 0.05        # gaveups / issued txns per cell
# The keys a repro bundle's config carries — exactly what replay needs to
# reconstruct the BenchConfig (the schedule itself rides separately).
CONFIG_KEYS = ("protocol", "n_nodes", "threads_per_node", "horizon_ms",
               "seed", "replication", "retry_fresh_ids", "lifecycle")
# The "rot" mix arms durable-state faults (bit-flips, torn tails, GC-pulse
# truncation) — it only makes sense with the lifecycle layer on, so run_one
# arms checksums+gc+scrub for it.  The baselined sweep() grid (MIXES) is
# untouched: BENCH_chaos.json stays bit-identical.
LIFECYCLE_MIXES = ("rot",)
LIFECYCLE_CFG = dict(checksums=True, gc=True, scrub=True,
                     gc_interval_ms=25.0, scrub_interval_ms=40.0)


def _wl(nodes, seed):
    return YCSBWorkload(nodes, seed=seed)


def run_one(proto: str, mix: str, replication: int, seed: int,
            horizon_ms: float):
    """One chaotic cell: generate the schedule, run, return (res, bundle
    ingredients)."""
    nodes = [f"n{i}" for i in range(4)]
    sched = FaultSchedule.generate(seed, nodes, horizon_ms,
                                   replication if replication > 1 else 0,
                                   mix)
    cfg = BenchConfig(protocol=proto, n_nodes=4, threads_per_node=2,
                      horizon_ms=horizon_ms, seed=seed,
                      replication=replication, retry_fresh_ids=True,
                      chaos=sched, record_history=True,
                      lifecycle=(dict(LIFECYCLE_CFG)
                                 if mix in LIFECYCLE_MIXES else None))
    res = run_bench(_wl, AZURE_REDIS, cfg)
    config = {k: getattr(cfg, k) for k in CONFIG_KEYS}
    return res, sched, config


def _report_failure(res, sched, config, cell: str) -> str:
    path = write_repro_bundle(sched, config, res.violation_details,
                              name=f"{cell.replace('/', '-')}.json")
    print(f"# VIOLATIONS in {cell}: {res.violations} "
          f"(repro bundle: {path})", file=sys.stderr)
    for v in res.violation_details:
        print(f"#   {v}", file=sys.stderr)
    return path


def sweep(quick: bool = False) -> List[Row]:
    horizon = 300.0 if quick else 600.0
    rows: List[Row] = []
    for mix in MIXES:
        for replication in (1, 3):
            tput: Dict[str, float] = {}
            for proto in PROTOS:
                res, sched, config = run_one(proto, mix, replication,
                                             seed=7, horizon_ms=horizon)
                tput[proto] = res.throughput_tps
                cell = f"chaos/{mix}/r{replication}/{proto}"
                issued = max(1, res.commits + res.aborts + res.gaveups)
                derived = (f"commits={res.commits} gaveups={res.gaveups} "
                           f"dropped={res.msgs_dropped} "
                           f"dup={res.msgs_duplicated} "
                           f"delayed={res.msgs_delayed} "
                           f"reordered={res.msgs_reordered} "
                           f"torn={res.torn_writes} "
                           f"restarts={res.crash_restarts} "
                           f"recov={res.recoveries_run} "
                           f"guard_retries={res.guard_retries} "
                           f"trips={res.breaker_trips} "
                           f"scrub={res.scrub_repairs} "
                           f"quar={res.quarantines} "
                           f"gc={res.gc_truncations} "
                           f"wml={res.watermark_lag}")
                rows.append((f"{cell}/tput_tps", res.throughput_tps,
                             derived))
                rows.append((f"{cell}/violations", float(res.violations),
                             "AC1-AC3 + writer-of + recoverability"))
                rows.append((f"{cell}/gaveup_frac",
                             res.gaveups / issued,
                             f"bound {GAVEUP_FRAC_BOUND}"))
                if res.violations:
                    _report_failure(res, sched, config, cell)
            rows.append((f"chaos/{mix}/r{replication}/goodput_ratio",
                         tput["cornus"] / max(tput["2pc"], 1e-9),
                         "cornus/2pc committed tput under identical chaos; "
                         "bound >= 1.0"))
    return rows


# ---------------------------------------------------------------------------
# Safety gate (beyond the throughput pin)
# ---------------------------------------------------------------------------
def _check_safety(rows: List[Row]) -> bool:
    ok = True
    for name, value, _ in rows:
        if name.endswith("/violations") and value != 0:
            print(f"# safety REGRESSION: {name} = {value:.0f} "
                  f"(must be 0)", file=sys.stderr)
            ok = False
        if name.endswith("/gaveup_frac") and value > GAVEUP_FRAC_BOUND:
            print(f"# liveness REGRESSION: {name} = {value:.3f} "
                  f"(bound {GAVEUP_FRAC_BOUND})", file=sys.stderr)
            ok = False
        if name.endswith("/goodput_ratio") and value < 1.0:
            print(f"# goodput REGRESSION: {name} = {value:.3f} "
                  f"(cornus must not trail 2pc under identical chaos)",
                  file=sys.stderr)
            ok = False
    if ok:
        print("# safety ok: zero violations, bounded gaveups, "
              "cornus >= 2pc goodput in every cell", file=sys.stderr)
    return ok


# ---------------------------------------------------------------------------
# --verify-schedules N: the acceptance sweep (every protocol, R ∈ {1, 3})
# ---------------------------------------------------------------------------
def verify_schedules(n: int, horizon_ms: float = 300.0) -> int:
    cells = [(p, r) for p in registered_protocols() for r in (1, 3)]
    mixes = MIXES + LIFECYCLE_MIXES
    bad = 0
    recoveries: Dict[str, int] = {}
    t0 = time.time()
    for i in range(n):
        proto, replication = cells[i % len(cells)]
        mix = mixes[(i // len(cells)) % len(mixes)]
        res, sched, config = run_one(proto, mix, replication, seed=i,
                                     horizon_ms=horizon_ms)
        recoveries[proto] = recoveries.get(proto, 0) + res.recoveries_run
        if res.violations:
            bad += 1
            _report_failure(res, sched, config,
                            f"verify/{mix}/r{replication}/{proto}/seed{i}")
    for proto in sorted(recoveries):
        print(f"# {proto}: crash-restart recoveries exercised: "
              f"{recoveries[proto]}", file=sys.stderr)
    print(f"# verified {n} schedules in {time.time() - t0:.1f}s: "
          f"{bad} with violations", file=sys.stderr)
    return bad


# ---------------------------------------------------------------------------
# --replay <bundle>: re-run a recorded failure bit-for-bit
# ---------------------------------------------------------------------------
def replay(path: str) -> int:
    sched, config = load_repro_bundle(path)
    kwargs = {k: config[k] for k in CONFIG_KEYS if k in config}
    cfg = BenchConfig(chaos=sched, record_history=True, **kwargs)
    res = run_bench(_wl, AZURE_REDIS, cfg)
    print(f"# replayed {path}: protocol={cfg.protocol} seed={cfg.seed} "
          f"commits={res.commits} gaveups={res.gaveups} "
          f"recoveries={res.recoveries_run}", file=sys.stderr)
    if res.violations:
        print(f"# violations REPRODUCED ({res.violations}):",
              file=sys.stderr)
        for v in res.violation_details:
            print(f"#   {v}", file=sys.stderr)
    else:
        print("# no violations (failure no longer reproduces)",
              file=sys.stderr)
    return res.violations


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="reduced issue windows (CI)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="pin current quick-mode throughput "
                         "to BENCH_chaos.json")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail (exit 1) on >15%% throughput regression, "
                         "any checker violation, unbounded gaveups, or "
                         "cornus goodput below 2pc")
    ap.add_argument("--baseline", default=BASELINE_PATH)
    ap.add_argument("--replay", metavar="BUNDLE",
                    help="re-run a failure-repro bundle and re-check")
    ap.add_argument("--verify-schedules", type=int, metavar="N",
                    help="run N seeded schedules across every registered "
                         "protocol at R in {1,3}; exit 1 on any violation")
    args = ap.parse_args()

    if args.replay:
        sys.exit(1 if replay(args.replay) else 0)
    if args.verify_schedules:
        sys.exit(1 if verify_schedules(args.verify_schedules) else 0)

    t0 = time.time()
    rows = sweep(args.quick or args.write_baseline or args.check_baseline)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.4f},{derived}")
    print(f"# sweep took {time.time() - t0:.1f}s", file=sys.stderr)

    if args.write_baseline:
        write_baseline(rows, args.baseline, "benchmarks.chaos --quick")
        print(f"# baseline written to {args.baseline}", file=sys.stderr)
    if args.check_baseline:
        if not check_baseline(rows, args.baseline, _check_safety):
            print("::error::chaos sweep regressed against BENCH_chaos.json "
                  "or violated a safety invariant", file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
