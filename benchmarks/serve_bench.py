"""Transactional-serving bench: protocol × arrival × batch mode.

Drives ``repro.serve`` — inference sessions whose every step commits as a
distributed transaction — through a sweep of commit protocol (cornus vs
2pc), arrival process (closed loop, open loop at a fixed rate), and batch
mode (continuous batching vs batches of one).  Per cell it reports
committed-step throughput (the tracked baseline metric), goodput within
deadline, and the latency tail (p50/p99, TTFT).

Every forced store write pays an injected 2 ms service delay (inside the
op, under the control plane), so the latency ordering is structural:
cornus commits a step after 3 forced vote writes, 2pc after the same 3
votes PLUS an eager forced commit record — a fixed ~2 ms tail gap that
the p99 gate pins per cell.

One extra cell prices disruption: a closed-loop cornus run on the quorum-
replicated store with a background checkpoint publisher committing
snapshot epochs over the middle third of the run AND one replica volume
killed at the same moment.  The gate requires in-window throughput to
stay ≥ 80% of steady state — serving must not stall behind a publish or
a dead replica.

Standalone entry point with a CI regression gate::

    python -m benchmarks.serve_bench --quick --check-baseline
    python -m benchmarks.serve_bench --quick --write-baseline

The baseline (``BENCH_serve.json`` at the repo root) pins quick-mode
throughput per cell; ``--check-baseline`` exits non-zero on a >15%
regression, on a cell where cornus p99 exceeds 2pc p99, or on a
disruption ratio below 0.8.
"""
from __future__ import annotations

import multiprocessing
import os
import sys
from typing import Dict, List, Optional

from repro.serve import AdmissionConfig, EngineConfig, SessionConfig, \
    run_serve

from benchmarks._baseline import Row, gate_main

BASELINE_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serve.json")

TRIALS = 3

# Injected per-forced-write service time: large enough that OS sleep
# overshoot stays a few percent of it, and the 2pc extra decision record
# (one more forced write per step) is a structural ~2 ms latency gap.
SERVICE_DELAY_MS = 2.0
PROTOCOLS = ("cornus", "2pc")

# (arrival label, batch modes swept at that arrival).  Open-loop rates
# sweep the arrival dimension; the unbatched control arm only needs the
# closed loop (it prices batching, not arrivals).
QUICK_ARRIVALS = (("closed", ("batched", "unbatched")),
                  ("open400", ("batched",)))
FULL_ARRIVALS = (("closed", ("batched", "unbatched")),
                 ("open200", ("batched",)),
                 ("open400", ("batched", "unbatched")),
                 ("open800", ("batched",)))


def _cell_config(protocol: str, arrival: str, mode: str,
                 quick: bool) -> EngineConfig:
    session = SessionConfig(protocol=protocol, backend="memory",
                            participants_per_txn=3,
                            service_delay_ms=SERVICE_DELAY_MS, seed=7)
    admission = AdmissionConfig(max_batch=8, window_ms=1.0,
                                queue_depth=64, deadline_ms=250.0)
    cfg = EngineConfig(session=session, admission=admission,
                       decode="stub", batch_mode=mode, seed=7,
                       clients=8,
                       steps_per_session=30 if quick else 80)
    if arrival.startswith("open"):
        cfg.arrival = "open"
        cfg.rate_rps = float(arrival[4:])
        cfg.duration_s = 1.2 if quick else 3.0
        cfg.admission = AdmissionConfig(max_batch=8, window_ms=1.0,
                                        queue_depth=64,
                                        backpressure="reject",
                                        deadline_ms=250.0)
    return cfg


def _disruption_config(quick: bool) -> EngineConfig:
    """Replicated store, background publish over the middle third of the
    run, one replica volume killed as publishing starts."""
    session = SessionConfig(protocol="cornus", backend="replicated",
                            replication=3, participants_per_txn=3,
                            service_delay_ms=SERVICE_DELAY_MS, seed=7)
    return EngineConfig(
        session=session,
        admission=AdmissionConfig(max_batch=8, window_ms=1.0),
        decode="stub", seed=7, clients=8,
        steps_per_session=45 if quick else 120,
        publish_at=0.33, publish_until=0.66, publish_hosts=2,
        publish_interval_s=0.02, kill_replica_at=0.33, stall_at=0.5)


def _summarize(cfg: EngineConfig) -> Dict[str, float]:
    """Best-of-TRIALS cell summary: throughput takes the best trial (noise
    only slows a run); tail latency and the disruption ratio take each
    trial's best too, so both protocols face the same scheduler luck."""
    best: Optional[Dict[str, float]] = None
    for _ in range(TRIALS):
        r = run_serve(cfg)
        rep = r.report
        cur = {
            "tput_tps": rep.throughput_tps,
            "goodput_tps": rep.goodput_tps,
            "p50_ms": rep.p50_ms, "p99_ms": rep.p99_ms,
            "ttft_p50_ms": rep.ttft_p50_ms,
            "tail_amp": rep.tail_amplification,
            "mean_batch": rep.mean_batch,
            "max_batch_seen": float(r.counters["max_batch_seen"]),
            "committed": float(rep.committed),
            "aborted": float(rep.aborted),
            "dropped": float(rep.dropped),
            "rejected": float(rep.rejected),
            "terminations": float(r.counters["terminations"]),
            "publishes": float(len(r.publishes)),
            "disruption": (rep.publish_disruption
                           if rep.publish_disruption is not None else -1.0),
        }
        if best is None:
            best = cur
        else:
            for k in ("tput_tps", "goodput_tps", "max_batch_seen",
                      "disruption"):
                best[k] = max(best[k], cur[k])
            for k in ("p50_ms", "p99_ms", "ttft_p50_ms", "tail_amp"):
                best[k] = min(best[k], cur[k])
    return best


def _run_cell(cfg: EngineConfig, queue: "multiprocessing.Queue") -> None:
    queue.put(_summarize(cfg))


def _run_isolated(cfg: EngineConfig) -> Dict[str, float]:
    """Each cell in a fresh subprocess — no cross-cell thread/CPU
    interference in the wall-clock numbers (inline fallback when the
    platform can't fork)."""
    try:
        ctx = multiprocessing.get_context("fork")
        queue: "multiprocessing.Queue" = ctx.Queue()
        proc = ctx.Process(target=_run_cell, args=(cfg, queue))
        proc.start()
        result = queue.get(timeout=600)
        proc.join()
        return result
    except (OSError, ValueError) as e:
        print(f"# serve_bench: subprocess unavailable ({e!r}), "
              f"running cell inline", file=sys.stderr)
        return _summarize(cfg)


def sweep(quick: bool = False) -> List[Row]:
    rows: List[Row] = []
    arrivals = QUICK_ARRIVALS if quick else FULL_ARRIVALS
    for arrival, modes in arrivals:
        for mode in modes:
            for protocol in PROTOCOLS:
                s = _run_isolated(_cell_config(protocol, arrival, mode,
                                               quick))
                key = f"serve/{protocol}/{arrival}/{mode}"
                derived = (f"goodput={s['goodput_tps']:.1f} "
                           f"p50={s['p50_ms']:.2f} "
                           f"ttft_p50={s['ttft_p50_ms']:.2f} "
                           f"tail_amp={s['tail_amp']:.2f} "
                           f"mean_batch={s['mean_batch']:.2f} "
                           f"committed={s['committed']:.0f} "
                           f"aborted={s['aborted']:.0f} "
                           f"dropped={s['dropped']:.0f} "
                           f"rejected={s['rejected']:.0f}")
                rows.append((f"{key}/tput_tps", s["tput_tps"], derived))
                rows.append((f"{key}/p99_ms", s["p99_ms"],
                             "end-to-end step latency tail"))
                if mode == "batched":
                    rows.append((f"{key}/max_batch_seen",
                                 s["max_batch_seen"],
                                 "continuous batching engagement"))
    d = _run_isolated(_disruption_config(quick))
    rows.append(("serve/disruption/tput_tps", d["tput_tps"],
                 f"replicated+publish+replica-kill committed={d['committed']:.0f} "
                 f"aborted={d['aborted']:.0f} publishes={d['publishes']:.0f} "
                 f"terminations={d['terminations']:.0f}"))
    rows.append(("serve/disruption/ratio", d["disruption"],
                 "publish-window tput / steady-state tput (>=0.8 gated)"))
    rows.append(("serve/disruption/publishes", d["publishes"],
                 "checkpoint epochs committed mid-traffic"))
    return rows


# ---------------------------------------------------------------------------
# Baseline gate (CI) — shared machinery in benchmarks/_baseline.py
# ---------------------------------------------------------------------------
P99_SLACK = 1.02        # scheduler-noise allowance on the per-cell compare
MIN_DISRUPTION = 0.8    # publish+kill window keeps >=80% of steady tput


def check_serve(rows: List[Row]) -> bool:
    got: Dict[str, float] = {name: value for name, value, _ in rows}
    ok = True
    # Within every swept cell, cornus's p99 must not exceed 2pc's: the
    # eager decision record is a per-step latency cost, and it has to show.
    cells = sorted({name[len("serve/cornus/"):-len("/p99_ms")]
                    for name in got
                    if name.startswith("serve/cornus/")
                    and name.endswith("/p99_ms")})
    for cell in cells:
        c = got.get(f"serve/cornus/{cell}/p99_ms")
        t = got.get(f"serve/2pc/{cell}/p99_ms")
        if c is None or t is None:
            print(f"# p99 MISSING for cell {cell}", file=sys.stderr)
            ok = False
            continue
        good = c <= t * P99_SLACK
        verdict = "ok" if good else "TAIL-INVERTED"
        if not good:
            ok = False
        print(f"# p99 {verdict}: {cell} cornus {c:.2f}ms vs 2pc {t:.2f}ms",
              file=sys.stderr)
    ratio = got.get("serve/disruption/ratio")
    if ratio is None:
        print("# disruption MISSING", file=sys.stderr)
        ok = False
    else:
        good = ratio >= MIN_DISRUPTION
        verdict = "ok" if good else "STALLED"
        if not good:
            ok = False
        print(f"# disruption {verdict}: publish-window ratio {ratio:.2f} "
              f"(floor {MIN_DISRUPTION})", file=sys.stderr)
    pubs = got.get("serve/disruption/publishes", 0.0)
    if pubs <= 0:
        print("# disruption ZERO publishes: publisher never committed "
              "an epoch mid-traffic", file=sys.stderr)
        ok = False
    engaged = sum(v for name, v, _ in rows
                  if name.endswith("/max_batch_seen"))
    if engaged < 2:
        print(f"# batching ZERO: no batched cell ever formed a multi-item "
              f"batch (sum max_batch_seen={engaged:.0f})", file=sys.stderr)
        ok = False
    return ok


def main() -> None:
    gate_main(description=__doc__.splitlines()[0],
              sweep=sweep,
              baseline_path=BASELINE_PATH,
              bench_name="benchmarks.serve_bench --quick",
              error_msg="serving throughput regressed >15% against "
                        "BENCH_serve.json (or cornus p99 exceeded 2pc p99 "
                        "in a cell, or a publish/replica-kill window "
                        "dropped throughput below 80% of steady state)",
              extra_check=check_serve)


if __name__ == "__main__":
    main()
