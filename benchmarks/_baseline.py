"""Shared throughput-baseline gate for the standalone sweep benches.

``benchmarks.contention`` and ``benchmarks.failover_recovery`` both pin
quick-mode committed-txn throughput per configuration in a JSON file at
the repo root and fail CI when any tracked value regresses more than
``REGRESSION_TOLERANCE``.  The sweep itself differs per bench; the gate
(tracking, pinning, checking, CLI) lives here once.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Callable, Dict, List, Optional, Tuple

Row = Tuple[str, float, str]

REGRESSION_TOLERANCE = 0.15     # CI fails below 85% of baseline throughput


def tracked(rows: List[Row]) -> Dict[str, float]:
    return {name: value for name, value, _ in rows
            if name.endswith("/tput_tps")}


def write_baseline(rows: List[Row], path: str, bench: str) -> None:
    payload = {
        "schema": 1,
        "bench": bench,
        "note": "quick-mode committed-txn throughput per configuration; "
                "CI fails when a tracked value drops below "
                f"{1 - REGRESSION_TOLERANCE:.0%} of this baseline "
                "(deterministic sim: genuine drift means a code change).",
        "tput_tps": tracked(rows),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def check_baseline(rows: List[Row], path: str,
                   extra_check: Optional[Callable[[List[Row]], bool]] = None
                   ) -> bool:
    with open(path) as f:
        baseline = json.load(f)["tput_tps"]
    got = tracked(rows)
    ok = True
    for name, want in sorted(baseline.items()):
        have = got.get(name)
        if have is None:
            print(f"# baseline MISSING from sweep: {name}", file=sys.stderr)
            ok = False
            continue
        floor = want * (1.0 - REGRESSION_TOLERANCE)
        verdict = "ok" if have >= floor else "REGRESSION"
        if have < floor:
            ok = False
        print(f"# baseline {verdict}: {name} {have:.1f} vs {want:.1f} "
              f"(floor {floor:.1f})", file=sys.stderr)
    if extra_check is not None:
        ok = extra_check(rows) and ok
    return ok


def gate_main(description: str, sweep: Callable[[bool], List[Row]],
              baseline_path: str, bench_name: str, error_msg: str,
              extra_check: Optional[Callable[[List[Row]], bool]] = None
              ) -> None:
    """Shared CLI: print the sweep CSV, optionally pin or gate it."""
    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--quick", action="store_true",
                    help="reduced grid / issue windows (CI)")
    ap.add_argument("--write-baseline", action="store_true",
                    help=f"pin current quick-mode throughput "
                         f"to {os.path.basename(baseline_path)}")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail (exit 1) on >15%% throughput regression "
                         "against the pinned baseline")
    ap.add_argument("--baseline", default=baseline_path)
    args = ap.parse_args()

    t0 = time.time()
    rows = sweep(args.quick or args.write_baseline or args.check_baseline)
    print("name,value,derived")
    for name, value, derived in rows:
        print(f"{name},{value:.4f},{derived}")
    print(f"# sweep took {time.time() - t0:.1f}s", file=sys.stderr)

    if args.write_baseline:
        write_baseline(rows, args.baseline, bench_name)
        print(f"# baseline written to {args.baseline}", file=sys.stderr)
    if args.check_baseline:
        if not check_baseline(rows, args.baseline, extra_check):
            print(f"::error::{error_msg}", file=sys.stderr)
            sys.exit(1)
