"""One benchmark per paper table/figure (Cornus §5), on the deterministic
discrete-event simulator with the paper's measured storage latencies.

Each fig*() returns a list of CSV rows: (name, value_ms_or_x, derived).
"""
from __future__ import annotations

import sys
from typing import List, Tuple

from repro.core import (AZURE_BLOB, AZURE_BLOB_SEPARATE_ACL, AZURE_REDIS,
                        CROSS_REGION, SLOW_REDIS, Cluster, Decision,
                        ProtocolConfig, Sim, SimStorage, TxnSpec,
                        measured_caller_latency_ms,
                        predicted_caller_latency_ms, rtt_table)
from repro.txn import (BenchConfig, GeoYCSBWorkload, TPCCWorkload,
                       YCSBWorkload, run_bench)

Row = Tuple[str, float, str]
HORIZON = 900.0

# Set by ``benchmarks.run --quick``: shrink issue windows so the whole suite
# doubles as a CI smoke job.
QUICK = False


def _horizon(h: float) -> float:
    return min(h, 250.0) if QUICK else h


def _ycsb(theta=0.0, keys=10_000, read_ratio=0.5):
    return lambda nodes, seed: YCSBWorkload(
        nodes, theta=theta, keys_per_partition=keys, read_ratio=read_ratio,
        seed=seed)


def _speedup(res) -> float:
    """2PC-over-Cornus caller-latency ratio for a {"cornus","2pc"} result
    pair (floor-guarded against empty-latency runs)."""
    return res["2pc"].avg_latency_ms / max(res["cornus"].avg_latency_ms, 1e-9)


def _bench(proto, model, n=4, wl=None, horizon=HORIZON, elr=False, seed=1):
    cfg = BenchConfig(protocol=proto, n_nodes=n, horizon_ms=_horizon(horizon),
                      elr=elr, seed=seed)
    return run_bench(wl or _ycsb(), model, cfg)


# ---------------------------------------------------------------------------
def fig5_scalability() -> List[Row]:
    """Fig 5(a–d): latency vs #nodes, Redis + Blob; speedup ≤1.9×."""
    rows: List[Row] = []
    for model, tag in ((AZURE_REDIS, "redis"), (AZURE_BLOB, "blob")):
        for n in (2, 4, 8):
            r = {p: _bench(p, model, n=n) for p in ("cornus", "2pc")}
            sp = _speedup(r)
            rows.append((f"fig5/{tag}/n{n}/cornus_avg_ms",
                         r["cornus"].avg_latency_ms, f"p99={r['cornus'].p99_latency_ms:.2f}"))
            rows.append((f"fig5/{tag}/n{n}/2pc_avg_ms",
                         r["2pc"].avg_latency_ms, f"p99={r['2pc'].p99_latency_ms:.2f}"))
            rows.append((f"fig5/{tag}/n{n}/speedup", sp, "paper<=1.9x"))
    return rows


def fig5_separate_acl() -> List[Row]:
    """Fig 5(e,f): Blob with separate ACLs — Cornus advantage vanishes."""
    rows = []
    r = {p: _bench(p, AZURE_BLOB_SEPARATE_ACL, n=4)
         for p in ("cornus", "2pc")}
    sp = _speedup(r)
    rows.append(("fig5acl/cornus_avg_ms", r["cornus"].avg_latency_ms,
                 f"prepare={r['cornus'].breakdown()['prepare']:.2f}"))
    rows.append(("fig5acl/2pc_avg_ms", r["2pc"].avg_latency_ms,
                 f"prepare={r['2pc'].breakdown()['prepare']:.2f}"))
    rows.append(("fig5acl/speedup", sp, "paper~1.0x (no improvement)"))
    return rows


def fig6_readonly() -> List[Row]:
    """Fig 6: varying read-only %: gain only from RW txns (≤1.7×)."""
    rows = []
    for frac, p_read in ((0.0, 0.5), (0.4, 0.4 ** (1 / 16)),
                         (0.8, 0.8 ** (1 / 16))):
        wl = _ycsb(read_ratio=p_read)
        r = {p: _bench(p, AZURE_BLOB, n=4, wl=wl) for p in ("cornus", "2pc")}
        sp = _speedup(r)
        bd = r["cornus"].breakdown()
        rows.append((f"fig6/ro{int(frac*100)}/speedup", sp,
                     f"commit_ms={bd['commit']:.2f}"))
    return rows


def fig7_contention() -> List[Row]:
    """Fig 7: YCSB zipfian θ and TPC-C warehouses; gain shrinks when abort
    time dominates."""
    rows = []
    for theta in (0.0, 0.6, 0.9):
        wl = _ycsb(theta=theta, keys=1000)
        r = {p: _bench(p, AZURE_REDIS, n=4, wl=wl) for p in ("cornus", "2pc")}
        sp = _speedup(r)
        rows.append((f"fig7/ycsb_theta{theta}/speedup", sp,
                     f"abort_ms={r['cornus'].breakdown()['abort']:.2f}"))
    for wh in (16, 4, 2):
        wl = lambda nodes, seed, wh=wh: TPCCWorkload(nodes, n_warehouses=wh,
                                                     seed=seed)
        r = {p: _bench(p, AZURE_REDIS, n=4, wl=wl) for p in ("cornus", "2pc")}
        sp = _speedup(r)
        rows.append((f"fig7/tpcc_wh{wh}/speedup", sp,
                     f"tput={r['cornus'].throughput_tps:.0f}tps"))
    return rows


def fig8_termination() -> List[Row]:
    """Fig 8: time to terminate on coordinator failure — Cornus bounded
    (~2·storage RTT), 2PC blocked (unbounded)."""
    rows = []
    for model, tag in ((AZURE_REDIS, "redis"), (AZURE_BLOB, "blob")):
        for n in (2, 4, 8):
            sim = Sim()
            storage = SimStorage(sim, model, seed=3)
            nodes = [f"n{i}" for i in range(n)]
            cl = Cluster(sim, storage, nodes,
                         ProtocolConfig(protocol="cornus"))
            spec = TxnSpec(txn_id="t", coordinator="n0", participants=nodes)
            # Coordinator dies BEFORE any vote lands => decision unsent,
            # every participant must run the termination protocol.
            cl.fail("n0", 1.0)
            cl.run_txn(spec)
            sim.run(until=60_000)
            times = [o.termination_ms for o in cl.outcomes.values()
                     if o.ran_termination and o.termination_ms > 0]
            avg = sum(times) / max(len(times), 1)
            mx = max(times) if times else 0.0
            rows.append((f"fig8/{tag}/n{n}/terminate_avg_ms", avg,
                         f"max={mx:.2f} paper<=4ms(redis)/20ms(blob)"))
        # 2PC blocks in the same scenario:
        sim = Sim()
        storage = SimStorage(sim, model, seed=3)
        nodes = [f"n{i}" for i in range(4)]
        cl = Cluster(sim, storage, nodes, ProtocolConfig(protocol="2pc"))
        cl.fail("n0", 1.0)
        cl.run_txn(TxnSpec(txn_id="t", coordinator="n0", participants=nodes))
        sim.run(until=60_000)
        blocked = sum(1 for b in cl.blocked.values() if b)
        rows.append((f"fig8/{tag}/2pc_blocked_participants", float(blocked),
                     "2PC: unbounded (blocked until coordinator recovery)"))
    return rows


def fig9_elr() -> List[Row]:
    """Fig 9: speculative precommit (ELR) under contention."""
    rows = []
    for theta in (0.0, 0.9):
        for proto in ("cornus", "2pc"):
            base = _bench(proto, AZURE_REDIS, n=4,
                          wl=_ycsb(theta=theta, keys=200))
            elr = _bench(proto, AZURE_REDIS, n=4,
                         wl=_ycsb(theta=theta, keys=200), elr=True)
            gain = (elr.throughput_tps - base.throughput_tps) / \
                max(base.throughput_tps, 1e-9) * 100
            rows.append((f"fig9/theta{theta}/{proto}_elr_tput_gain_pct",
                         gain, f"base={base.throughput_tps:.0f}tps"))
    return rows


def fig10_coordinator_log() -> List[Row]:
    """Fig 10: CL vs 2PC vs Cornus on slow (443ms-write) storage."""
    rows = []
    r = {p: _bench(p, SLOW_REDIS, n=4, horizon=12_000.0)
         for p in ("cornus", "cl", "2pc")}
    for p in ("cornus", "cl", "2pc"):
        rows.append((f"fig10/{p}_avg_ms", r[p].avg_latency_ms,
                     f"commits={r[p].commits}"))
    rows.append(("fig10/cl_vs_2pc_gain_pct",
                 (r["2pc"].avg_latency_ms - r["cl"].avg_latency_ms)
                 / max(r["2pc"].avg_latency_ms, 1e-9) * 100, "paper~33%"))
    rows.append(("fig10/cornus_vs_cl_gain_pct",
                 (r["cl"].avg_latency_ms - r["cornus"].avg_latency_ms)
                 / max(r["cl"].avg_latency_ms, 1e-9) * 100, "paper~50%"))
    return rows


def table3_rtt() -> List[Row]:
    """Table 3: analytic RTTs on the critical path (Paxos-backed storage)."""
    want = {"2pc": 5.0, "cornus": 3.0, "cornus-opt1": 2.5, "2pc-coloc": 3.0,
            "cornus-coloc": 2.0, "paxos-commit": 1.5}
    rows = []
    for proto, row in rtt_table().items():
        rows.append((f"table3/{proto}_rtts", row["total"],
                     f"paper={want[proto]} requires={';'.join(row['requires']) or '-'}"))
    return rows


# ---------------------------------------------------------------------------
# Replicated / geo-distributed storage (extended paper §6)
# ---------------------------------------------------------------------------
GEO_PLACEMENT = {"n0": "us-east", "n1": "us-west", "n2": "eu-west",
                 "n3": "us-west"}
GEO_REPLICAS = ["us-east", "us-west", "eu-west", "us-east", "us-west"]


def _geo_bench(proto, r, fail=(), seed=7, horizon=4000.0):
    """Geo-YCSB: coordinator (and caller) in us-east; data partitions and
    the R-replica storage quorum spread across us-west / eu-west.  Fewer
    accesses per txn than plain YCSB so commit round trips, not execution
    RPCs, dominate caller latency."""
    def wl(nodes, seed):
        return GeoYCSBWorkload(nodes, GEO_PLACEMENT, "us-east",
                               accesses_per_txn=4, seed=seed)

    cfg = BenchConfig(protocol=proto, n_nodes=4, horizon_ms=_horizon(horizon),
                      replication=r, topology=CROSS_REGION,
                      placement=GEO_PLACEMENT,
                      replica_regions=GEO_REPLICAS[:r],
                      replica_failures=fail, coordinator_nodes=["n0"],
                      seed=seed)
    return run_bench(wl, AZURE_REDIS, cfg)


# The geo sweeps cover the registry's whole protocol family: the paper's
# headline pair plus the forwarding Table-3 rows this repo implements.
GEO_PROTOCOLS = ("cornus", "2pc", "cornus-opt1", "paxos-commit")


def geo_replication_sweep() -> List[Row]:
    """Replication factor sweep R ∈ {1,3,5} × protocol on the cross-region
    topology: Cornus's missing decision-log write is worth one full
    cross-region quorum round per transaction; the forwarding variants
    shave further half-rounds off the prepare path."""
    rows: List[Row] = []
    for r in (1, 3, 5):
        res = {p: _geo_bench(p, r) for p in GEO_PROTOCOLS}
        for p in GEO_PROTOCOLS:
            rows.append((f"geo/r{r}/{p}_avg_ms", res[p].avg_latency_ms,
                         f"commits={res[p].commits} "
                         f"p99={res[p].p99_latency_ms:.1f}"))
        sp = _speedup(res)
        rows.append((f"geo/r{r}/speedup", sp, "cornus vs 2pc"))
    return rows


def geo_failover() -> List[Row]:
    """R=3 with the coordinator-region replica down from t=0: quorum ops
    fail over (leader moves cross-region, LogOnce pays full prepare+accept)
    yet every protocol stays live and Cornus keeps its latency win."""
    rows: List[Row] = []
    res = {p: _geo_bench(p, 3, fail=((0, 0.0),)) for p in GEO_PROTOCOLS}
    for p in GEO_PROTOCOLS:
        rows.append((f"geofail/{p}_avg_ms", res[p].avg_latency_ms,
                     f"commits={res[p].commits} gaveups={res[p].gaveups}"))
    sp = _speedup(res)
    rows.append(("geofail/speedup", sp,
                 "one replica down; cornus should still beat 2pc"))
    return rows


def table3_sim_validation() -> List[Row]:
    """Measured sim caller latency vs the analytic Table-3 RTT counts —
    every row of Table 3 now has a runnable deployment and must land
    EXACTLY on its predicted multiple."""
    from repro.core import SIMULATED_RTT_ROWS
    rows: List[Row] = []
    rtt = 20.0
    for proto in SIMULATED_RTT_ROWS:
        measured = measured_caller_latency_ms(proto, rtt)
        predicted = predicted_caller_latency_ms(proto, rtt)
        rows.append((f"table3sim/{proto}_measured_ms", measured,
                     f"predicted={predicted:.1f} "
                     f"exact={'yes' if measured == predicted else 'NO'}"))
    return rows


def smoke() -> List[Row]:
    """CI smoke: one fast single-store comparison plus one replicated
    geo run; seconds, not minutes."""
    rows: List[Row] = []
    r = {p: _bench(p, AZURE_REDIS, n=4, horizon=200.0)
         for p in ("cornus", "2pc")}
    sp = _speedup(r)
    rows.append(("smoke/redis_speedup", sp,
                 f"cornus={r['cornus'].commits} 2pc={r['2pc'].commits} commits"))
    g = {p: _geo_bench(p, 3, horizon=1200.0) for p in ("cornus", "2pc")}
    gsp = _speedup(g)
    rows.append(("smoke/geo_r3_speedup", gsp,
                 f"cornus={g['cornus'].commits} 2pc={g['2pc'].commits} commits"))
    return rows


ALL = [fig5_scalability, fig5_separate_acl, fig6_readonly, fig7_contention,
       fig8_termination, fig9_elr, fig10_coordinator_log, table3_rtt,
       geo_replication_sweep, geo_failover, table3_sim_validation, smoke]
