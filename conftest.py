import os
import sys

import pytest

# Make `benchmarks` (and `repro` when PYTHONPATH is missing) importable
# regardless of how pytest is invoked.
ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (ROOT, os.path.join(ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def hypothesis_or_stubs():
    """(has_hypothesis, given, settings, st) — real hypothesis when
    installed, otherwise stand-ins that let strategy expressions parse at
    module scope and mark each @given test as skipped.  hypothesis is a
    dev-only dependency (requirements-dev.txt); test modules using it must
    still collect without it.  Usage:

        from conftest import hypothesis_or_stubs
        HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()
    """
    try:
        from hypothesis import given, settings, strategies as st
        return True, given, settings, st
    except ImportError:
        class _AnyStrategy:
            """Stands in for any strategy expression at module scope."""

            def __call__(self, *a, **k):
                return self

            def __getattr__(self, name):
                return self

        def given(*a, **k):
            return pytest.mark.skip(reason="hypothesis not installed")

        def settings(*a, **k):
            return lambda f: f

        return False, given, settings, _AnyStrategy()


def pytest_configure(config):
    # `slow` marks the long-running sim/train tests.  pytest.ini deselects
    # them by default (addopts = -m "not slow") so the tier-1 suite stays
    # fast; run everything with:  python -m pytest -m ""
    config.addinivalue_line(
        "markers",
        'slow: long-running sim/train test, deselected by default '
        '(override with -m "")')
