import os
import sys

# Make `benchmarks` (and `repro` when PYTHONPATH is missing) importable
# regardless of how pytest is invoked.
ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (ROOT, os.path.join(ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
