"""Storage-side group commit: amortization, semantic invisibility, and the
contention win.

The batching layer must be invisible to every registered protocol: with
window=0 (the default) it is an exact passthrough — validated here against
the analytic Table-3 RTT counts for all six rows — and with a window it may
only change *timing*, never outcomes, CAS winners, or liveness.
"""
from __future__ import annotations

import threading

import pytest

from repro.core import (AZURE_REDIS, BatchConfig, BatchingStore, Cluster,
                        Decision, FileStore, LatencyModel, MemoryStore,
                        ProtocolConfig, ReplicatedStore, Sim, SimStorage,
                        SIMULATED_RTT_ROWS, TxnSpec, Vote,
                        measured_caller_latency_ms,
                        predicted_caller_latency_ms)
from repro.txn import BenchConfig, YCSBWorkload, run_bench


# ---------------------------------------------------------------------------
# Amortization model (the deduped §5.6 batch-write cost)
# ---------------------------------------------------------------------------
def test_batched_write_ms_shared_amortization():
    m = AZURE_REDIS
    assert m.batched_write_ms(1) == m.plain_write_ms
    assert m.batched_write_ms(4) == pytest.approx(
        m.plain_write_ms * (1.0 + 3 * m.batch_size_factor))
    # Explicit base (a batch led by a conditional write) grows the same way.
    assert m.batched_write_ms(4, m.conditional_write_ms) == pytest.approx(
        m.conditional_write_ms * (1.0 + 3 * m.batch_size_factor))


def test_cl_log_batch_rides_shared_path():
    """The coordinator-log batched record goes through the same flush path
    as ingress group commit: one round trip whatever n_records is."""
    sim = Sim()
    st = SimStorage(sim, AZURE_REDIS, seed=0)
    ev = st.log_batch("n0", "t", Vote.COMMIT, n_records=5, writer="n0")
    sim.run()
    assert ev.value == Vote.COMMIT
    assert st.round_trips == 1
    assert st.requests == 1
    assert st.store.read_state("n0", "t") == Vote.COMMIT


# ---------------------------------------------------------------------------
# Window=0 passthrough: all six Table-3 rows stay EXACT
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("row", sorted(SIMULATED_RTT_ROWS))
def test_table3_exact_at_window0(row):
    measured = measured_caller_latency_ms(row, 20.0, batch_window_ms=0.0)
    assert measured == predicted_caller_latency_ms(row, 20.0)


@pytest.mark.parametrize("row", sorted(SIMULATED_RTT_ROWS))
def test_table3_rows_commit_when_batched(row):
    """With a window the rows still commit (semantic invisibility under
    replication + vote forwarding); each logged vote waits at most one
    window, so the batched latency is bounded by predicted + 2*window."""
    rtt, window = 20.0, 5.0
    measured = measured_caller_latency_ms(row, rtt, batch_window_ms=window)
    predicted = predicted_caller_latency_ms(row, rtt)
    assert predicted <= measured <= predicted + 2 * window


# ---------------------------------------------------------------------------
# Contention: batching strictly reduces storage round trips
# ---------------------------------------------------------------------------
def _hot_partition_wl(nodes, seed):
    return YCSBWorkload(nodes, accesses_per_txn=4, partition_theta=0.9,
                        keys_per_partition=10_000, seed=seed)


@pytest.mark.parametrize("replication", [1, 3])
def test_batching_reduces_round_trips_hot_partition(replication):
    res = {}
    for mode, kw in (("nobatch", dict(storage_serial=True, batch_max=1)),
                     ("batched", dict(storage_serial=True, batch_max=64))):
        cfg = BenchConfig(protocol="cornus", n_nodes=4, threads_per_node=8,
                          horizon_ms=300.0, replication=replication,
                          seed=3, **kw)
        res[mode] = run_bench(_hot_partition_wl, AZURE_REDIS, cfg)
    # Coalescing pays strictly fewer wire round trips...
    assert (res["batched"].storage_round_trips
            < res["nobatch"].storage_round_trips)
    # ...and converts them into committed-txn throughput (the acceptance
    # bar is 1.5x on the full bench; even this short run clears it).
    assert res["batched"].commits >= 1.5 * max(res["nobatch"].commits, 1)


def test_sim_batched_requests_exceed_round_trips():
    """Direct storage-level check: concurrent same-partition writes
    coalesce, and every caller still gets the true CAS result."""
    sim = Sim()
    st = SimStorage(sim, AZURE_REDIS, seed=1,
                    batch=BatchConfig(window_ms=2.0, serial=True))
    evs = [st.log_once("p", f"t{i}", Vote.VOTE_YES, writer=f"w{i}")
           for i in range(10)]
    sim.run()
    assert all(ev.value == Vote.VOTE_YES for ev in evs)
    assert st.requests == 10
    assert st.round_trips == 1          # one flush carried all ten slots
    assert st._ingress.max_batch_seen == 10


def test_sim_batched_cas_race_first_arrival_wins():
    """Two writers racing one slot inside a batch: arrival order decides,
    and BOTH callers observe the winner (log-once semantics)."""
    sim = Sim()
    st = SimStorage(sim, AZURE_REDIS, seed=1,
                    batch=BatchConfig(window_ms=2.0, serial=True))
    a = st.log_once("p", "t", Vote.VOTE_YES, writer="participant")
    b = st.log_once("p", "t", Vote.ABORT, writer="terminator")
    sim.run()
    assert a.value == Vote.VOTE_YES and b.value == Vote.VOTE_YES
    assert st.store.writer_of("p", "t") == "participant"


def test_cornus_batched_termination_race_consistent():
    """Everyone racing the termination protocol (tiny timeouts) through a
    batched store still converges on one decision."""
    for window in (0.0, 1.5):
        sim = Sim()
        storage = SimStorage(sim, AZURE_REDIS, seed=9,
                             batch=BatchConfig(window_ms=window,
                                               serial=window > 0))
        nodes = [f"n{i}" for i in range(4)]
        cfg = ProtocolConfig(protocol="cornus", vote_timeout_ms=0.5,
                             decision_timeout_ms=0.5)
        cl = Cluster(sim, storage, nodes, cfg)
        cl.run_txn(TxnSpec(txn_id="t", coordinator="n0", participants=nodes))
        sim.run(until=100_000)
        decisions = {st["decision"] for st in cl.local.values()
                     if st["decision"] is not None}
        assert len(decisions) == 1, f"window={window}: split {decisions}"


def test_batched_silent_participant_still_aborted():
    """Fig 4b through the batching layer: the termination CAS on behalf of
    a dead participant lands exactly as unbatched."""
    sim = Sim()
    storage = SimStorage(sim, AZURE_REDIS, seed=3,
                         batch=BatchConfig(window_ms=2.0, serial=True))
    nodes = ["n0", "n1", "n2"]
    cl = Cluster(sim, storage, nodes, ProtocolConfig(protocol="cornus"))
    cl.fail("n2", 0.05)
    done = cl.run_txn(TxnSpec(txn_id="t", coordinator="n0",
                              participants=nodes))
    sim.run(until=50_000)
    assert done.value.decision == Decision.ABORT
    assert storage.store.read_state("n2", "t") == Vote.ABORT
    assert storage.store.writer_of("n2", "t") in ("n0", "n1")


# ---------------------------------------------------------------------------
# Adaptive ("auto") formation window
# ---------------------------------------------------------------------------
def test_batch_config_auto_validation_and_bounds():
    cfg = BatchConfig(window_ms="auto", serial=True, max_window_ms=3.0)
    assert cfg.auto and cfg.active
    assert cfg.worst_case_window_ms == 3.0
    with pytest.raises(ValueError):
        BatchConfig(window_ms="sometimes")
    fixed = BatchConfig(window_ms=2.0)
    assert not fixed.auto and fixed.worst_case_window_ms == 2.0


def test_auto_window_idle_lane_never_delays():
    """A lone request on an idle lane must flush immediately — the same
    latency as piggyback window=0 (real log daemons only delay under
    concurrency)."""
    lat = {}
    for name, window in (("fixed0", 0.0), ("auto", "auto")):
        sim = Sim()
        st = SimStorage(sim, AZURE_REDIS, seed=2,
                        batch=BatchConfig(window_ms=window, serial=True))
        ev = st.log_once("p", "t", Vote.VOTE_YES, writer="w")
        sim.run()
        assert ev.value == Vote.VOTE_YES
        lat[name] = sim.now
    assert lat["auto"] == lat["fixed0"]


def test_auto_window_straggler_after_burst_not_delayed():
    """A lone request arriving AFTER a burst went idle must not inherit
    the burst's inter-arrival EWMA and wait out a formation window."""
    sim = Sim()
    st = SimStorage(sim, AZURE_REDIS, seed=2,
                    batch=BatchConfig(window_ms="auto", serial=True,
                                      max_window_ms=4.0))
    for i in range(20):                      # dense burst, iat ~0.3 ms
        def emit(i=i):
            def gen():
                yield sim.timeout(i * 0.3)
                yield st.log_once("p", f"t{i}", Vote.VOTE_YES,
                                  writer=f"w{i}")
            sim.process(gen())
        emit()
    sim.run()
    t_burst_end = sim.now
    lat = {}

    def straggler():
        yield sim.timeout(50.0)              # long idle gap
        t0 = sim.now
        yield st.log_once("p", "late", Vote.VOTE_YES, writer="w")
        lat["ms"] = sim.now - t0
    sim.process(straggler())
    sim.run()
    assert sim.now > t_burst_end
    # No formation delay: just the single flush's service time (well
    # under the 4 ms clamp + service it would pay with a stale EWMA).
    assert lat["ms"] < 4.0


def test_auto_window_batches_under_load():
    """A busy lane (tight arrivals) coalesces under "auto": strictly fewer
    round trips than requests, and every caller gets the true result."""
    sim = Sim()
    st = SimStorage(sim, AZURE_REDIS, seed=2,
                    batch=BatchConfig(window_ms="auto", serial=True,
                                      max_window_ms=4.0))
    evs = []

    def emit(i):
        def gen():
            yield sim.timeout(i * 0.3)      # inter-arrival << max window
            evs.append((yield st.log_once("p", f"t{i}", Vote.VOTE_YES,
                                          writer=f"w{i}")))
        sim.process(gen())

    for i in range(20):
        emit(i)
    sim.run()
    assert len(evs) == 20 and set(evs) == {Vote.VOTE_YES}
    assert st.round_trips < st.requests
    assert st._ingress.max_batch_seen >= 3


# ---------------------------------------------------------------------------
# Threaded BatchingStore decorator
# ---------------------------------------------------------------------------
def test_batching_store_concurrent_log_once_one_winner():
    inner = MemoryStore()
    st = BatchingStore(inner, window_s=0.01, max_batch=64)
    results = {}
    barrier = threading.Barrier(8)

    def worker(i):
        barrier.wait()
        results[i] = st.log_once("p", "t", Vote.VOTE_YES if i % 2 == 0
                                 else Vote.ABORT, writer=f"w{i}")

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # One winner, every caller observed it, and it IS the stored state.
    assert len(set(results.values())) == 1
    winner = results[0]
    assert inner.read_state("p", "t") == winner
    # Coalescing actually happened (8 ops, fewer leader round trips).
    assert st.batched_ops == 8
    assert st.round_trips < 8


def test_batching_store_sequential_matches_inner():
    st = BatchingStore(MemoryStore())
    assert st.log_once("p", "t1", Vote.VOTE_YES, "a") == Vote.VOTE_YES
    assert st.log_once("p", "t1", Vote.ABORT, "b") == Vote.VOTE_YES
    assert st.log("p", "t1", Vote.COMMIT, "a") == Vote.COMMIT
    assert st.log("p", "t1", Vote.VOTE_YES, "a") == Vote.COMMIT  # sticky
    assert st.read_state("p", "t1") == Vote.COMMIT               # delegated
    assert st.writer_of("p", "t1") == "a"


def test_batching_store_wraps_filestore(tmp_path):
    st = BatchingStore(FileStore(str(tmp_path)), window_s=0.005)
    assert st.log_once("p", "t", Vote.VOTE_YES, "w") == Vote.VOTE_YES
    assert st.log_once("p", "t", Vote.ABORT, "x") == Vote.VOTE_YES
    assert st.read_state("p", "t") == Vote.VOTE_YES


def test_batching_store_wraps_replicated_store_and_raises():
    from repro.core import QuorumUnavailable
    inner = ReplicatedStore(n_replicas=3)
    st = BatchingStore(inner, window_s=0.0)
    assert st.log_once("p", "t", Vote.VOTE_YES, "p") == Vote.VOTE_YES
    inner.fail_replica(0)
    inner.fail_replica(1)
    with pytest.raises(QuorumUnavailable):
        st.log_once("p", "t2", Vote.VOTE_YES, "p")  # error surfaces


def test_batching_store_leader_hands_off_under_sustained_load():
    """A batch leader serves ONE round then promotes a follower: no caller
    is trapped draining other threads' ops while arrivals keep pace."""
    inner = MemoryStore()
    st = BatchingStore(inner, window_s=0.002, max_batch=4)
    stop = threading.Event()
    n_done = [0]

    def producer(i):
        k = 0
        while not stop.is_set():
            st.log_once("p", f"t{i}.{k}", Vote.VOTE_YES, writer=f"w{i}")
            n_done[0] += 1
            k += 1

    threads = [threading.Thread(target=producer, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    import time as _time
    _time.sleep(0.25)
    stop.set()
    for t in threads:
        t.join(timeout=5.0)
    assert not any(t.is_alive() for t in threads), \
        "a caller was captured as perpetual batch leader"
    assert n_done[0] > 6                # everyone made progress
    assert st.round_trips < st.batched_ops or st.batched_ops <= 6


# ---------------------------------------------------------------------------
# Forwarding rows through the replicated batched fast path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("proto", ["cornus-opt1", "paxos-commit"])
def test_forwarding_protocols_commit_under_batched_contention(proto):
    cfg = BenchConfig(protocol=proto, n_nodes=4, threads_per_node=8,
                      horizon_ms=300.0, replication=3, seed=5,
                      storage_serial=True, batch_max=64)
    r = run_bench(_hot_partition_wl, AZURE_REDIS, cfg)
    assert r.commits > 100
    assert r.storage_round_trips < r.storage_requests


def test_batched_leader_forwards_coalesce_into_one_delivery():
    """cornus-opt1 under a batched leader: several concurrent txns' votes
    for ONE partition flush together, and their forwards — all bound for
    the same coordinator — leave as ONE deliver_many message
    (delivery_batches < deliveries)."""
    from repro.core import ReplicatedSimStorage

    sim = Sim()
    storage = ReplicatedSimStorage(
        sim, LatencyModel("null", conditional_write_ms=0.0,
                          plain_write_ms=0.0, read_ms=0.0, jitter=0.0),
        n_replicas=3, batch=BatchConfig(window_ms=5.0, serial=True))
    nodes = ["c", "p0", "p1"]
    cl = Cluster(sim, storage, nodes,
                 ProtocolConfig(protocol="cornus-opt1"))
    n_txns = 5
    dones = [cl.run_txn(TxnSpec(txn_id=f"t{i}", coordinator="c",
                                participants=["p0", "p1"]))
             for i in range(n_txns)]
    sim.run(until=10_000)
    assert all(d.value.decision == Decision.COMMIT for d in dones)
    tr = cl.transport
    assert tr.deliveries == 2 * n_txns  # one forwarded vote per participant
    assert tr.delivery_batches < tr.deliveries, \
        "forwards for one coordinator should coalesce via deliver_many"
    assert storage.forward_batches >= 1
    assert storage._ingress.max_batch_seen >= 2
