"""Replicated-storage quorum LogOnce (extended paper §6).

The store must behave like a single CAS register: under concurrent writers,
minority replica failures, and any interleaving of replica fail/recover
schedules, every caller of log_once observes the SAME first value (Paxos
Commit's "first value accepted by a majority wins").
"""
import threading

import pytest

from repro.core import (AZURE_REDIS, CROSS_REGION, CROSS_ZONE, INTRA_ZONE,
                        Cluster, Decision, ProtocolConfig, QuorumUnavailable,
                        RegionTopology, ReplicatedSimStorage, ReplicatedStore,
                        Sim, TxnSpec, Vote, measured_caller_latency_ms,
                        predicted_caller_latency_ms)
from repro.txn import BenchConfig, GeoYCSBWorkload, run_bench


# ---------------------------------------------------------------------------
# Threaded ReplicatedStore
# ---------------------------------------------------------------------------
def test_log_once_decided_exactly_once_under_concurrent_writers():
    """Owner's VOTE-YES races a terminator's ABORT; both must return the
    same winner, and reads must agree, on every trial."""
    for trial in range(60):
        store = ReplicatedStore(n_replicas=3, seed=trial)
        results = {}

        def owner():
            results["o"] = store.log_once("p1", "t", Vote.VOTE_YES,
                                          writer="p1")

        def terminator():
            results["t"] = store.log_once("p1", "t", Vote.ABORT, writer="p2")

        threads = [threading.Thread(target=owner),
                   threading.Thread(target=terminator)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert results["o"] == results["t"], (trial, results)
        assert store.read_state("p1", "t") == results["o"]


def test_log_once_under_minority_replica_failure():
    store = ReplicatedStore(n_replicas=3)
    store.fail_replica(2)
    assert store.log_once("p", "t1", Vote.VOTE_YES, writer="p") \
        == Vote.VOTE_YES
    # Second writer loses the CAS even though a replica is down.
    assert store.log_once("p", "t1", Vote.ABORT, writer="q") == Vote.VOTE_YES
    assert store.cas_losses == 1


def test_recovered_replica_is_read_repaired():
    store = ReplicatedStore(n_replicas=3)
    store.fail_replica(2)
    store.log_once("p", "t1", Vote.VOTE_YES, writer="p")
    store.log("p", "t1", Vote.COMMIT, writer="p")
    store.recover_replica(2)
    assert store.replicas[2].read(("p", "t1"))[0] is None  # stale disk
    assert store.read_state("p", "t1") == Vote.COMMIT
    # The read pushed the merged record into the recovered replica.
    assert store.replicas[2].read(("p", "t1"))[0] == Vote.COMMIT


def test_majority_down_is_unavailable_not_wrong():
    store = ReplicatedStore(n_replicas=3)
    store.fail_replica(0)
    store.fail_replica(1)
    with pytest.raises(QuorumUnavailable):
        store.log_once("p", "t", Vote.VOTE_YES, writer="p")
    with pytest.raises(QuorumUnavailable):
        store.read_state("p", "t")


def test_log_decision_is_sticky():
    store = ReplicatedStore(n_replicas=3)
    store.log("p", "t", Vote.COMMIT, writer="p")
    assert store.log("p", "t", Vote.VOTE_YES, writer="p") == Vote.COMMIT
    assert store.read_state("p", "t") == Vote.COMMIT


def test_many_concurrent_slots_and_writers():
    """8 writers x 16 slots, each slot raced by two values."""
    store = ReplicatedStore(n_replicas=5, seed=3)
    results = [dict() for _ in range(8)]

    def worker(w):
        for s in range(16):
            v = Vote.VOTE_YES if w % 2 == 0 else Vote.ABORT
            results[w][s] = store.log_once("p", f"t{s}", v, writer=f"w{w}")

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for s in range(16):
        winners = {results[w][s] for w in range(8)}
        assert len(winners) == 1, (s, winners)


# ---------------------------------------------------------------------------
# RegionTopology
# ---------------------------------------------------------------------------
def test_region_topology_lookup_and_presets():
    assert INTRA_ZONE.rtt_ms("zone-a", "zone-a") == INTRA_ZONE.intra_ms
    assert CROSS_ZONE.rtt_ms("zone-a", "zone-b") == 2.0
    # Symmetric regardless of argument order.
    assert CROSS_REGION.rtt_ms("us-east", "eu-west") \
        == CROSS_REGION.rtt_ms("eu-west", "us-east") == 76.0
    assert CROSS_REGION.max_rtt_ms == 140.0
    uni = RegionTopology.uniform("u", ("a", "b"), 7.0)
    assert uni.rtt_ms("a", "a") == uni.rtt_ms("a", "b") == 7.0
    pl = CROSS_REGION.place_round_robin(["n0", "n1", "n2", "n3"])
    assert pl["n0"] == "us-east" and pl["n3"] == "us-east"


# ---------------------------------------------------------------------------
# Simulated quorum store: deterministic interleaving sweep
# ---------------------------------------------------------------------------
def _race_one(seed, mode, n_replicas, fails, delays):
    """Three proposers race on one slot under a replica outage schedule;
    returns the dict of returned values (must be a singleton set)."""
    sim = Sim()
    storage = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=n_replicas,
                                   seed=seed, mode=mode)
    for idx, at, rec in fails:
        if idx < n_replicas:
            storage.fail_replica(idx, at, rec)
    results = {}

    def proposer(name, value, delay):
        def gen():
            yield sim.timeout(delay)
            got = yield storage.log_once("p0", "t", value, writer=name)
            results[name] = got
        sim.process(gen())

    proposer("p0", Vote.VOTE_YES, delays[0])   # slot owner
    proposer("q1", Vote.ABORT, delays[1])      # termination peer
    proposer("q2", Vote.ABORT, delays[2])      # second terminator
    sim.run(until=200_000.0)
    return results


@pytest.mark.parametrize("mode", ["leader", "coloc"])
def test_sim_quorum_race_single_decision_sweep(mode):
    """Deterministic sweep over seeds, outage schedules, and proposer
    offsets: no interleaving yields divergent decisions."""
    schedules = [
        (),
        (((0, 0.0, float("inf"))),),
        ((1, 2.0, 30.0),),
        ((0, 0.0, 25.0), (2, 10.0, 60.0)),
    ]
    # normalize: first entry above is a 3-tuple, keep consistent
    schedules[1] = ((0, 0.0, float("inf")),)
    for seed in range(10):
        for fails in schedules:
            res = _race_one(seed, mode, 3, fails,
                            delays=(0.0, seed % 5, (seed * 3) % 7))
            assert len(res) == 3, (seed, fails, res)
            assert len(set(res.values())) == 1, (seed, fails, res)


def test_sim_recovered_replica_catches_up():
    sim = Sim()
    storage = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3, seed=1)
    storage.fail_replica(2, at=0.0, recover_at=500.0)
    done = {}

    def gen():
        got = yield storage.log_once("p", "t", Vote.VOTE_YES, writer="p")
        done["v"] = got
    sim.process(gen())
    sim.run(until=400.0)
    assert done["v"] == Vote.VOTE_YES
    assert storage.replicas[2].read(("p", "t"))[0] is None
    sim.run(until=1000.0)

    def rd():
        done["r"] = yield storage.read_state("p", "t")
    sim.process(rd())
    sim.run(until=2000.0)
    assert done["r"] == Vote.VOTE_YES
    sim.run(until=3000.0)   # let the async repair push land
    assert storage.replicas[2].read(("p", "t"))[0] == Vote.VOTE_YES


# ---------------------------------------------------------------------------
# Hypothesis: no interleaving of replica failures yields divergent decisions
# (skipped, but still collected, when hypothesis is not installed)
# ---------------------------------------------------------------------------
from conftest import hypothesis_or_stubs  # noqa: E402

HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()

outage = st.tuples(st.integers(0, 4), st.floats(0.0, 50.0),
                   st.floats(50.0, 500.0))


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 10_000),
       mode=st.sampled_from(["leader", "coloc"]),
       n_replicas=st.sampled_from([3, 5]),
       fails=st.lists(outage, max_size=3),
       delays=st.tuples(st.floats(0.0, 20.0), st.floats(0.0, 20.0),
                        st.floats(0.0, 20.0)))
def test_no_failure_interleaving_diverges(seed, mode, n_replicas, fails,
                                          delays):
    """Every proposer sees the same decided value, and the merged on-disk
    state agrees with it, under randomized outage schedules (all outages
    recover, so quorum is eventually available)."""
    sim = Sim()
    storage = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=n_replicas,
                                   seed=seed, mode=mode)
    for idx, at, rec in fails:
        if idx < n_replicas:
            storage.fail_replica(idx, at, rec)
    results = {}

    def proposer(name, value, delay):
        def gen():
            yield sim.timeout(delay)
            got = yield storage.log_once("p0", "t", value, writer=name)
            results[name] = got
        sim.process(gen())

    proposer("p0", Vote.VOTE_YES, delays[0])
    proposer("q1", Vote.ABORT, delays[1])
    proposer("q2", Vote.ABORT, delays[2])
    sim.run(until=500_000.0)
    assert len(results) == 3, results
    assert len(set(results.values())) == 1, results
    decided = next(iter(results.values()))
    assert storage.snapshot().get(("p0", "t")) == decided


# ---------------------------------------------------------------------------
# Protocol integration over the replicated store
# ---------------------------------------------------------------------------
def _geo_run(proto, fail=()):
    placement = {"n0": "us-east", "n1": "us-west", "n2": "eu-west",
                 "n3": "us-west"}

    def wl(nodes, seed):
        return GeoYCSBWorkload(nodes, placement, "us-east",
                               accesses_per_txn=4, seed=seed)

    cfg = BenchConfig(protocol=proto, n_nodes=4, horizon_ms=1500.0,
                      replication=3, topology=CROSS_REGION,
                      placement=placement,
                      replica_regions=["us-east", "us-west", "eu-west"],
                      replica_failures=fail, coordinator_nodes=["n0"],
                      seed=7)
    return run_bench(wl, AZURE_REDIS, cfg)


def test_geo_ycsb_r3_with_replica_failure_cornus_beats_2pc():
    """Acceptance: Cornus and 2PC both complete geo-YCSB against the R=3
    quorum store with the coordinator-region replica down, and Cornus's
    caller latency stays ahead (no decision-log quorum round)."""
    res = {p: _geo_run(p, fail=((0, 0.0),)) for p in ("cornus", "2pc")}
    for p, r in res.items():
        assert r.commits > 0 and r.gaveups == 0, (p, r.commits, r.gaveups)
    assert res["cornus"].avg_latency_ms < res["2pc"].avg_latency_ms, \
        {p: r.avg_latency_ms for p, r in res.items()}


def test_cornus_termination_bounded_over_replicated_store():
    """Coordinator dies before sending the decision: every surviving
    participant resolves through the quorum-CAS termination protocol in
    bounded time, they all agree, and the merged replica state matches —
    Cornus stays non-blocking on replicated storage."""
    sim = Sim()
    topo = CROSS_ZONE
    nodes = [f"n{i}" for i in range(4)]
    placement = topo.place_round_robin(nodes)
    storage = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3, seed=3,
                                   topology=topo, placement=placement,
                                   mode="leader")
    cfg = ProtocolConfig(protocol="cornus", topology=topo,
                         placement=placement,
                         vote_timeout_ms=60.0, decision_timeout_ms=60.0,
                         votereq_timeout_ms=60.0, termination_retry_ms=60.0)
    cl = Cluster(sim, storage, nodes, cfg)
    cl.fail("n0", 1.0)
    cl.run_txn(TxnSpec(txn_id="t", coordinator="n0", participants=nodes))
    sim.run(until=60_000.0)
    survivors = [o for (t, n), o in cl.outcomes.items() if n != "n0"]
    assert len(survivors) == 3, "a participant blocked"
    decisions = {o.decision for o in survivors}
    assert len(decisions) == 1 and Decision.UNDETERMINED not in decisions
    for o in survivors:
        assert o.ran_termination
        assert o.termination_ms < 1_000.0   # bounded, no blocking
    # Merged replica state carries the same outcome for every partition
    # that logged a decision record.
    snap = storage.snapshot()
    decided = next(iter(decisions))
    want = Vote.COMMIT if decided == Decision.COMMIT else Vote.ABORT
    logged = [v for (p, t), v in snap.items() if v.is_decision()]
    assert logged and all(v == want for v in logged), snap


def test_table3_measured_matches_predicted():
    """The replicated simulator reproduces the analytic Table-3 RTT counts
    EXACTLY (zero service times, uniform topology) for all six rows —
    including the forwarding rows cornus-opt1 (Paxos leader forwards the
    vote, 2.5 RTT) and paxos-commit (acceptors forward, 1.5 RTT)."""
    from repro.core import SIMULATED_RTT_ROWS
    assert set(SIMULATED_RTT_ROWS) == {"2pc", "cornus", "cornus-opt1",
                                       "2pc-coloc", "cornus-coloc",
                                       "paxos-commit"}
    for proto in SIMULATED_RTT_ROWS:
        measured = measured_caller_latency_ms(proto, 20.0)
        predicted = predicted_caller_latency_ms(proto, 20.0)
        assert measured == predicted, (proto, measured, predicted)
