"""Crash–restart replay: a crashed node comes back with its durable log
intact, runs the registered protocol's recovery (Table 1/2 "During
Recovery") against live traffic, and the history checker certifies the
result.

Also pins the zombie-round fence: protocol rounds started before a crash
must stop acting after the restart (crash–restart incarnation epochs) —
without the fence, a pre-crash participant round parked on a decision wait
resumes after the restart and presumed-abort-logs ABORT over the decision
recovery already reached (an AC3 violation the chaos sweep caught).
"""
import pytest

from repro.core import (AZURE_REDIS, Cluster, Decision, FaultSchedule,
                        ProtocolConfig, Sim, SimStorage, TxnSpec, Vote,
                        get_protocol)
from repro.core.history import HistoryRecorder, check_run
from repro.txn import BenchConfig, YCSBWorkload, run_bench

ALL_PROTOCOLS = ["cornus", "2pc", "cl", "cornus-opt1", "paxos-commit"]


def _cluster(proto, n, seed=0):
    sim = Sim()
    storage = SimStorage(sim, AZURE_REDIS, seed=seed)
    storage.history = HistoryRecorder(sim)
    nodes = [f"n{i}" for i in range(n)]
    return sim, storage, Cluster(sim, storage, nodes,
                                 ProtocolConfig(protocol=proto)), nodes


def _decisions(cluster, txn="t"):
    return {node: st["decision"]
            for (node, t), st in cluster.local.items()
            if t == txn and st["decision"] is not None}


def _certify(cluster, storage, proto):
    violations = check_run(cluster.ctx, storage=storage,
                           participant_logs=get_protocol(
                               proto).participant_logs)
    assert violations == [], (proto, violations)


# ---------------------------------------------------------------------------
# Durable-log replay through the automatic restart path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("proto", ALL_PROTOCOLS)
def test_coordinator_crash_restart_replays_durable_log(proto):
    """The coordinator crashes mid-protocol and RESTARTS (no manual
    recover_txn): the restart scans its unresolved txns, runs recovery off
    the durable log, and every node converges on one certified decision."""
    sim, storage, cluster, nodes = _cluster(proto, 4, seed=11)
    spec = TxnSpec(txn_id="t", coordinator="n0", participants=nodes)
    cluster.schedule_crash_restart("n0", at=1.0, restart_at=5_000.0)
    cluster.run_txn(spec)
    sim.run(until=500_000.0)

    assert cluster.crash_restarts == 1
    assert cluster.recoveries_run >= 1, proto
    rec = cluster.outcomes[("t", "n0:recovery")]
    assert rec.decision != Decision.UNDETERMINED, proto
    decisions = _decisions(cluster)
    assert set(decisions) == set(nodes), (proto, decisions)
    assert set(decisions.values()) == {rec.decision}, (proto, decisions)
    _certify(cluster, storage, proto)


@pytest.mark.parametrize("proto", ALL_PROTOCOLS)
def test_participant_crash_restart_replays_durable_log(proto):
    """A participant crashes and restarts: its recovery must land on the
    SAME decision the survivors reached, with the vote it logged before
    the crash still in the durable slot (protocols that log votes)."""
    sim, storage, cluster, nodes = _cluster(proto, 3, seed=5)
    spec = TxnSpec(txn_id="t", coordinator="n0", participants=nodes)
    cluster.schedule_crash_restart("n2", at=2.5, restart_at=2_000.0)
    cluster.run_txn(spec)
    sim.run(until=100_000.0)

    assert cluster.crash_restarts == 1
    decisions = _decisions(cluster)
    assert "n0" in decisions and "n1" in decisions, (proto, decisions)
    assert len(set(decisions.values())) == 1, (proto, decisions)
    want = next(iter(decisions.values()))
    # Resolved either by recovery or (if it decided pre-crash) locally.
    rec = cluster.outcomes.get(("t", "n2:recovery"))
    got = rec.decision if rec is not None else decisions.get("n2")
    assert got == want, (proto, got, want)
    if get_protocol(proto).participant_logs and rec is not None:
        # Recovery re-logged the decision durably in n2's own slot.
        state = storage.store.read_state("n2", "t")
        assert state == (Vote.COMMIT if want == Decision.COMMIT
                         else Vote.ABORT), (proto, state)
    _certify(cluster, storage, proto)


@pytest.mark.parametrize("proto", ALL_PROTOCOLS)
def test_restart_during_own_termination_round(proto):
    """A participant restarts while INSIDE its own termination round (the
    coordinator is down, so the 2PC family's cooperative termination is
    guaranteed still blocked at crash time).  The pre-crash round is fenced
    by the incarnation bump; recovery — not the zombie — resolves, and once
    the coordinator itself restarts everyone converges."""
    sim, storage, cluster, nodes = _cluster(proto, 4, seed=3)
    spec = TxnSpec(txn_id="t", coordinator="n0", participants=nodes)
    # Coordinator out for a long window; participants time out at ~25 ms
    # and enter termination, where n1 crashes and later restarts.
    cluster.schedule_crash_restart("n0", at=1.0, restart_at=800.0)
    cluster.schedule_crash_restart("n1", at=40.0, restart_at=90.0)
    cluster.run_txn(spec)
    sim.run(until=500_000.0)

    assert cluster.crash_restarts == 2
    assert cluster.recoveries_run >= 1, proto
    decisions = _decisions(cluster)
    assert set(decisions) == set(nodes), (proto, decisions)
    assert len(set(decisions.values())) == 1, (proto, decisions)
    _certify(cluster, storage, proto)


# ---------------------------------------------------------------------------
# Zombie-round fence (incarnation epochs)
# ---------------------------------------------------------------------------
def test_incarnation_epochs_fence_zombie_rounds():
    """A round captures its epoch at entry; after the node's crash–restart
    the OLD epoch is fenced forever even though alive() is true again."""
    sim, storage, cluster, nodes = _cluster("cornus", 3)
    proto = cluster.protocol
    ep = proto.epoch("n1")
    assert proto.live("n1", ep)
    cluster.schedule_crash_restart("n1", at=5.0, restart_at=20.0)
    sim.run(until=10.0)
    assert not cluster.alive("n1") and not proto.live("n1", ep)
    sim.run(until=30.0)
    assert cluster.alive("n1")          # restarted...
    assert not proto.live("n1", ep)     # ...but the old incarnation is dead
    assert proto.live("n1", proto.epoch("n1"))
    assert proto.epoch("n1") == ep + 1


def test_repeated_restarts_bump_epoch_each_time():
    sim, storage, cluster, nodes = _cluster("2pc", 3)
    cluster.schedule_crash_restart("n2", at=5.0, restart_at=10.0)
    cluster.schedule_crash_restart("n2", at=20.0, restart_at=25.0)
    sim.run(until=50.0)
    assert cluster.transport.incarnation("n2") == 2
    assert cluster.crash_restarts == 2


# ---------------------------------------------------------------------------
# Certified under live traffic (bench-level regression of the AC3 zombie
# bug and the recoverability path), incl. inside a reconfiguration window
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("proto", ["cornus", "2pc"])
def test_crash_mix_traffic_is_certified(proto):
    """Seeded crash-heavy chaos under closed-loop traffic: zero checker
    violations and at least one restart actually exercised."""
    nodes = [f"n{i}" for i in range(4)]
    sched = FaultSchedule.generate(9, nodes, 250.0, 0, "crash")
    cfg = BenchConfig(protocol=proto, n_nodes=4, threads_per_node=2,
                      horizon_ms=250.0, seed=9, retry_fresh_ids=True,
                      chaos=sched, record_history=True)
    res = run_bench(lambda n, seed: YCSBWorkload(n, seed=seed),
                    AZURE_REDIS, cfg)
    assert res.violations == 0, res.violation_details
    assert res.crash_restarts >= 1
    assert res.commits > 0


def test_restart_inside_reconfiguration_window_is_certified():
    """Coordinator node crash–restarts while the replicated store is
    mid-reconfiguration (R 3 → 5): recovery runs against the changing
    quorum and the history still certifies clean."""
    cfg = BenchConfig(protocol="cornus", n_nodes=4, threads_per_node=2,
                      horizon_ms=250.0, seed=3, replication=3,
                      retry_fresh_ids=True, record_history=True,
                      reconfigurations=((80.0, 5),),
                      crash_restarts=(("n0", 60.0, 140.0),))
    res = run_bench(lambda n, seed: YCSBWorkload(n, seed=seed),
                    AZURE_REDIS, cfg)
    assert res.violations == 0, res.violation_details
    assert res.crash_restarts == 1
    assert res.commits > 0
