"""Per-kernel allclose tests: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes and dtypes (deliverable c).

Skips as a whole — cleanly, at collection — when jax (and with it Pallas)
is not importable: the serving/commit layers run jax-free, and this suite
must not fail a jax-less environment."""
import math

import numpy as np
import pytest

jax = pytest.importorskip("jax", reason="kernel tests need jax/pallas")
pytest.importorskip("jax.experimental.pallas",
                    reason="kernel tests need jax/pallas")
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.mlstm_scan import mlstm_scan


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.key(key), shape).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
ATTN_SWEEP = [
    # (B, Hq, Hkv, Sq, Skv, hd, causal, window, softcap)
    (1, 2, 2, 64, 64, 32, True, 0, 0.0),      # MHA causal
    (2, 4, 2, 128, 128, 16, True, 0, 0.0),    # GQA
    (1, 2, 1, 96, 96, 32, True, 0, 0.0),      # ragged seq vs block
    (1, 2, 2, 64, 64, 32, True, 32, 0.0),     # sliding window
    (1, 2, 2, 64, 64, 32, True, 0, 50.0),     # softcap (gemma)
    (1, 2, 2, 64, 64, 32, False, 0, 0.0),     # non-causal
    (1, 8, 4, 160, 224, 64, True, 64, 30.0),  # everything at once, ragged
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", ATTN_SWEEP)
def test_flash_attention_matches_ref(case, dtype):
    B, Hq, Hkv, Sq, Skv, hd, causal, window, cap = case
    q = rand(1, (B, Hq, Sq, hd), dtype)
    k = rand(2, (B, Hkv, Skv, hd), dtype)
    v = rand(3, (B, Hkv, Skv, hd), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=32, block_kv=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=cap)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_attention_q_offset_matches_ref():
    q = rand(4, (1, 2, 16, 32), jnp.float32)
    k = rand(5, (1, 2, 64, 32), jnp.float32)
    v = rand(6, (1, 2, 64, 32), jnp.float32)
    got = flash_attention(q, k, v, causal=True, q_offset=48,
                          block_q=16, block_kv=16, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True, q_offset=48)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash_decode
# ---------------------------------------------------------------------------
DECODE_SWEEP = [
    # (B, Hq, Hkv, T, hd, kv_len, softcap)
    (1, 2, 2, 128, 32, 100, 0.0),
    (2, 8, 2, 256, 64, 256, 0.0),
    (1, 4, 1, 96, 32, 17, 0.0),      # ragged cache vs block
    (3, 4, 4, 512, 16, 333, 0.0),
    (1, 2, 2, 128, 32, 100, 50.0),   # softcap (gemma decode)
    (2, 8, 1, 192, 32, 130, 30.0),   # softcap + deep GQA group, ragged
    (1, 16, 2, 256, 64, 256, 0.0),   # wide GQA group in the q tile
    (4, 4, 2, 64, 128, 50, 20.0),    # big head dim, everything on
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", DECODE_SWEEP)
def test_flash_decode_matches_ref(case, dtype):
    B, Hq, Hkv, T, hd, kv_len, cap = case
    q = rand(7, (B, Hq, 1, hd), dtype)
    k = rand(8, (B, Hkv, T, hd), dtype)
    v = rand(9, (B, Hkv, T, hd), dtype)
    got = flash_decode(q, k, v, jnp.int32(kv_len), softcap=cap,
                       block_kv=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False, softcap=cap,
                             kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------
MAMBA_SWEEP = [
    # (B, S, di, N, chunk)
    (1, 32, 64, 8, 8),
    (2, 100, 128, 16, 16),     # ragged seq vs chunk
    (1, 64, 256, 4, 64),       # single chunk
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", MAMBA_SWEEP)
def test_mamba_scan_matches_ref(case, dtype):
    B, S, di, N, chunk = case
    u = rand(10, (B, S, di), dtype)
    dt = jax.nn.softplus(rand(11, (B, S, di), jnp.float32)).astype(dtype)
    a = -jnp.exp(rand(12, (di, N), jnp.float32) * 0.5)
    b = rand(13, (B, S, N), dtype)
    c = rand(14, (B, S, N), dtype)
    h0 = jnp.zeros((B, di, N), jnp.float32)
    y, h = mamba_scan(u, dt, a, b, c, h0, chunk=chunk, di_block=di,
                      interpret=True)
    y_ref, h_ref = ref.mamba_scan_ref(u, dt, a, b, c, h0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=5e-3, atol=5e-3)


def test_mamba_scan_carries_state_across_calls():
    B, S, di, N = 1, 48, 32, 8
    u = rand(20, (B, S, di), jnp.float32)
    dt = jax.nn.softplus(rand(21, (B, S, di), jnp.float32))
    a = -jnp.exp(rand(22, (di, N), jnp.float32) * 0.5)
    b = rand(23, (B, S, N), jnp.float32)
    c = rand(24, (B, S, N), jnp.float32)
    h0 = jnp.zeros((B, di, N), jnp.float32)
    y_full, h_full = mamba_scan(u, dt, a, b, c, h0, chunk=16, di_block=di,
                                interpret=True)
    y1, h1 = mamba_scan(u[:, :24], dt[:, :24], a, b[:, :24], c[:, :24], h0,
                        chunk=16, di_block=di, interpret=True)
    y2, h2 = mamba_scan(u[:, 24:], dt[:, 24:], a, b[:, 24:], c[:, 24:], h1,
                        chunk=16, di_block=di, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# mLSTM chunkwise
# ---------------------------------------------------------------------------
MLSTM_SWEEP = [
    # (B, S, H, hd, chunk)
    (1, 32, 2, 16, 8),
    (2, 80, 4, 32, 16),        # ragged seq vs chunk
    (1, 64, 1, 64, 64),        # single chunk
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", MLSTM_SWEEP)
def test_mlstm_matches_sequential_ref(case, dtype):
    B, S, H, hd, chunk = case
    q = rand(30, (B, S, H, hd), dtype)
    k = rand(31, (B, S, H, hd), dtype)
    v = rand(32, (B, S, H, hd), dtype)
    i_gate = jax.nn.sigmoid(rand(33, (B, S, H), jnp.float32))
    f_gate = jax.nn.sigmoid(rand(34, (B, S, H), jnp.float32) + 2.0)
    c0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    y, c_last = mlstm_scan(q, k, v, i_gate.astype(dtype),
                           f_gate.astype(dtype), c0, chunk=chunk,
                           interpret=True)
    y_ref, c_ref, _ = ref.mlstm_ref(q, k, v, i_gate, f_gate, c0,
                                    jnp.zeros((B, H, hd), jnp.float32))
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **TOL[dtype])
    np.testing.assert_allclose(np.asarray(c_last), np.asarray(c_ref),
                               rtol=2e-2, atol=2e-2)
