"""Transactional-serving tests: sessions, admission control, engine.

Deterministic twins carry the coverage (hypothesis is a dev-only
dependency); the @given properties re-check the batching-invisibility
contract under random request sets when hypothesis is installed.
"""
from __future__ import annotations

import threading
import time

import pytest

from conftest import hypothesis_or_stubs

HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()

from repro.core.state import Vote
from repro.serve import (AdmissionConfig, ContinuousBatcher, EngineConfig,
                         SessionConfig, SessionManager, StepRequest,
                         StubDecode, build_session_store, run_serve)


# ---------------------------------------------------------------------------
# Sessions as transactions: per-protocol storage choreography
# ---------------------------------------------------------------------------
def _manager(protocol: str, **kw) -> SessionManager:
    cfg = SessionConfig(protocol=protocol, backend="memory",
                        participants_per_txn=3, kv_partitions=4, **kw)
    return SessionManager(build_session_store(cfg), cfg)


@pytest.mark.parametrize("protocol", ["cornus", "2pc", "cl"])
def test_session_lifecycle_commits(protocol):
    mgr = _manager(protocol)
    s = mgr.open_session("client")
    assert s.open
    for _ in range(3):
        out = mgr.step(s)
        assert out.committed
    assert mgr.close_session(s)
    assert s.kv_len == 3
    assert (mgr.opens, mgr.steps_committed, mgr.closes) == (1, 3, 1)


def test_cornus_step_leaves_only_votes():
    """Cornus: commit == the collective vote state; no decision record."""
    mgr = _manager("cornus")
    s = mgr.open_session("c")
    mgr.step(s)
    txn = s.step_txn(0)
    for p in s.partitions:
        assert mgr.store.read_state(p, txn) == Vote.VOTE_YES


def test_2pc_step_forces_decision_record():
    """2PC: the eager COMMIT record lands on the coordinator partition —
    the extra forced write cornus removes."""
    mgr = _manager("2pc")
    s = mgr.open_session("c")
    mgr.step(s)
    txn = s.step_txn(0)
    assert mgr.store.read_state(s.coordinator, txn) == Vote.COMMIT
    for p in s.partitions[1:]:
        assert mgr.store.read_state(p, txn) == Vote.VOTE_YES


def test_cl_step_logs_only_coordinator():
    mgr = _manager("cl")
    s = mgr.open_session("c")
    mgr.step(s)
    txn = s.step_txn(0)
    assert mgr.store.read_state(s.coordinator, txn) == Vote.COMMIT
    for p in s.partitions[1:]:
        assert mgr.store.read_state(p, txn) is None


def test_terminate_step_aborts_parked_step():
    """A step parked mid-vote is CAS-terminated by a scavenger and comes
    back ABORTED — never hangs (the paper's non-blocking property)."""
    mgr = _manager("cornus")
    s = mgr.open_session("c")
    txn = s.step_txn(s.steps)
    parts = list(s.partitions)

    def park(i: int, _p: str) -> None:
        if i == len(parts) - 1:     # stall before the LAST vote
            t = threading.Thread(target=mgr.terminate_step,
                                 args=(s.sid, txn, parts), daemon=True)
            t.start()
            t.join()                # scavenger fully done while we "hang"

    out = mgr.step(s, before_vote=park)
    assert not out.committed
    assert mgr.store.read_state(parts[-1], txn) == Vote.ABORT
    assert mgr.terminations == 1
    assert mgr.steps_aborted == 1
    assert s.kv_len == 0            # the aborted step appended nothing
    # Serving continues: the next step commits normally.
    assert mgr.step(s).committed


def test_terminate_step_after_full_commit_is_noop():
    mgr = _manager("cornus")
    s = mgr.open_session("c")
    out = mgr.step(s)
    assert out.committed
    landed = mgr.terminate_step(s.sid, s.step_txn(0), s.partitions)
    assert not landed               # every slot already held VOTE_YES


def test_build_session_store_rejects_sim_backends():
    with pytest.raises(ValueError, match="simulated"):
        build_session_store(SessionConfig(backend="sim"))


# ---------------------------------------------------------------------------
# Admission control: deadlines, backpressure, shutdown
# ---------------------------------------------------------------------------
class _GatedDecode:
    """Decode that announces entry and blocks until released — makes the
    backpressure tests deterministic."""

    def __init__(self) -> None:
        self.started = threading.Event()
        self.gate = threading.Event()
        self.calls = 0

    def __call__(self, reqs):
        self.calls += 1
        self.started.set()
        assert self.gate.wait(timeout=10.0)
        return [0] * len(reqs)


def test_deadline_expired_request_is_dropped_before_decode():
    decode = StubDecode(base_ms=0.1)
    b = ContinuousBatcher(decode, AdmissionConfig(max_batch=4,
                                                  window_ms=0.0)).start()
    try:
        req = StepRequest("s", 0, deadline_at=time.monotonic() - 1.0)
        assert b.submit(req)
        assert req.done.wait(timeout=5.0)
        assert req.dropped and req.result is None
        assert b.dropped == 1 and b.decoded == 0 and b.batches == 0
    finally:
        b.stop()


def test_backpressure_reject_sheds_when_queue_full():
    decode = _GatedDecode()
    b = ContinuousBatcher(decode, AdmissionConfig(
        max_batch=1, window_ms=0.0, queue_depth=1,
        backpressure="reject")).start()
    try:
        r1 = StepRequest("s", 0)
        assert b.submit(r1)
        assert decode.started.wait(timeout=5.0)   # worker busy on r1
        r2 = StepRequest("s", 1)
        assert b.submit(r2)                       # fills the queue
        r3 = StepRequest("s", 2)
        assert not b.submit(r3)                   # shed, immediately
        assert b.rejected == 1
        decode.gate.set()
        assert r1.done.wait(timeout=5.0)
        assert r2.done.wait(timeout=5.0)
        assert not r1.dropped and not r2.dropped
    finally:
        decode.gate.set()
        b.stop()


def test_backpressure_block_waits_for_capacity():
    decode = _GatedDecode()
    b = ContinuousBatcher(decode, AdmissionConfig(
        max_batch=1, window_ms=0.0, queue_depth=1,
        backpressure="block")).start()
    try:
        assert b.submit(StepRequest("s", 0))
        assert decode.started.wait(timeout=5.0)
        assert b.submit(StepRequest("s", 1))      # queue now full
        r3 = StepRequest("s", 2)
        got = []
        t = threading.Thread(target=lambda: got.append(b.submit(r3)),
                             daemon=True)
        t.start()
        t.join(timeout=0.15)
        assert t.is_alive()                       # blocked, not shed
        decode.gate.set()                         # drain; capacity frees
        t.join(timeout=5.0)
        assert not t.is_alive() and got == [True]
        assert r3.done.wait(timeout=5.0)
        assert b.rejected == 0
    finally:
        decode.gate.set()
        b.stop()


def test_stop_fails_queued_requests_instead_of_hanging():
    decode = _GatedDecode()
    b = ContinuousBatcher(decode, AdmissionConfig(
        max_batch=1, window_ms=0.0, queue_depth=8)).start()
    assert b.submit(StepRequest("s", 0))
    assert decode.started.wait(timeout=5.0)
    queued = StepRequest("s", 1)
    assert b.submit(queued)
    decode.gate.set()
    b.stop()
    assert queued.done.wait(timeout=5.0)          # failed, not forgotten


# ---------------------------------------------------------------------------
# Batching invisibility: batched == unbatched decode decisions
# ---------------------------------------------------------------------------
def _decode_all(reqs_spec, max_batch: int, window_ms: float):
    """Push every (session, token) through a batcher; return results and
    shed/drop counts."""
    b = ContinuousBatcher(StubDecode(base_ms=0.05, per_item_ms=0.01),
                         AdmissionConfig(max_batch=max_batch,
                                         window_ms=window_ms,
                                         queue_depth=10_000)).start()
    try:
        reqs = [StepRequest(sid, tok) for sid, tok in reqs_spec]
        for r in reqs:
            assert b.submit(r)
        for r in reqs:
            assert r.done.wait(timeout=30.0)
        assert b.dropped == 0 and b.rejected == 0
        return {(r.session, r.token): r.result for r in reqs}
    finally:
        b.stop()


def test_batched_equals_unbatched_results_deterministic():
    spec = [(f"s{i % 5}", i) for i in range(40)]
    batched = _decode_all(spec, max_batch=8, window_ms=2.0)
    unbatched = _decode_all(spec, max_batch=1, window_ms=0.0)
    assert batched == unbatched
    assert all(v is not None for v in batched.values())


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 6), st.integers(0, 1000)),
                min_size=1, max_size=60))
def test_batched_equals_unbatched_results_property(pairs):
    spec = [(f"s{sid}", tok) for sid, tok in pairs]
    assert (_decode_all(spec, max_batch=8, window_ms=1.0)
            == _decode_all(spec, max_batch=1, window_ms=0.0))


# ---------------------------------------------------------------------------
# Engine: end-to-end serving with publish + failure injection
# ---------------------------------------------------------------------------
def test_engine_closed_loop_serves_through_publish_and_stall():
    cfg = EngineConfig(
        session=SessionConfig(protocol="cornus", backend="memory",
                              participants_per_txn=3,
                              service_delay_ms=0.5),
        admission=AdmissionConfig(max_batch=8, window_ms=0.5),
        clients=4, steps_per_session=10,
        publish_at=0.3, publish_until=0.7, stall_at=0.5)
    r = run_serve(cfg)
    rep = r.report
    total = 4 * 10
    assert rep.completed == total
    assert rep.aborted == 1                 # exactly the scavenged stall
    assert rep.committed == total - 1
    assert r.counters["terminations"] == 1
    assert len(r.publishes) >= 1            # epochs committed mid-traffic
    assert rep.publish_disruption is not None
    assert rep.p99_ms >= rep.p50_ms > 0
    assert r.counters["closes"] == 4


def test_engine_replicated_survives_replica_kill():
    cfg = EngineConfig(
        session=SessionConfig(protocol="cornus", backend="replicated",
                              replication=3, participants_per_txn=2,
                              service_delay_ms=0.5),
        admission=AdmissionConfig(max_batch=8, window_ms=0.5),
        clients=4, steps_per_session=8,
        publish_at=0.3, publish_until=0.8, kill_replica_at=0.3)
    r = run_serve(cfg)
    rep = r.report
    assert r.counters["replica_killed"] >= 0
    assert rep.committed == 4 * 8           # quorum survives, every step
    assert r.counters["fast_path_ops"] > 0  # lease fast path engaged
    assert len(r.publishes) >= 1


def test_engine_unbatched_mode_batches_of_one():
    cfg = EngineConfig(
        session=SessionConfig(protocol="cornus", backend="memory",
                              service_delay_ms=0.2),
        clients=3, steps_per_session=4, batch_mode="unbatched")
    r = run_serve(cfg)
    assert r.report.committed == 3 * 4
    assert r.counters["max_batch_seen"] == 1


def test_engine_deadline_drops_count_against_goodput():
    cfg = EngineConfig(
        session=SessionConfig(protocol="cornus", backend="memory",
                              service_delay_ms=0.2),
        admission=AdmissionConfig(max_batch=4, window_ms=5.0,
                                  deadline_ms=1e-4),
        clients=3, steps_per_session=4)
    r = run_serve(cfg)
    rep = r.report
    assert rep.dropped == 3 * 4             # every step expires queued
    assert rep.committed == 0 and rep.goodput_tps == 0.0


def test_engine_open_loop_sheds_instead_of_stalling():
    cfg = EngineConfig(
        session=SessionConfig(protocol="cornus", backend="memory",
                              service_delay_ms=0.5),
        admission=AdmissionConfig(max_batch=4, window_ms=0.5,
                                  backpressure="reject", queue_depth=8),
        clients=4, arrival="open", rate_rps=300.0, duration_s=0.5,
        max_inflight=16)
    r = run_serve(cfg)
    rep = r.report
    assert rep.committed > 0
    assert rep.committed == r.counters["steps_committed"]
    # Whatever wasn't admitted was shed, not lost: accounting adds up.
    assert rep.completed + rep.dropped <= r.counters["submitted"]
