"""Property-based tests of decision-path equivalence under the
termination-storm controls (decision cache, singleflight, push, dedup).

Mirrors the group-commit invisibility suite's structure: full equality
(decisions AND final log state AND ``writer_of`` winners) whenever no
termination runs — the controls must be entirely invisible on the happy
path, rng stream included — and the atomic-commit acceptance criteria
(AC1–AC3: no split brain, never COMMIT without unanimous YES votes) under
arbitrary failure schedules and storm-tight timeouts, for EVERY registered
protocol.
"""
from __future__ import annotations

import pytest

from conftest import hypothesis_or_stubs

HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()

from repro.core import (AZURE_REDIS, Cluster, Decision, DecisionCacheConfig,
                        ProtocolConfig, Sim, SimStorage, TxnSpec,
                        registered_protocols)

HORIZON = 50_000.0
ALL_ON = DecisionCacheConfig(cache=True, singleflight=True, push=True)


def run_cluster(proto, n, votes_yes, seed, storm, fails=None,
                timeout_ms=25.0):
    sim = Sim()
    storage = SimStorage(sim, AZURE_REDIS, seed=seed,
                         decisions=ALL_ON if storm else None)
    nodes = [f"n{i}" for i in range(n)]
    # coop_retry floors at 25ms: 2PC's blocked-participant poll loop runs
    # until the blocking guard, and a sub-ms poll period would turn one
    # blocked example into tens of millions of sim events.
    cfg = ProtocolConfig(protocol=proto,
                         vote_timeout_ms=timeout_ms,
                         decision_timeout_ms=timeout_ms,
                         votereq_timeout_ms=timeout_ms,
                         termination_retry_ms=timeout_ms,
                         coop_retry_ms=max(timeout_ms, 25.0),
                         push_decisions=storm, termination_dedup=storm)
    cluster = Cluster(sim, storage, nodes, cfg)
    spec = TxnSpec(txn_id="t", coordinator=nodes[0], participants=nodes,
                   votes={nd: v for nd, v in zip(nodes, votes_yes)})
    for nd, ft in zip(nodes, fails or [None] * n):
        if ft is not None:
            cluster.fail(nd, ft)
    cluster.run_txn(spec)
    sim.run(until=HORIZON)
    decisions = {node: s["decision"] for (node, t), s in cluster.local.items()
                 if t == "t" and s["decision"] is not None}
    slots = {k: (v, storage.store.writer_of(*k))
             for k, v in storage.store.snapshot().items()}
    return decisions, slots


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(sorted(registered_protocols())),
       st.integers(2, 6).flatmap(lambda n: st.tuples(
           st.just(n),
           st.lists(st.booleans(), min_size=n, max_size=n),
           st.integers(0, 10_000),
       )))
def test_storm_controls_invisible_without_termination(proto, params):
    """No failures + generous timeouts: no termination ever runs, so the
    storm controls must change NOTHING — identical per-node decisions and
    identical final log state (values AND writer_of winners).  This also
    guards the shared rng stream: a cache that consumed service randomness
    would shift every later sample and show up as a changed log state."""
    n, votes, seed = params
    d0, s0 = run_cluster(proto, n, votes, seed, storm=False)
    d1, s1 = run_cluster(proto, n, votes, seed, storm=True)
    assert d0 == d1
    assert s0 == s1


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(sorted(registered_protocols())),
       st.integers(2, 6).flatmap(lambda n: st.tuples(
           st.just(n),
           st.lists(st.booleans(), min_size=n, max_size=n),
           st.lists(st.one_of(st.none(), st.floats(0.0, 40.0)),
                    min_size=n, max_size=n),
           st.integers(0, 10_000),
           st.floats(0.5, 30.0),        # storm-tight timeouts included
       )))
def test_storm_controls_keep_agreement_under_failures(proto, params):
    """AC1–AC3 with every control ON, under arbitrary failure schedules and
    timeouts tight enough that termination (and therefore the cache /
    singleflight / push machinery) actually fires: no split brain, and
    never COMMIT without unanimous YES votes."""
    n, votes, fails, seed, tmo = params
    decisions, _ = run_cluster(proto, n, votes, seed, storm=True,
                               fails=fails, timeout_ms=tmo)
    assert len(set(decisions.values())) <= 1, f"split brain: {decisions}"
    if not all(votes):
        assert Decision.COMMIT not in decisions.values()


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 5).flatmap(lambda n: st.tuples(
    st.just(n),
    st.lists(st.booleans(), min_size=n, max_size=n),
    st.integers(0, 10_000),
)))
def test_cornus_decisions_match_with_and_without_controls_on_coord_death(
        params):
    """Deterministic-failure equivalence: the coordinator dies before any
    decision is sent, every survivor resolves via termination.  The storm
    controls may only remove round trips — the survivors' decisions match
    the control-free run exactly."""
    n, votes, seed = params
    fails = [1.0] + [None] * (n - 1)
    d0, _ = run_cluster("cornus", n, votes, seed, storm=False, fails=fails)
    d1, _ = run_cluster("cornus", n, votes, seed, storm=True, fails=fails)
    assert d0 == d1


@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
def test_meta_tight_timeouts_do_exercise_the_cache():
    """Meta-check: the failure-schedule strategy space really does drive
    the decision cache (guards against the suite silently testing an
    inactive configuration)."""
    sim_hits = 0
    for seed in range(5):
        sim = Sim()
        storage = SimStorage(sim, AZURE_REDIS, seed=seed, decisions=ALL_ON)
        nodes = ["n0", "n1", "n2", "n3"]
        cfg = ProtocolConfig(protocol="cornus", vote_timeout_ms=2.0,
                             decision_timeout_ms=2.0, votereq_timeout_ms=25.0,
                             termination_retry_ms=25.0,
                             push_decisions=True, termination_dedup=True)
        cl = Cluster(sim, storage, nodes, cfg)
        cl.run_txn(TxnSpec(txn_id="t", coordinator="n0", participants=nodes))
        sim.run(until=50_000.0)
        sim_hits += storage.decision_cache_hits
    assert sim_hits > 0
