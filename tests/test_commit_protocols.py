"""Pluggable commit-protocol API: registry, recovery across every registered
protocol (Table 1/2 "During Recovery"), the two forwarding Table-3 rows
(cornus-opt1 / paxos-commit), and the unified read-only fast path that fixed
the CL accounting drift.
"""
import pytest

from repro.core import (AZURE_REDIS, CROSS_ZONE, Cluster,
                        Decision, LatencyModel, ProtocolConfig, RegionTopology,
                        ReplicatedSimStorage, Sim, SimStorage, TxnSpec, Vote,
                        get_protocol, registered_protocols)
from repro.txn import BenchConfig, YCSBWorkload, run_bench

ALL_PROTOCOLS = ["cornus", "2pc", "cl", "cornus-opt1", "paxos-commit"]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
def test_registry_contents_and_errors():
    assert registered_protocols() == sorted(ALL_PROTOCOLS)
    for name in ALL_PROTOCOLS:
        assert get_protocol(name).name == name
    with pytest.raises(KeyError, match="unknown commit protocol"):
        get_protocol("3pc")


def test_coordinator_log_alias_removed_registry_is_the_door():
    import repro.core
    assert not hasattr(repro.core, "CoordinatorLogCluster")
    sim = Sim()
    cl = Cluster(sim, SimStorage(sim, AZURE_REDIS), ["n0", "n1"],
                 ProtocolConfig(protocol="cl"))
    assert cl.protocol.name == "cl"


def test_run_bench_rejects_unknown_protocol():
    with pytest.raises(KeyError, match="unknown commit protocol"):
        run_bench(lambda nodes, seed: YCSBWorkload(nodes, seed=seed),
                  AZURE_REDIS, BenchConfig(protocol="nope", horizon_ms=10.0))


# ---------------------------------------------------------------------------
# Recovery, parameterized over every registered protocol
# ---------------------------------------------------------------------------
def _cluster(proto, n, seed=0):
    sim = Sim()
    storage = SimStorage(sim, AZURE_REDIS, seed=seed)
    nodes = [f"n{i}" for i in range(n)]
    return sim, storage, Cluster(sim, storage, nodes,
                                 ProtocolConfig(protocol=proto)), nodes


def _decisions(cluster, txn="t"):
    return {node: st["decision"]
            for (node, t), st in cluster.local.items()
            if t == txn and st["decision"] is not None}


@pytest.mark.parametrize("proto", ALL_PROTOCOLS)
def test_recovered_participant_resolves_consistently(proto):
    """A participant that crashes mid-protocol and later recovers must
    resolve the txn to the SAME decision the survivors reached."""
    sim, storage, cluster, nodes = _cluster(proto, 3, seed=5)
    spec = TxnSpec(txn_id="t", coordinator="n0", participants=nodes)
    cluster.fail("n2", 2.5, recover_at=2_000.0)
    cluster.run_txn(spec)
    sim.run(until=2_000.0)
    survivors = _decisions(cluster)
    assert "n0" in survivors and "n1" in survivors, survivors
    assert len(set(survivors.values())) == 1

    done = cluster.recover_txn(spec, "n2")
    sim.run(until=100_000.0)
    rec = cluster.outcomes[("t", "n2:recovery")]
    assert rec.decision != Decision.UNDETERMINED, proto
    assert rec.decision == next(iter(survivors.values())), \
        (proto, rec.decision, survivors)


@pytest.mark.parametrize("proto", ALL_PROTOCOLS)
def test_coordinator_failure_then_recover_resolves(proto):
    """The coordinator dies mid-protocol and recovers: its recovery pass
    must resolve the transaction (termination for the Cornus family, the
    decision/presumed-abort log for the 2PC family) — and once it has,
    every blocked participant must eventually learn the same decision."""
    sim, storage, cluster, nodes = _cluster(proto, 4, seed=11)
    spec = TxnSpec(txn_id="t", coordinator="n0", participants=nodes)
    cluster.fail("n0", 1.0, recover_at=5_000.0)
    cluster.run_txn(spec)
    sim.run(until=5_000.0)

    cluster.recover_txn(spec, "n0")
    sim.run(until=500_000.0)
    rec = cluster.outcomes[("t", "n0:recovery")]
    assert rec.decision != Decision.UNDETERMINED, proto
    decisions = _decisions(cluster)
    # Everyone — coordinator included — converged on one decision.
    assert set(decisions) == set(nodes), (proto, decisions)
    assert set(decisions.values()) == {rec.decision}, (proto, decisions)


def test_cornus_coordinator_recovery_uses_termination():
    """Cornus coordinator recovery resolves via the storage-CAS termination
    protocol (bounded, no peer round-trips needed): the participants' log
    slots carry the evidence."""
    sim, storage, cluster, nodes = _cluster("cornus", 3, seed=2)
    spec = TxnSpec(txn_id="t", coordinator="n0", participants=nodes)
    cluster.fail("n0", 1.0, recover_at=3_000.0)
    cluster.run_txn(spec)
    sim.run(until=3_000.0)
    cluster.recover_txn(spec, "n0")
    sim.run(until=50_000.0)
    rec = cluster.outcomes[("t", "n0:recovery")]
    assert rec.decision != Decision.UNDETERMINED
    # The decision is durable in the participants' slots, not a peer's RAM.
    states = [storage.store.read_state(p, "t") for p in ("n1", "n2")]
    want = Vote.COMMIT if rec.decision == Decision.COMMIT else Vote.ABORT
    assert want in states, (rec.decision, states)


# ---------------------------------------------------------------------------
# Unified read-only fast path (the old CL accounting drift)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("proto", ALL_PROTOCOLS)
def test_readonly_fast_path_unified(proto):
    """All-read-only txns take the shared fast path in EVERY protocol:
    measured (not hardcoded) caller latency, and remote participants ARE
    notified so their locks release."""
    sim, storage, cluster, nodes = _cluster(proto, 3)
    spec = TxnSpec(txn_id="t", coordinator="n0", participants=nodes,
                   read_only=frozenset(nodes), read_only_known_upfront=True)
    # Start mid-simulation so a hardcoded 0.0 would be distinguishable
    # from a measured start-relative latency.
    sim.run(until=7.0)
    done = cluster.run_txn(spec)
    sim.run(until=1_000.0)
    out = done.value
    assert out.decision == Decision.COMMIT
    assert out.caller_latency_ms == 0.0          # measured: now - t0
    assert out.done_at_ms >= 7.0
    decisions = _decisions(cluster)
    assert set(decisions) == set(nodes), (proto, decisions)
    assert set(decisions.values()) == {Decision.COMMIT}


# ---------------------------------------------------------------------------
# Vote forwarding (cornus-opt1 / paxos-commit)
# ---------------------------------------------------------------------------
ZERO_LAT = LatencyModel("null", conditional_write_ms=0.0, plain_write_ms=0.0,
                        read_ms=0.0, jitter=0.0)


def test_forwarded_vote_delivers_decided_value_once():
    """coloc acceptor forwarding: the forward target gets the slot's DECIDED
    value exactly once, even when a termination ABORT won the CAS race."""
    for delay, want in ((0.0, Vote.VOTE_YES), (50.0, Vote.ABORT)):
        sim = Sim()
        topo = RegionTopology.uniform("u", ("r0",), 10.0)
        storage = ReplicatedSimStorage(sim, ZERO_LAT, n_replicas=3,
                                       topology=topo, mode="coloc")
        got = []

        def run():
            if delay:
                # Terminator's ABORT decides the slot first.
                yield storage.log_once("p", "t", Vote.ABORT, writer="peer")
                yield sim.timeout(delay)
            yield storage.log_once("p", "t", Vote.VOTE_YES, writer="p",
                                   forward_to="c",
                                   on_forward=lambda v: got.append(
                                       (sim.now, v)))

        sim.process(run())
        sim.run(until=10_000.0)
        assert len(got) == 1, got
        assert got[0][1] == want, (delay, got)


def test_leader_forwarding_parallel_with_reply():
    """leader mode (cornus-opt1): the leader pushes the vote to the forward
    target in parallel with the reply hop — both land at the same instant
    under a uniform topology (the coordinator saves the extra half-RTT
    participant→coordinator message that plain Cornus still needs)."""
    sim = Sim()
    topo = RegionTopology.uniform("u", ("r0",), 10.0)
    storage = ReplicatedSimStorage(sim, ZERO_LAT, n_replicas=3,
                                   topology=topo, mode="leader")
    got, reply = [], []

    def run():
        v = yield storage.log_once("p", "t", Vote.VOTE_YES, writer="p",
                                   forward_to="c",
                                   on_forward=lambda v: got.append(sim.now))
        reply.append((sim.now, v))

    sim.process(run())
    sim.run(until=10_000.0)
    assert got and reply
    # to-leader 5 + accept round 10 (leader self-ack + acceptor RTT) + 5
    assert got[0] == reply[0][0] == 20.0


@pytest.mark.parametrize("proto", ["cornus-opt1", "paxos-commit"])
def test_forward_protocols_run_bench_end_to_end(proto):
    """BenchConfig(protocol=<forwarding row>) runs through run_bench by
    registry lookup only — single store AND replicated deployments."""
    wl = lambda nodes, seed: YCSBWorkload(nodes, seed=seed)
    r = run_bench(wl, AZURE_REDIS,
                  BenchConfig(protocol=proto, n_nodes=4, horizon_ms=400.0,
                              seed=3))
    assert r.commits > 50, (proto, r.commits)
    # Replicated: storage_mode=None lets the registry pick the protocol's
    # preferred deployment (coloc for paxos-commit, leader for cornus-opt1).
    r3 = run_bench(wl, AZURE_REDIS,
                   BenchConfig(protocol=proto, n_nodes=4, horizon_ms=400.0,
                               replication=3, topology=CROSS_ZONE, seed=3))
    assert r3.commits > 0, (proto, r3.commits)


def test_forwarding_shaves_the_predicted_rtts():
    """Against the same replicated deployment, the measured caller-latency
    ordering matches Table 3: paxos-commit < cornus-opt1 < cornus."""
    from repro.core import measured_caller_latency_ms
    lat = {p: measured_caller_latency_ms(p, 20.0)
           for p in ("paxos-commit", "cornus-opt1", "cornus")}
    assert lat["paxos-commit"] < lat["cornus-opt1"] < lat["cornus"], lat
