"""Cornus checkpoint-commit integration tests (live protocol over threads +
FileStore CAS).  These are the training-framework deployment of the paper's
claims: atomicity of multi-host checkpoints, non-blocking resolution when
hosts die mid-epoch, straggler force-abort, elastic restore.
"""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (CornusCheckpointer, latest_committed, pack_tree,
                        partition_leaves, restore_params, unpack_tree)
from repro.ckpt.commit import AsyncCheckpointer, _txn
from repro.core.state import Decision, Vote
from repro.core.storage import FileStore, MemoryStore


HOSTS = ["h0", "h1", "h2", "h3"]


def make_tree(seed=0):
    rng = np.random.RandomState(seed)
    return {
        "embed": jnp.asarray(rng.randn(64, 16).astype(np.float32)),
        "layers": {"w1": jnp.asarray(rng.randn(16, 32).astype(np.float32)),
                   "w2": jnp.asarray(rng.randn(32, 16).astype(np.float32))},
        "ln": jnp.asarray(rng.randn(16).astype(np.float32)),
    }


def host_payloads(tree, hosts):
    parts = partition_leaves(tree, len(hosts))
    return {h: pack_tree(tree, keys) for h, keys in zip(hosts, parts)}


def test_pack_roundtrip():
    tree = make_tree()
    flat = unpack_tree(pack_tree(tree))
    assert set(flat) == {"embed", "layers/w1", "layers/w2", "ln"}
    np.testing.assert_array_equal(flat["embed"], np.asarray(tree["embed"]))


def test_partition_covers_all_leaves_balanced():
    tree = make_tree()
    parts = partition_leaves(tree, 3)
    all_keys = [k for p in parts for k in p]
    assert sorted(all_keys) == sorted(unpack_tree(pack_tree(tree)).keys())


def test_all_hosts_commit(tmp_path):
    store = FileStore(str(tmp_path))
    tree = make_tree()
    payloads = host_payloads(tree, HOSTS)
    outs = {}

    def run(h):
        ck = CornusCheckpointer(store, h, HOSTS, straggler_timeout_s=5.0)
        outs[h] = ck.save(1, payloads[h])

    ts = [threading.Thread(target=run, args=(h,)) for h in HOSTS]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert all(o.decision == Decision.COMMIT for o in outs.values()), outs
    assert latest_committed(store, HOSTS) == 1

    restored = restore_params(store, HOSTS, 1, jax.tree_util.tree_map(
        jnp.zeros_like, tree))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_is_force_aborted_not_waited_on(tmp_path):
    """h3 never shows up; peers resolve the epoch in bounded time by
    CAS-writing ABORT into h3's slot (Theorem 4) — nobody blocks."""
    store = FileStore(str(tmp_path))
    payloads = host_payloads(make_tree(), HOSTS)
    outs = {}
    t0 = time.monotonic()

    def run(h):
        ck = CornusCheckpointer(store, h, HOSTS, straggler_timeout_s=0.3)
        outs[h] = ck.save(2, payloads[h])

    ts = [threading.Thread(target=run, args=(h,)) for h in HOSTS[:3]]
    [t.start() for t in ts]
    [t.join() for t in ts]
    elapsed = time.monotonic() - t0
    assert elapsed < 5.0, "termination must be bounded"
    assert all(o.decision == Decision.ABORT for o in outs.values())
    assert store.read_state("h3", _txn(2)) == Vote.ABORT
    assert latest_committed(store, HOSTS) is None

    # The straggler finally arrives: its LogOnce CAS-loses, it learns ABORT.
    late = CornusCheckpointer(store, "h3", HOSTS)
    out = late.save(2, payloads["h0"])
    assert out.decision == Decision.ABORT


def test_restart_resolves_inflight_epoch_without_blocking(tmp_path):
    """Half the fleet dies after voting; a restarting job must settle the
    epoch immediately (2PC would block on the dead coordinator)."""
    store = FileStore(str(tmp_path))
    payloads = host_payloads(make_tree(), HOSTS)
    # Epoch 1 fully committed earlier.
    for h in HOSTS:
        CornusCheckpointer(store, h, HOSTS).vote(1, payloads[h])
    # Epoch 2: only h0, h1 voted before the crash.
    for h in HOSTS[:2]:
        CornusCheckpointer(store, h, HOSTS).vote(2, payloads[h])

    t0 = time.monotonic()
    latest = latest_committed(store, HOSTS)
    assert time.monotonic() - t0 < 2.0
    assert latest == 1                      # epoch 2 force-aborted, not hung
    assert store.read_state("h2", _txn(2)) == Vote.ABORT


def test_concurrent_resolvers_agree(tmp_path):
    """Many racing terminators (every host times out at once) — log-once
    guarantees one consistent decision (the hypothesis-tested Lemma 1, now
    over the real FileStore CAS)."""
    store = FileStore(str(tmp_path))
    payloads = host_payloads(make_tree(), HOSTS)
    for h in HOSTS[:2]:
        CornusCheckpointer(store, h, HOSTS).vote(3, payloads[h])
    decisions = []
    lock = threading.Lock()

    def resolve(h):
        ck = CornusCheckpointer(store, h, HOSTS)
        d, _ = ck.terminate(3)
        with lock:
            decisions.append(d)

    ts = [threading.Thread(target=resolve, args=(h,)) for h in HOSTS * 3]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert len(set(decisions)) == 1 and decisions[0] == Decision.ABORT


def test_memorystore_cas_concurrency():
    store = MemoryStore()
    winners = []
    lock = threading.Lock()

    def racer(i):
        r = store.log_once("p", "t", Vote.VOTE_YES if i % 2 else Vote.ABORT,
                           writer=f"w{i}")
        with lock:
            winners.append(r)

    ts = [threading.Thread(target=racer, args=(i,)) for i in range(16)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert len(set(winners)) == 1  # everyone observed the single first write


def test_async_checkpointer_overlaps(tmp_path):
    store = FileStore(str(tmp_path))
    payloads = host_payloads(make_tree(), ["h0"])
    ck = AsyncCheckpointer(CornusCheckpointer(store, "h0", ["h0"]))
    ck.save(5, payloads["h0"])
    outs = ck.join()
    assert outs and outs[-1].decision == Decision.COMMIT
    assert latest_committed(store, ["h0"]) == 5


def test_ckpt_commit_over_replicated_store_survives_volume_loss():
    """The committer pointed at a ReplicatedStore (R=3 quorum CAS + shard
    payloads replicated per volume): a full-fleet commit stays readable and
    restorable after losing any ONE replica volume — the disaggregated
    durability the FileStore deployment cannot give."""
    from repro.core.storage import ReplicatedStore

    store = ReplicatedStore(n_replicas=3)
    tree = make_tree(seed=4)
    payloads = host_payloads(tree, HOSTS)
    outs = {}

    def run(h):
        ck = CornusCheckpointer(store, h, HOSTS, straggler_timeout_s=5.0)
        outs[h] = ck.save(3, payloads[h])

    ts = [threading.Thread(target=run, args=(h,)) for h in HOSTS]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert all(o.decision == Decision.COMMIT for o in outs.values()), outs
    assert latest_committed(store, HOSTS) == 3

    # Lose one replica: its volume (shard payloads AND state slots) is
    # unreachable.  Quorum reads and any surviving copy of each shard keep
    # the checkpoint fully restorable.
    store.fail_replica(0)
    store.replicas[0].drop_data()     # the volume is really gone
    assert latest_committed(store, HOSTS) == 3
    template = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored = restore_params(store, HOSTS, 3, template)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # A second failure breaks quorum: unavailable, never wrong.
    from repro.core import QuorumUnavailable
    store.fail_replica(1)
    with pytest.raises(QuorumUnavailable):
        CornusCheckpointer(store, "h0", HOSTS).vote(4, payloads["h0"])


def test_elastic_restore_different_host_count(tmp_path):
    """Written by 4 hosts, restored by a fleet of any size."""
    store = FileStore(str(tmp_path))
    tree = make_tree(seed=9)
    payloads = host_payloads(tree, HOSTS)
    for h in HOSTS:
        CornusCheckpointer(store, h, HOSTS).vote(7, payloads[h])
    assert latest_committed(store, HOSTS) == 7
    template = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored = restore_params(store, HOSTS, 7, template)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
