"""Sundial-like substrate sanity: locks, workloads, bench orderings."""
import random

import pytest

from conftest import hypothesis_or_stubs

from repro.core.storage import AZURE_BLOB, AZURE_REDIS
from repro.txn import (BenchConfig, LockMode, LockTable, TPCCWorkload,
                       YCSBWorkload, run_bench, zipf_sampler)

HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()


def test_nowait_lock_semantics():
    lt = LockTable("p0")
    assert lt.try_lock("t1", "k", LockMode.SHARED)
    assert lt.try_lock("t2", "k", LockMode.SHARED)
    assert not lt.try_lock("t3", "k", LockMode.EXCLUSIVE)  # conflict -> abort
    assert not lt.try_lock("t1", "k", LockMode.EXCLUSIVE)  # upgrade blocked
    lt.release_all("t2")
    assert lt.try_lock("t1", "k", LockMode.EXCLUSIVE)      # upgrade ok now
    lt.release_all("t1")
    assert lt.try_lock("t3", "k", LockMode.EXCLUSIVE)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.0, 0.99), st.integers(0, 999))
def test_zipf_sampler_in_range(theta, seed):
    rng = random.Random(seed)
    s = zipf_sampler(1000, theta, rng)
    xs = [s() for _ in range(500)]
    assert all(0 <= x < 1000 for x in xs)
    if theta > 0.8:  # strong skew concentrates on low ranks
        assert sum(1 for x in xs if x < 10) > len(xs) * 0.2


def test_ycsb_txn_shape():
    w = YCSBWorkload(["n0", "n1", "n2"], theta=0.5, seed=1)
    t = w.next_txn("n0")
    assert len(t.accesses) == 16
    assert set(t.participants) <= {"n0", "n1", "n2"}
    assert t.is_distributed  # 16 accesses over 3 nodes


def test_tpcc_txn_shape():
    w = TPCCWorkload(["n0", "n1"], n_warehouses=4, seed=2)
    kinds = set()
    for _ in range(50):
        t = w.next_txn("n0")
        kinds.add(t.txn_id.split("-")[1])
        assert len(t.accesses) >= 2
    assert kinds == {"payment", "neworder"}


def test_cornus_beats_2pc_on_latency():
    """Core claim (Fig 5): same workload, Cornus < 2PC caller latency."""
    results = {}
    for proto in ("cornus", "2pc"):
        cfg = BenchConfig(protocol=proto, n_nodes=4, horizon_ms=600.0, seed=11)
        r = run_bench(lambda nodes, seed: YCSBWorkload(nodes, seed=seed),
                      AZURE_BLOB, cfg)
        results[proto] = r
        assert r.commits > 100
    speedup = results["2pc"].avg_latency_ms / results["cornus"].avg_latency_ms
    assert 1.1 < speedup < 2.2, f"speedup {speedup:.2f} out of paper band"
    # Cornus's commit phase is (nearly) eliminated.
    assert results["cornus"].breakdown()["commit"] < 0.2
    assert results["2pc"].breakdown()["commit"] > 5.0


def test_elr_improves_high_contention_throughput():
    """Fig 9: speculative precommit (ELR) helps under contention."""
    outs = {}
    for elr in (False, True):
        cfg = BenchConfig(protocol="cornus", n_nodes=4, horizon_ms=600.0,
                          elr=elr, seed=5)
        r = run_bench(lambda nodes, seed: YCSBWorkload(
            nodes, theta=0.9, keys_per_partition=100, seed=seed),
            AZURE_REDIS, cfg)
        outs[elr] = r
    assert outs[True].throughput_tps > outs[False].throughput_tps * 1.05


def test_single_partition_fast_path():
    cfg = BenchConfig(protocol="cornus", n_nodes=1, horizon_ms=300.0)
    r = run_bench(lambda nodes, seed: YCSBWorkload(nodes, seed=seed),
                  AZURE_REDIS, cfg)
    # Single node => nothing distributed => no distributed-txn latencies.
    assert r.commits == 0 and r.latencies == []
