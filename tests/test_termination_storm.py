"""Termination-storm controls: storage-side decision cache + singleflight +
decision push, compute-side termination dedup, adaptive (EWMA, re-arming)
timeouts, fresh retry ids — and the AnyOf subscription-leak fix they lean on.

The scenario: under a serial log lane, queueing pushes write latency past a
static protocol timeout, and every timed-out participant races a LogOnce
termination round against the same queue — load multiplies and the paper's
cornus-over-2PC ordering inverts.  The controls kill the storm on three
layers while keeping the no-failure Table-3 critical path EXACT.
"""
from __future__ import annotations

import pytest

from repro.core import (AZURE_REDIS, Cluster, Decision, DecisionCacheConfig,
                        ProtocolConfig, ReplicatedSimStorage, Sim, SimStorage,
                        SIMULATED_RTT_ROWS, Transport, TxnSpec, Vote,
                        measured_caller_latency_ms,
                        predicted_caller_latency_ms)
from repro.txn import (AdaptiveTimeouts, BenchConfig, YCSBWorkload,
                       median_of_trials, run_bench)

ALL_ON = DecisionCacheConfig(cache=True, singleflight=True, push=True)


# ---------------------------------------------------------------------------
# Sim kernel: AnyOf detaches its subscriptions (the leak satellite)
# ---------------------------------------------------------------------------
def test_anyof_detaches_losing_subscriptions():
    """A long-lived loser event must not keep the composite's callback (and
    the composite) alive after the race is decided."""
    sim = Sim()
    slot = sim.event()                  # long-lived (like a transport slot)
    av = sim.any_of([slot, sim.timeout(1.0)])
    sim.run()
    assert av.value == (1, None)
    assert slot.callbacks == []         # detached when the timeout won


def test_transport_wait_leaves_no_slot_callbacks():
    """Every timed-out wait() on a persistent message slot detaches fully —
    long contention runs used to accumulate one dead callback per wait."""
    sim = Sim()
    tr = Transport(sim, ["a", "b"], ProtocolConfig())
    for _ in range(50):
        tr.wait("b", "t", "k", 1.0)
    sim.run()
    assert tr.slot("b", "t", "k").callbacks == []


# ---------------------------------------------------------------------------
# Re-arming waits (adaptive timeout providers)
# ---------------------------------------------------------------------------
def test_wait_rearms_when_provider_raises_deadline():
    """A wait armed while the policy was cold must stretch to the policy's
    later, higher value instead of reporting a spurious timeout."""
    sim = Sim()
    tr = Transport(sim, ["a", "b"], ProtocolConfig())
    values = iter([5.0, 20.0, 20.0, 20.0])
    ev = tr.wait("b", "t", "k", lambda: next(values))
    sim._schedule(15.0, lambda: tr.deliver("b", "t", "k", "late"))
    sim.run()
    assert ev.value == ("msg", "late")  # 15 > 5, but the deadline grew to 20

    # A plain float keeps the single-deadline behaviour exactly.
    sim2 = Sim()
    tr2 = Transport(sim2, ["a", "b"], ProtocolConfig())
    ev2 = tr2.wait("b", "t", "k", 5.0)
    sim2._schedule(15.0, lambda: tr2.deliver("b", "t", "k", "late"))
    sim2.run()
    assert ev2.value == ("timeout", None)


def test_adaptive_timeouts_policy_is_raise_only_and_capped():
    class _Stats:
        write_lat_ewma = None
        write_lat_dev = 0.0

    cold = _Stats()
    pol = AdaptiveTimeouts(cold, seed=1, jitter=0.0)
    assert pol.timeout_ms("vote", 25.0) == 25.0     # no observations: base

    warm = _Stats()
    warm.write_lat_ewma, warm.write_lat_dev = 50.0, 10.0
    pol = AdaptiveTimeouts(warm, seed=1, jitter=0.0)
    assert pol.timeout_ms("vote", 25.0) == pytest.approx(
        4.0 * 50.0 + 8.0 * 10.0)                    # tracks the EWMA
    assert pol.timeout_ms("vote", 1000.0) == 1000.0  # never below the floor

    hot = _Stats()
    hot.write_lat_ewma, hot.write_lat_dev = 10_000.0, 0.0
    pol = AdaptiveTimeouts(hot, seed=1, jitter=0.0)
    assert pol.timeout_ms("vote", 25.0) == 64.0 * 25.0   # capped

    jit = AdaptiveTimeouts(warm, seed=1, jitter=0.25)
    vals = {jit.timeout_ms("vote", 25.0) for _ in range(20)}
    lo = 4.0 * 50.0 + 8.0 * 10.0
    assert all(lo <= v < lo * 1.25 for v in vals)    # raise-only jitter
    assert len(vals) > 1                             # ...and desynchronized


def test_storage_observes_write_latency():
    sim = Sim()
    st = SimStorage(sim, AZURE_REDIS, seed=0)
    assert st.write_lat_ewma is None
    st.log("p", "t", Vote.COMMIT, writer="p")
    sim.run()
    assert st.write_lat_ewma is not None and st.write_lat_ewma > 0


# ---------------------------------------------------------------------------
# Storage-side decision cache
# ---------------------------------------------------------------------------
def test_decision_cache_answers_post_decision_log_once():
    """Once any slot of a txn holds a terminal record, a later LogOnce for
    that txn is answered from the index — no CAS runs, no slot mutates."""
    sim = Sim()
    st = SimStorage(sim, AZURE_REDIS, seed=0, decisions=ALL_ON)
    a = st.log_once("p1", "t", Vote.ABORT, writer="term")
    sim.run()
    assert a.value == Vote.ABORT
    b = st.log_once("p2", "t", Vote.VOTE_YES, writer="p2")
    c = st.log_once("p2", "t", Vote.ABORT, writer="another-term")
    sim.run()
    assert b.value == Vote.ABORT and c.value == Vote.ABORT
    assert st.decision_cache_hits == 2
    assert st.store.read_state("p2", "t") is None    # the CAS never ran
    # A different txn is unaffected.
    d = st.log_once("p2", "u", Vote.VOTE_YES, writer="p2")
    sim.run()
    assert d.value == Vote.VOTE_YES
    assert st.decision_cache_hits == 2


def test_decision_cache_inactive_by_default():
    sim = Sim()
    st = SimStorage(sim, AZURE_REDIS, seed=0)
    st.log_once("p1", "t", Vote.ABORT, writer="term")
    sim.run()
    b = st.log_once("p2", "t", Vote.VOTE_YES, writer="p2")
    sim.run()
    assert b.value == Vote.VOTE_YES                  # full CAS, no cache
    assert st.decision_cache_hits == 0
    assert st.store.read_state("p2", "t") == Vote.VOTE_YES


def test_replicated_decision_cache_skips_the_paxos_round():
    sim = Sim()
    st = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3, seed=0,
                              decisions=ALL_ON)
    a = st.log_once("p1", "t", Vote.ABORT, writer="term")
    sim.run()
    assert a.value == Vote.ABORT
    rounds_before = st.round_trips
    b = st.log_once("p2", "t", Vote.VOTE_YES, writer="p2")
    sim.run()
    assert b.value == Vote.ABORT
    assert st.decision_cache_hits == 1
    assert st.round_trips == rounds_before           # no quorum scatter paid


def test_singleflight_coalesces_identical_inflight_cas():
    """Two racing terminators CASing the same value into one slot share ONE
    round; content and writer are exactly what back-to-back CASes give."""
    sim = Sim()
    st = SimStorage(sim, AZURE_REDIS, seed=1,
                    decisions=DecisionCacheConfig(singleflight=True))
    a = st.log_once("p", "t", Vote.ABORT, writer="t1")
    b = st.log_once("p", "t", Vote.ABORT, writer="t2")
    sim.run()
    assert a.value == Vote.ABORT and b.value == Vote.ABORT
    assert st.singleflight_hits == 1
    assert st.round_trips == 1
    assert st.store.writer_of("p", "t") == "t1"


def test_watch_decision_fires_once_on_first_terminal_record():
    sim = Sim()
    st = SimStorage(sim, AZURE_REDIS, seed=0, decisions=ALL_ON)
    got = []
    st.watch_decision("t", got.append)
    st.log_once("p1", "t", Vote.VOTE_YES, writer="p1")   # not terminal
    sim.run()
    assert got == []
    st.log_once("p2", "t", Vote.ABORT, writer="term")
    st.log("p1", "t", Vote.ABORT, writer="p1")
    sim.run()
    assert got == [Vote.ABORT]                       # first terminal only
    late = []
    st.watch_decision("t", late.append)              # already decided
    assert late == [Vote.ABORT]
    assert st.decisions_pushed == 2


# ---------------------------------------------------------------------------
# Protocol integration: push prevents terminations, cache absorbs the rest
# ---------------------------------------------------------------------------
def _dead_participant_cluster(push: bool, seed: int = 2):
    """n2 dies before voting: the coordinator's vote wait times out and it
    runs the termination protocol; n1 is left waiting for the decision."""
    sim = Sim()
    storage = SimStorage(sim, AZURE_REDIS, seed=seed, decisions=ALL_ON)
    nodes = ["n0", "n1", "n2"]
    cfg = ProtocolConfig(protocol="cornus", push_decisions=push,
                         termination_dedup=True)
    cl = Cluster(sim, storage, nodes, cfg)
    cl.fail("n2", 0.0)
    cl.run_txn(TxnSpec(txn_id="t", coordinator="n0", participants=nodes))
    sim.run(until=10_000.0)
    decisions = {n: s["decision"] for (n, t), s in cl.local.items()
                 if s["decision"] is not None}
    return cl, storage, decisions


def test_decision_push_spares_waiting_participants_the_termination():
    cl_off, st_off, d_off = _dead_participant_cluster(push=False)
    cl_on, st_on, d_on = _dead_participant_cluster(push=True)
    # Same decisions either way (the push changes round trips, not outcomes)
    assert d_on == d_off == {"n0": Decision.ABORT, "n1": Decision.ABORT}
    # Without push n1 times out and terminates too; its whole round is
    # answered from the decision cache (the coordinator's ABORT landed).
    assert cl_off.ctx.terminations == 2
    assert st_off.decision_cache_hits > 0
    # With push the coordinator's first terminal CAS is delivered straight
    # into n1's decision slot: only ONE termination ever runs.
    assert cl_on.ctx.terminations == 1
    assert st_on.decisions_pushed >= 1


def test_termination_dedup_joins_inflight_run():
    sim = Sim()
    storage = SimStorage(sim, AZURE_REDIS, seed=3, decisions=ALL_ON)
    nodes = ["n0", "n1", "n2"]
    cfg = ProtocolConfig(protocol="cornus", termination_dedup=True)
    cl = Cluster(sim, storage, nodes, cfg)
    spec = TxnSpec(txn_id="t", coordinator="n0", participants=nodes)
    cl.fail("n0", 0.0)                  # coordinator never sends a decision
    from repro.core import TxnOutcome
    outs = [TxnOutcome(txn_id="t", node="n1",
                       decision=Decision.UNDETERMINED) for _ in range(3)]
    procs = [sim.process(cl.protocol.run_termination(spec, "n1", o))
             for o in outs]
    sim.run(until=10_000.0)
    got = {p.value for p in procs}
    assert got == {Decision.ABORT}      # one run, one shared decision
    assert cl.ctx.terminations == 1
    assert cl.ctx.dedup_hits == 2
    assert cl.ctx.term_inflight == {}   # cleaned up


# ---------------------------------------------------------------------------
# Table 3 stays EXACT with the full storm-control stack enabled
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("row", sorted(SIMULATED_RTT_ROWS))
def test_table3_exact_with_storm_controls_enabled(row):
    """On the no-failure critical path none of the storm machinery may
    fire: the measured caller latency lands EXACTLY on the predicted
    Table-3 RTT multiple (equality, not a tolerance)."""
    measured = measured_caller_latency_ms(row, 20.0, storm_control=True)
    assert measured == predicted_caller_latency_ms(row, 20.0)


# ---------------------------------------------------------------------------
# The storm itself: before/after on the nobatch serial lane
# ---------------------------------------------------------------------------
def _contention_wl(nodes, seed):
    return YCSBWorkload(nodes, accesses_per_txn=4, partition_theta=0.9,
                        keys_per_partition=10_000, seed=seed)


STORM_CONTROL = dict(decision_cache=True, termination_singleflight=True,
                     decision_push=True, termination_dedup=True,
                     retry_fresh_ids=True)


def test_storm_controls_restore_cornus_over_2pc_on_nobatch():
    """The acceptance scenario in miniature: R=3 serial nobatch lanes under
    hot-partition skew.  Static no-load timeouts storm (few commits, many
    terminations); with the controls on, terminations vanish, throughput
    recovers by an order of magnitude, and cornus is no longer behind 2PC."""
    def run(proto, **kw):
        cfg = BenchConfig(protocol=proto, n_nodes=4, threads_per_node=8,
                          horizon_ms=300.0, replication=3, seed=3,
                          storage_serial=True, batch_max=1, **kw)
        return run_bench(_contention_wl, AZURE_REDIS, cfg)

    stormy = run("cornus", timeout_ms=25.0)          # the old static world
    cornus = run("cornus", **STORM_CONTROL)          # adaptive + controls
    twopc = run("2pc", **STORM_CONTROL)
    assert stormy.terminations > 20                  # the storm is real
    assert cornus.terminations <= 2
    assert cornus.commits >= 5 * max(stormy.commits, 1)
    assert cornus.commits >= twopc.commits           # paper ordering holds
    assert cornus.gaveups == 0


def test_retry_fresh_ids_unpoisons_terminated_txns():
    """A quorum outage forces in-flight txns through termination-ABORT;
    their LogOnce slots stay terminal forever.  Retrying the same txn id
    can then only re-abort (burning every attempt into a gaveup); a fresh
    incarnation id commits once storage recovers."""
    def run(fresh):
        def wl(nodes, seed):
            return YCSBWorkload(nodes, accesses_per_txn=4, seed=seed)
        cfg = BenchConfig(protocol="cornus", n_nodes=2, threads_per_node=1,
                          horizon_ms=400.0, replication=3, seed=5,
                          timeout_ms=20.0, max_attempts=10,
                          retry_fresh_ids=fresh,
                          replica_failures=((0, 0.0, 100.0),
                                            (1, 0.0, 100.0)))
        return run_bench(wl, AZURE_REDIS, cfg)

    stale, fresh = run(False), run(True)
    assert stale.gaveups >= 1                        # poisoned ids give up
    assert fresh.gaveups == 0
    assert fresh.commits > stale.commits


# ---------------------------------------------------------------------------
# Counters + percentiles ride BenchResult / breakdown()
# ---------------------------------------------------------------------------
def test_benchresult_percentiles_and_counters():
    cfg = BenchConfig(protocol="cornus", n_nodes=4, threads_per_node=8,
                      horizon_ms=300.0, replication=3, seed=3,
                      storage_serial=True, batch_max=1, **STORM_CONTROL)
    r = run_bench(_contention_wl, AZURE_REDIS, cfg)
    assert r.commits > 0
    assert 0 < r.p50_latency_ms <= r.p95_latency_ms <= r.p99_latency_ms
    bd = r.breakdown()
    assert bd["p50"] == r.p50_latency_ms and bd["p95"] == r.p95_latency_ms
    assert r.decisions_pushed > 0
    for f in ("terminations", "dedup_hits", "decision_cache_hits",
              "singleflight_hits"):
        assert getattr(r, f) >= 0


# ---------------------------------------------------------------------------
# median_of_trials: process fan-out is bit-identical to serial
# ---------------------------------------------------------------------------
@pytest.mark.filterwarnings("ignore:os.fork")
def test_median_of_trials_parallel_matches_serial():
    cfg = BenchConfig(protocol="cornus", n_nodes=4, horizon_ms=120.0, seed=7)
    wl = lambda nodes, seed: YCSBWorkload(nodes, seed=seed)
    serial = median_of_trials(wl, AZURE_REDIS, cfg, trials=3, processes=1)
    par = median_of_trials(wl, AZURE_REDIS, cfg, trials=3, processes=3)
    assert serial.commits == par.commits
    assert serial.avg_latency_ms == par.avg_latency_ms
    assert serial.latencies == par.latencies
