"""System-level behaviour tests: the three layers compose.

(The per-layer suites live in test_protocol_properties / test_txn_bench /
test_arch_smoke / test_kernels / test_ckpt_commit / test_train_loop; this
file asserts the cross-layer contracts.)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AZURE_BLOB, AZURE_REDIS, Cluster, Decision,
                        ProtocolConfig, Sim, SimStorage, TxnSpec,
                        predicted_caller_latency_ms, rtt_table)


def commit_latency(proto: str, model, n=4, seed=0):
    sim = Sim()
    cluster = Cluster(sim, SimStorage(sim, model, seed=seed),
                      [f"n{i}" for i in range(n)],
                      ProtocolConfig(protocol=proto))
    done = cluster.run_txn(TxnSpec(
        txn_id="t", coordinator="n0",
        participants=[f"n{i}" for i in range(n)]))
    sim.run(until=10_000)
    return done.value


def test_cornus_eliminates_commit_phase():
    """The paper's core mechanism: caller latency = prepare phase only."""
    for model in (AZURE_REDIS, AZURE_BLOB):
        c = commit_latency("cornus", model)
        t = commit_latency("2pc", model)
        assert c.decision == t.decision == Decision.COMMIT
        assert c.commit_ms < 0.01, "Cornus must not log a decision"
        assert t.commit_ms > model.plain_write_ms * 0.8
        # Commit-level speedup approaches the Table-3 5/3 ratio as storage
        # latency dominates the 0.5ms RTT.
        ratio = t.caller_latency_ms / c.caller_latency_ms
        assert 1.3 < ratio < 2.2, ratio


def test_table3_consistency_with_simulator():
    """The analytic RTT model and the simulator agree on the 2PC/Cornus gap
    when one 'Paxos RTT' equals one storage write."""
    rows = rtt_table()
    assert rows["2pc"]["total"] / rows["cornus"]["total"] == pytest.approx(
        5.0 / 3.0)
    assert predicted_caller_latency_ms("cornus", 10.0) == 30.0


def test_roofline_reader_on_artifacts():
    """benchmarks.roofline parses whatever dry-run artifacts exist."""
    import os
    if not os.path.isdir("artifacts/dryrun"):
        pytest.skip("no dry-run artifacts in this checkout")
    from benchmarks.roofline import load_cells
    cells = load_cells("artifacts/dryrun")
    assert len(cells) >= 1
    ok = [c for c in cells if not c.skipped and not c.error]
    assert ok, "no successful cells recorded"
    for c in ok:
        assert c.compute_s >= 0 and c.memory_s >= 0 and c.collective_s >= 0
        assert c.bottleneck in ("compute", "memory", "collective")


def test_dryrun_lowering_path_smoke():
    """The dry-run machinery (input_specs -> jit -> lower -> compile ->
    cost/collective extraction) works on a 1-device mesh with a smoke
    config — the 512-device run just changes the mesh."""
    from repro.configs import get_config
    from repro.launch import steps as S
    from repro.launch.dryrun import cost_dict, parse_collectives
    from repro.launch.mesh import auto_axis_types_kwargs
    from repro.launch.sharding import Rules
    from repro.models.config import ShapeConfig, smoke

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         **auto_axis_types_kwargs(2))
    rules = Rules(mesh)
    cfg = smoke(get_config("llama3.2-1b"))
    shape = ShapeConfig("tiny_train", seq_len=32, global_batch=2,
                        kind="train")
    settings = S.TrainSettings(remat="dots")
    specs = S.input_specs(cfg, shape, rules, settings)
    fn = S.make_train_step(cfg, settings, rules)
    with mesh:
        compiled = jax.jit(fn).lower(specs["params"], specs["opt_state"],
                                     specs["batch"], specs["step"]).compile()
    ca = cost_dict(compiled)
    assert ca["flops"] > 1e6
    coll = parse_collectives(compiled.as_text())
    assert set(coll) == {"all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute"}


def test_grad_compression_roundtrip_and_error_feedback():
    from repro.optim import (CompressionConfig, compress_gradients,
                             decompress_gradients, error_feedback_update)
    rng = np.random.RandomState(0)
    grads = {"a": jnp.asarray(rng.randn(64, 32).astype(np.float32)),
             "b": jnp.asarray(rng.randn(128).astype(np.float32) * 1e-3)}
    ccfg = CompressionConfig()
    q, s, pre = compress_gradients(grads, ccfg)
    deq = decompress_gradients(q, s)
    for k in grads:
        assert q[k].dtype == jnp.int8
        rel = float(jnp.max(jnp.abs(deq[k] - grads[k])) /
                    jnp.max(jnp.abs(grads[k])))
        assert rel < 0.02, f"{k}: int8 error {rel}"
    # error feedback: residual + dequantized == original
    resid = error_feedback_update(pre, deq)
    for k in grads:
        np.testing.assert_allclose(np.asarray(deq[k] + resid[k]),
                                   np.asarray(grads[k]), rtol=1e-5,
                                   atol=1e-6)


def test_data_pipeline_stateless_resume():
    from repro.data import DataConfig, SyntheticTokens
    cfg = DataConfig(batch=4, seq_len=16, vocab_size=100, seed=5)
    a = SyntheticTokens(cfg).batch_at(37)
    b = SyntheticTokens(cfg).batch_at(37)   # fresh instance, same step
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticTokens(cfg).batch_at(38)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_wsd_schedule_shape():
    from repro.optim import wsd_schedule
    mult = [float(wsd_schedule(s, warmup=10, stable=50, decay=20))
            for s in (0, 5, 10, 40, 60, 70, 80, 200)]
    assert mult[0] == 0.0 and mult[1] == pytest.approx(0.5)
    assert mult[2] == mult[3] == 1.0       # stable plateau
    assert mult[4] == 1.0                   # decay starts at 60
    assert 0.1 <= mult[5] < 1.0
    assert mult[7] == pytest.approx(0.1)    # decayed to final_frac
