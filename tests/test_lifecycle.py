"""Durable-state lifecycle: record framing, crash-consistency, quarantine.

Covers the CRC32 record format (torn tail vs. bit-rot classification, typed
`CorruptRecord` results), the `FileStore` crash-consistency fixes (a
zero-length / truncated state file reads as absent instead of raising, a
writer killed mid-`log`/`put_data` leaves no orphan temp files behind after
the startup sweep), per-volume quarantine counting, and the truncation
tombstone that keeps a late terminator from re-claiming a GC'd slot.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import textwrap

import pytest

from repro.core import Vote
from repro.core.lifecycle import (CorruptRecord, LifecycleConfig,
                                  RECORD_MAGIC, decode_record, encode_record)
from repro.core.storage import FileStore, MemoryStore


# ---------------------------------------------------------------------------
# Record framing
# ---------------------------------------------------------------------------
def test_frame_round_trip():
    blob = encode_record(Vote.VOTE_YES.value, "n2")
    assert blob.startswith(RECORD_MAGIC)
    assert decode_record(blob) == (Vote.VOTE_YES.value, "n2")


def test_torn_tail_classified_torn():
    blob = encode_record(Vote.COMMIT.value, "n0")
    for cut in (1, 3, len(blob) - len(RECORD_MAGIC) - 1):
        rec = decode_record(blob[:-cut], "p", "t")
        assert isinstance(rec, CorruptRecord)
        assert rec.torn, f"cut={cut} should classify as torn"


def test_bit_rot_classified_rot_not_torn():
    blob = bytearray(encode_record(Vote.COMMIT.value, "n0"))
    # Flip a body byte (past the header newline) — full length, bad CRC.
    body_start = blob.index(b"\n") + 1
    blob[body_start] ^= 0x40
    rec = decode_record(bytes(blob), "p", "t")
    assert isinstance(rec, CorruptRecord)
    assert not rec.torn
    assert not rec.is_decision()
    assert rec.value == "CORRUPT"


def test_empty_and_garbage_blobs_are_torn():
    for blob in (b"", b"crc1", b"crc1 zz zz\nxx", b"not a frame"):
        rec = decode_record(blob)
        assert isinstance(rec, CorruptRecord) and rec.torn


def test_lifecycle_config_coerce():
    assert LifecycleConfig.coerce(None) is None
    lc = LifecycleConfig.coerce(dict(gc=True, gc_interval_ms=10.0))
    assert lc.gc and lc.gc_interval_ms == 10.0 and lc.checksums
    assert LifecycleConfig.coerce(lc) is lc
    assert LifecycleConfig.coerce(lc.to_dict()).gc
    with pytest.raises(TypeError):
        LifecycleConfig.coerce(42)


# ---------------------------------------------------------------------------
# FileStore crash consistency
# ---------------------------------------------------------------------------
def test_zero_length_state_file_reads_absent(tmp_path):
    """Regression: a torn create used to raise IndexError from _read."""
    fs = FileStore(str(tmp_path))
    os.makedirs(os.path.join(str(tmp_path), "state", "p0"), exist_ok=True)
    open(os.path.join(str(tmp_path), "state", "p0", "t0"), "wb").close()
    assert fs.read_state("p0", "t0") is None
    assert fs.torn_records >= 1
    # The slot is claimable: LogOnce treats the torn create as absent.
    assert fs.log_once("p0", "t0", Vote.VOTE_YES, writer="p0") \
        == Vote.VOTE_YES


def test_truncated_framed_file_reads_absent(tmp_path):
    fs = FileStore(str(tmp_path),
                   lifecycle=LifecycleConfig(checksums=True))
    assert fs.log_once("p0", "t1", Vote.VOTE_YES, writer="p0") \
        == Vote.VOTE_YES
    path = os.path.join(str(tmp_path), "state", "p0", "t1")
    blob = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(blob[:-2])
    assert fs.read_state("p0", "t1") is None
    assert fs.torn_records >= 1


def test_bit_rot_reads_as_typed_corrupt_record(tmp_path):
    fs = FileStore(str(tmp_path),
                   lifecycle=LifecycleConfig(checksums=True))
    fs.log("p0", "t2", Vote.COMMIT, writer="p0")
    path = os.path.join(str(tmp_path), "state", "p0", "t2")
    blob = bytearray(open(path, "rb").read())
    blob[blob.index(b"\n") + 1] ^= 0x01
    with open(path, "wb") as f:
        f.write(bytes(blob))
    rec = fs.read_state("p0", "t2")
    assert isinstance(rec, CorruptRecord) and not rec.torn
    assert fs.corrupt_records == 1
    assert fs.scrub() == [path]        # scrub reports the rotted path


def test_repeated_rot_trips_quarantine(tmp_path):
    fs = FileStore(str(tmp_path),
                   lifecycle=LifecycleConfig(checksums=True,
                                             quarantine_threshold=3))
    for i in range(3):
        fs.log("p0", f"q{i}", Vote.COMMIT, writer="p0")
        path = os.path.join(str(tmp_path), "state", "p0", f"q{i}")
        blob = bytearray(open(path, "rb").read())
        blob[blob.index(b"\n") + 1] ^= 0x01
        with open(path, "wb") as f:
            f.write(bytes(blob))
        fs.read_state("p0", f"q{i}")
    assert fs.corrupt_records == 3
    assert fs.quarantines == 1


def test_orphan_tmp_files_swept_on_startup(tmp_path):
    sdir = os.path.join(str(tmp_path), "state", "p0")
    ddir = os.path.join(str(tmp_path), "data", "p0")
    os.makedirs(sdir)
    os.makedirs(ddir)
    for d in (sdir, ddir):
        with open(os.path.join(d, "x.tmp.123.456"), "wb") as f:
            f.write(b"partial")
    fs = FileStore(str(tmp_path))
    assert fs.orphans_swept == 2
    assert not [p for p in os.listdir(sdir) if ".tmp." in p]
    assert not [p for p in os.listdir(ddir) if ".tmp." in p]


_KILL_SCRIPT = textwrap.dedent("""\
    import os, sys, threading
    sys.path.insert(0, {src!r})
    from repro.core import Vote
    from repro.core.storage import FileStore

    root = {root!r}
    fs = FileStore(root)
    # Patch the atomic-replace fsync to signal readiness then hang, so the
    # parent can SIGKILL us with the temp file guaranteed on disk.
    real_fsync = os.fsync
    def hang(fd):
        real_fsync(fd)
        print("READY", flush=True)
        threading.Event().wait()
    os.fsync = hang
    if {mode!r} == "log":
        fs.log("p0", "victim", Vote.COMMIT, writer="p0")
    else:
        fs.put_data("p0", "shard", b"x" * 128)
""")


@pytest.mark.parametrize("mode", ["log", "put_data"])
def test_writer_killed_mid_write_leaves_no_orphans(tmp_path, mode):
    """Kill -9 a writer while its temp file exists; a fresh FileStore on
    the same root must sweep the orphan and read the volume cleanly."""
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    script = _KILL_SCRIPT.format(src=src, root=str(tmp_path), mode=mode)
    proc = subprocess.Popen([sys.executable, "-c", script],
                            stdout=subprocess.PIPE)
    assert proc.stdout.readline().strip() == b"READY"
    os.kill(proc.pid, signal.SIGKILL)
    proc.wait()
    leftovers = []
    for dirpath, _dirs, files in os.walk(str(tmp_path)):
        leftovers += [f for f in files if ".tmp." in f]
    assert leftovers, "test rig failed to strand a temp file"
    fs = FileStore(str(tmp_path))
    assert fs.orphans_swept == len(leftovers)
    for dirpath, _dirs, files in os.walk(str(tmp_path)):
        assert not [f for f in files if ".tmp." in f]
    # The interrupted write never reached its final path: absent, claimable.
    assert fs.read_state("p0", "victim") is None


# ---------------------------------------------------------------------------
# Truncation tombstones (MemoryStore; the sim stores delegate to it)
# ---------------------------------------------------------------------------
def test_gc_tombstone_blocks_late_terminator():
    ms = MemoryStore(lifecycle=LifecycleConfig(checksums=True, gc=True))
    ms.log_once("p0", "t", Vote.VOTE_YES, writer="p0")
    ms.log("p0", "t", Vote.COMMIT, writer="p0")
    assert ms.gc_pass() == 1
    assert ms.gc_log[0].decision == Vote.COMMIT.value
    # The slot is gone from the state map but a late CAS must NOT claim it.
    assert ms.log_once("p0", "t", Vote.ABORT, writer="n9") == Vote.COMMIT
    assert ms.read_state("p0", "t") == Vote.COMMIT
    assert ms.is_truncated(("p0", "t"))


def test_gc_refuses_unsettled_prefix():
    ms = MemoryStore(lifecycle=LifecycleConfig(checksums=True, gc=True))
    ms.log_once("p0", "a", Vote.VOTE_YES, writer="p0")   # in doubt
    ms.log_once("p0", "b", Vote.COMMIT, writer="p0")     # settled
    assert ms.gc_pass() == 0       # 'a' blocks the prefix
    assert ms.watermark_lag() == 2
    ms.log("p0", "a", Vote.ABORT, writer="p0")
    assert ms.gc_pass() == 2
    assert ms.watermark_lag() == 0


def test_decision_never_flips_in_log():
    """A zombie re-issue must not make a slot serve both terminal values."""
    ms = MemoryStore()
    assert ms.log("p0", "t", Vote.COMMIT, writer="p0") == Vote.COMMIT
    assert ms.log("p0", "t", Vote.ABORT, writer="p0") == Vote.COMMIT
    assert ms.read_state("p0", "t") == Vote.COMMIT


def test_decision_never_flips_in_filestore_log(tmp_path):
    fs = FileStore(str(tmp_path))
    assert fs.log("p0", "t", Vote.ABORT, writer="p0") == Vote.ABORT
    assert fs.log("p0", "t", Vote.COMMIT, writer="p0") == Vote.ABORT
    assert fs.read_state("p0", "t") == Vote.ABORT
