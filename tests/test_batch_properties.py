"""Property-based tests of group-commit invisibility.

Hypothesis drives random cluster sizes, vote assignments, batch windows and
interleavings through the deterministic sim and asserts the batching layer's
contract: batched and unbatched runs produce identical commit/abort outcomes
and identical ``writer_of`` winners per slot (absent failures, where only
timing may differ), and under arbitrary failure schedules batching never
breaks atomic-commit agreement.
"""
from __future__ import annotations

import pytest

from conftest import hypothesis_or_stubs

HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()

from repro.core import (AZURE_REDIS, BatchConfig, Cluster, Decision,
                        ProtocolConfig, Sim, SimStorage, TxnSpec, Vote)

HORIZON = 100_000.0


def run_cluster(n, votes_yes, seed, window_ms, fails=None, protocol="cornus"):
    sim = Sim()
    batch = BatchConfig(window_ms=window_ms, serial=window_ms > 0)
    storage = SimStorage(sim, AZURE_REDIS, seed=seed, batch=batch)
    nodes = [f"n{i}" for i in range(n)]
    cluster = Cluster(sim, storage, nodes, ProtocolConfig(protocol=protocol))
    spec = TxnSpec(txn_id="t", coordinator=nodes[0], participants=nodes,
                   votes={nd: v for nd, v in zip(nodes, votes_yes)})
    for nd, ft in zip(nodes, fails or [None] * n):
        if ft is not None:
            cluster.fail(nd, ft)
    cluster.run_txn(spec)
    sim.run(until=HORIZON)
    decisions = {node: s["decision"] for (node, t), s in cluster.local.items()
                 if t == "t" and s["decision"] is not None}
    slots = {k: (v, storage.store.writer_of(*k))
             for k, v in storage.store.snapshot().items()}
    return decisions, slots


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 6).flatmap(lambda n: st.tuples(
    st.just(n),
    st.lists(st.booleans(), min_size=n, max_size=n),
    st.integers(0, 10_000),
    st.floats(0.1, 5.0),
)))
def test_batched_equals_unbatched_without_failures(params):
    """No failures + generous timeouts: window=0 and window=w runs reach
    identical per-node decisions AND identical final log state — same
    value and same ``writer_of`` winner in every (partition, txn) slot."""
    n, votes, seed, window = params
    d0, s0 = run_cluster(n, votes, seed, 0.0)
    d1, s1 = run_cluster(n, votes, seed, window)
    assert d0 == d1
    assert s0 == s1


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 6).flatmap(lambda n: st.tuples(
    st.just(n),
    st.lists(st.booleans(), min_size=n, max_size=n),
    st.lists(st.one_of(st.none(), st.floats(0.0, 40.0)),
             min_size=n, max_size=n),
    st.integers(0, 10_000),
    st.floats(0.1, 5.0),
)))
def test_batched_cornus_agreement_under_failures(params):
    """AC1–AC3 survive batching under arbitrary failure schedules: no split
    brain, and never COMMIT without unanimous YES votes."""
    n, votes, fails, seed, window = params
    decisions, _ = run_cluster(n, votes, seed, window, fails=fails)
    assert len(set(decisions.values())) <= 1, f"split brain: {decisions}"
    if not all(votes):
        assert Decision.COMMIT not in decisions.values()


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 4),            # partitions
       st.integers(2, 12),           # racing writers
       st.integers(0, 10_000),
       st.floats(0.0, 5.0))
def test_concurrent_log_once_single_winner_any_window(n_parts, n_writers,
                                                      seed, window):
    """Random interleavings of racing LogOnce calls: for every slot, all
    callers observe ONE value, and it is exactly what the store holds."""
    import random as _random
    rng = _random.Random(seed)
    sim = Sim()
    batch = BatchConfig(window_ms=window, serial=True)
    storage = SimStorage(sim, AZURE_REDIS, seed=seed, batch=batch)
    calls = []   # (key, event, proposed)

    def caller(delay, part, txn, value, writer):
        def gen():
            yield sim.timeout(delay)
            got = yield storage.log_once(part, txn, value, writer=writer)
            return got
        calls.append(((part, txn), sim.process(gen()), value))

    for w in range(n_writers):
        part = f"p{rng.randrange(n_parts)}"
        txn = f"t{rng.randrange(3)}"
        value = Vote.VOTE_YES if rng.random() < 0.5 else Vote.ABORT
        caller(rng.random() * 10.0, part, txn, value, f"w{w}")
    sim.run()

    by_slot = {}
    for key, ev, _ in calls:
        by_slot.setdefault(key, []).append(ev.value)
    for key, observed in by_slot.items():
        assert len(set(observed)) == 1, f"slot {key} split: {observed}"
        assert storage.store.read_state(*key) == observed[0]
    # Accounting: round trips never exceed logical requests.
    assert storage.round_trips <= storage.requests


@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
def test_hypothesis_is_exercising_windows():
    """Meta-check: the strategies above include genuinely batched windows
    (guards against the suite silently degenerating to passthrough)."""
    d, s = run_cluster(3, [True, True, True], 0, 2.5)
    assert set(d.values()) == {Decision.COMMIT}
