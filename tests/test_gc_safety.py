"""Safety of the durable-state lifecycle: invisibility, AC-GC, anti-resurrection.

Three property families from the lifecycle design:

  1. Bit-invisibility — arming the lifecycle layer (checksums, and in
     fault-free runs even GC + scrub) changes NOTHING observable about a
     healthy benchmark run: same commits, same aborts, same latency, same
     certified history.
  2. AC-GC under chaos — random fault schedules that mix bit-rot, torn
     tails and GC-pulse truncation with crashes and network loss still
     certify AC1–AC3 + writer-of + recoverability + AC-GC with zero
     violations (regression seeds from development are pinned).
  3. Anti-resurrection — once the watermark truncates a slot cluster-wide,
     no scrub repair, state transfer, or late LogOnce can bring a
     conflicting value back: the GC journal's decision is the tombstone.

The @given properties run when hypothesis is installed; the seeded plain
tests below each family carry the same coverage example-based so the suite
is meaningful either way (see conftest.hypothesis_or_stubs).
"""
from __future__ import annotations

import random

import pytest

from conftest import hypothesis_or_stubs

from repro.core import AZURE_REDIS, Vote
from repro.core.lifecycle import LifecycleConfig
from repro.core.protocols import registered_protocols
from repro.core.storage import MemoryStore, ReplicatedStore
from repro.txn import BenchConfig, YCSBWorkload, run_bench

from benchmarks.chaos import run_one as chaos_run_one

HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()

ARMED = dict(checksums=True, gc=True, scrub=True,
             gc_interval_ms=25.0, scrub_interval_ms=40.0)
# The lifecycle's own observability surface: these move when it is armed
# (watermark_lag counts retained slots); everything else must not.
LIFECYCLE_KEYS = frozenset({"gc_truncations", "watermark_lag",
                            "scrub_repairs", "quarantines",
                            "corrupt_records", "torn_records"})


def _foreground(res) -> dict:
    return {k: v for k, v in res.breakdown().items()
            if k not in LIFECYCLE_KEYS}


def _bench(proto: str, lifecycle, seed: int = 5, horizon_ms: float = 200.0,
           replication: int = 1):
    def wl(nodes, seed):
        return YCSBWorkload(nodes, seed=seed)
    cfg = BenchConfig(protocol=proto, n_nodes=4, threads_per_node=2,
                      horizon_ms=horizon_ms, seed=seed,
                      replication=replication, record_history=True,
                      lifecycle=lifecycle)
    return run_bench(wl, AZURE_REDIS, cfg)


# ---------------------------------------------------------------------------
# 1. Bit-invisibility
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("proto", registered_protocols())
def test_checksums_only_is_bit_invisible(proto):
    """lifecycle=None vs checksums-only framing: identical breakdown."""
    off = _bench(proto, None)
    framed = _bench(proto, dict(checksums=True))
    assert _foreground(framed) == _foreground(off)
    assert framed.corrupt_records == 0 and framed.torn_records == 0


@pytest.mark.parametrize("proto", ["cornus", "2pc", "cl"])
@pytest.mark.parametrize("replication", [1, 3])
def test_armed_lifecycle_invisible_on_healthy_runs(proto, replication):
    """Full GC + scrub on a fault-free run: the foreground outcome is
    untouched; only the maintenance counters move.  At R=1 the armed run
    is result-identical; at R=3 the background cadence perturbs event-tie
    ordering in the scheduler, so the bound is a tight tolerance instead
    of exact equality (the bit-exactness contract is lifecycle=OFF, which
    the bench gates pin)."""
    off = _bench(proto, None, replication=replication)
    on = _bench(proto, dict(ARMED), replication=replication)
    if replication == 1:
        assert on.commits == off.commits
        assert on.aborts == off.aborts
        assert on.throughput_tps == off.throughput_tps
        assert on.avg_latency_ms == off.avg_latency_ms
        assert on.scrub_repairs == 0   # single volume: nothing diverges
    else:
        assert abs(on.commits - off.commits) <= max(3, off.commits * 0.05)
    assert on.gaveups == off.gaveups == 0
    assert on.violations == 0 and off.violations == 0
    # Scrub may catch up stale minority copies at R>1 (quorum writes skip
    # a replica legitimately) — but never quarantines a healthy volume.
    assert on.quarantines == 0
    assert on.gc_truncations > 0       # GC ran and settled txns
    assert on.corrupt_records == 0 and on.torn_records == 0


if HAS_HYPOTHESIS:
    @given(st.integers(min_value=0, max_value=10_000),
           st.sampled_from(registered_protocols()))
    @settings(max_examples=10, deadline=None)
    def test_prop_checksums_invisible(seed, proto):
        off = _bench(proto, None, seed=seed, horizon_ms=120.0)
        framed = _bench(proto, dict(checksums=True), seed=seed,
                        horizon_ms=120.0)
        assert _foreground(framed) == _foreground(off)


# ---------------------------------------------------------------------------
# 2. AC-GC under random chaos + truncation (the "rot" fault mix)
# ---------------------------------------------------------------------------
# Regression cells from development: seeds that exposed the truncation/
# recovery race (cornus R3) and the zombie decision re-issue (2pc R1),
# plus generic coverage of both protocols at both replication levels.
ROT_CELLS = [
    ("cornus", 3, 0), ("cornus", 3, 3), ("cornus", 3, 5),
    ("2pc", 1, 8),
    ("cornus", 1, 2), ("2pc", 3, 1),
]


@pytest.mark.parametrize("proto,replication,seed", ROT_CELLS)
def test_rot_mix_certifies_zero_violations(proto, replication, seed):
    res, _sched, _config = chaos_run_one(proto, "rot", replication, seed,
                                         horizon_ms=300.0)
    assert res.violations == 0, res.violation_details
    assert res.commits > 0                   # chaos may slow, not stop
    assert res.gc_truncations > 0            # truncation pulses did fire


@pytest.mark.slow
@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("proto", ["cornus", "2pc"])
@pytest.mark.parametrize("replication", [1, 3])
def test_rot_mix_sweep_slow(proto, replication, seed):
    res, _sched, _config = chaos_run_one(proto, "rot", replication, seed,
                                         horizon_ms=300.0)
    assert res.violations == 0, res.violation_details


if HAS_HYPOTHESIS:
    @given(st.integers(min_value=0, max_value=100_000),
           st.sampled_from(["cornus", "2pc"]),
           st.sampled_from([1, 3]))
    @settings(max_examples=8, deadline=None)
    def test_prop_rot_mix_certifies(seed, proto, replication):
        res, _s, _c = chaos_run_one(proto, "rot", replication, seed,
                                    horizon_ms=200.0)
        assert res.violations == 0, res.violation_details


# ---------------------------------------------------------------------------
# 3. Anti-resurrection
# ---------------------------------------------------------------------------
def _settled_store(lifecycle=None) -> ReplicatedStore:
    """R=3 threaded store with a few settled txns per partition."""
    lc = lifecycle or LifecycleConfig(**ARMED)
    store = ReplicatedStore(3, seed=1, lifecycle=lc)
    for p in ("p0", "p1"):
        for t in range(4):
            store.log_once(p, f"t{t}", Vote.VOTE_YES, writer=p)
            store.log(p, f"t{t}", Vote.COMMIT if t % 2 else Vote.ABORT,
                      writer=p)
    return store


def test_state_transfer_never_resurrects_truncated_slots():
    store = _settled_store()
    assert store.gc_pass() == 8
    truncated = list(store._gc_index)
    assert truncated
    # Plant zombie copies on replica 2 — a rejoiner whose disk still holds
    # (or re-acquired) pre-truncation slots, with the WRONG decision.
    for k in truncated:
        store.replicas[2].repair(k, Vote.COMMIT, 99, True)
    store._state_transfer(2, store._membership.replica_ids)
    for k in truncated:
        assert k not in store.replicas[2].keys()
        # The journal, not the zombie, answers late ops.
        want = Vote(store._gc_index[k].decision)
        assert store.read_state(*k) == want
        assert store.log_once(*k, Vote.COMMIT, writer="n9") == want


def test_scrub_truncates_resurrected_copies_and_repairs_rot():
    store = _settled_store()
    store.gc_pass()
    zombie = next(iter(store._gc_index))
    store.replicas[0].repair(zombie, Vote.COMMIT, 99, True)
    # Rot one RETAINED slot on replica 1 so the scrubber has real work.
    store.log_once("p2", "live", Vote.VOTE_YES, writer="p2")
    live = ("p2", "live")
    assert store.replicas[1].corrupt_slot(live)
    store.scrub_pass()
    assert zombie not in store.replicas[0].keys()
    assert store.replicas[1].corrupt_keys() == []
    assert store.scrub_repairs >= 1
    assert store.read_state("p2", "live") == Vote.VOTE_YES


def test_quarantine_refreshes_volume_from_peers():
    store = _settled_store(LifecycleConfig(checksums=True, scrub=True,
                                           quarantine_threshold=3))
    keys = [("p0", f"t{t}") for t in range(3)]
    for k in keys:
        assert store.replicas[2].corrupt_slot(k)
    store.scrub_pass()
    assert store.quarantines == 1
    assert store.replicas[2].corrupt_keys() == []
    for p, t in keys:
        assert store.read_state(p, t) is not None


def test_memorystore_gc_interleaving_invariants():
    """Random op/GC interleavings on the single-volume store: a decided
    slot always answers its decision (before and after truncation), and
    truncation never lets a slot be re-claimed or flipped."""
    for seed in range(10):
        rng = random.Random(seed)
        ms = MemoryStore(lifecycle=LifecycleConfig(checksums=True, gc=True))
        decided = {}
        for step in range(120):
            p = f"p{rng.randrange(3)}"
            t = f"t{rng.randrange(20)}"
            op = rng.random()
            if op < 0.4:
                ms.log_once(p, t, Vote.VOTE_YES, writer=p)
            elif op < 0.7:
                # One decision per TXN id (atomic commit): every slot of a
                # txn terminates the same way, as the protocols guarantee.
                d = Vote.COMMIT if int(t[1:]) % 2 else Vote.ABORT
                got = ms.log(p, t, d, writer=p)
                decided.setdefault((p, t), got)
            elif op < 0.8:
                ms.gc_pass()
            else:
                ms.read_state(p, t)
            for k, want in decided.items():
                assert ms.read_state(*k) == want, (seed, step, k)
        ms.gc_pass()
        for k, want in decided.items():
            assert ms.read_state(*k) == want
            assert ms.log_once(*k, Vote.VOTE_YES, writer="z") == want


if HAS_HYPOTHESIS:
    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 19),
                              st.integers(0, 99)),
                    min_size=1, max_size=200))
    @settings(max_examples=50, deadline=None)
    def test_prop_gc_never_loses_decisions(ops):
        ms = MemoryStore(lifecycle=LifecycleConfig(checksums=True, gc=True))
        decided = {}
        for pi, ti, r in ops:
            p, t = f"p{pi}", f"t{ti}"
            if r < 40:
                ms.log_once(p, t, Vote.VOTE_YES, writer=p)
            elif r < 70:
                d = Vote.COMMIT if ti % 2 else Vote.ABORT
                decided.setdefault((p, t), ms.log(p, t, d, writer=p))
            else:
                ms.gc_pass()
        ms.gc_pass()
        for k, want in decided.items():
            assert ms.read_state(*k) == want
            assert ms.log_once(*k, Vote.VOTE_YES, writer="z") == want
