"""Erasure-coded checkpoint shards: codec, commit placement, restore.

The headline property: a (k=2, n=5) epoch restores from TWO surviving
replica volumes — a *minority* — including when volumes keep dying in the
middle of the restore's per-host reads.
"""
from __future__ import annotations

import itertools
import random
import threading

import pytest

from conftest import hypothesis_or_stubs

HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()

from repro.ckpt.commit import CornusCheckpointer
from repro.ckpt.restore import fetch_payloads, latest_committed
from repro.ckpt.shards import ec_decode, ec_encode
from repro.core.control import QuorumUnavailable
from repro.core.state import Decision
from repro.core.storage import MemoryStore, ReplicatedStore


# ---------------------------------------------------------------------------
# Codec
# ---------------------------------------------------------------------------
def test_every_k_subset_decodes():
    payload = bytes(range(256)) * 5 + b"tail"
    for k, n in [(1, 3), (2, 5), (3, 4), (4, 6)]:
        frags = ec_encode(payload, k, n)
        assert len(frags) == n
        for subset in itertools.combinations(frags, k):
            assert ec_decode(subset) == payload, (k, n)


def test_codec_edge_payloads():
    for payload in (b"", b"x", b"ab" * 1000):
        frags = ec_encode(payload, 3, 5)
        assert ec_decode(frags[2:]) == payload


def test_storage_overhead_is_n_over_k():
    payload = bytes(1200)
    frags = ec_encode(payload, 3, 5)
    body = len(frags[0]) - 15            # header is 15 bytes
    assert body == 400                   # ceil(1200/3) per fragment


def test_codec_rejects_bad_inputs():
    frags = ec_encode(b"hello world", 3, 5)
    with pytest.raises(ValueError, match="3 distinct"):
        ec_decode(frags[:2])
    with pytest.raises(ValueError, match="3 distinct"):
        ec_decode([frags[0], frags[0], frags[0]])   # duplicates don't count
    with pytest.raises(ValueError, match="magic"):
        ec_decode([b"XXXX" + frags[0][4:]])
    with pytest.raises(ValueError, match="truncated"):
        ec_decode([frags[0][:4]])
    other = ec_encode(b"hello world", 2, 5)
    with pytest.raises(ValueError, match="geometries"):
        ec_decode([frags[0], other[1], frags[2]])
    with pytest.raises(ValueError):
        ec_encode(b"x", 4, 3)            # k > n


@settings(max_examples=40, deadline=None)
@given(st.binary(max_size=2048), st.integers(1, 6), st.integers(0, 5),
       st.integers(0, 10_000))
def test_codec_roundtrip_property(payload, k, extra, seed):
    n = k + extra
    frags = ec_encode(payload, k, n)
    rng = random.Random(seed)
    keep = rng.sample(frags, rng.randint(k, n))
    assert ec_decode(keep) == payload


# ---------------------------------------------------------------------------
# Commit placement + restore under volume loss
# ---------------------------------------------------------------------------
def _commit_epoch(store, hosts, payloads, epoch, ec_k):
    """All hosts vote concurrently (an epoch only commits collectively)."""
    cks = {h: CornusCheckpointer(store, h, hosts, ec_k=ec_k,
                                 straggler_timeout_s=5.0,
                                 poll_interval_s=0.005) for h in hosts}
    outs = {}
    threads = [threading.Thread(
        target=lambda h=h: outs.update({h: cks[h].save(epoch, payloads[h])}),
        daemon=True) for h in hosts]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return outs


def test_ec_epoch_commits_and_places_fragments_per_volume():
    store = ReplicatedStore(n_replicas=5)
    hosts = ["h0", "h1"]
    payloads = {h: random.Random(h).randbytes(2000) for h in hosts}
    outs = _commit_epoch(store, hosts, payloads, 3, ec_k=2)
    assert all(o.decision == Decision.COMMIT for o in outs.values())
    assert latest_committed(store, hosts) == 3
    # One distinct fragment per replica volume, none holds the payload.
    name = "e000000000003.ec"
    bodies = {r.index: r.get_data("h0", name)[1] for r in store.replicas}
    assert len(set(bodies.values())) == 5
    assert all(len(b) < len(payloads["h0"]) for b in bodies.values())


def test_restore_from_minority_with_volumes_dying_mid_restore():
    store = ReplicatedStore(n_replicas=5)
    hosts = ["h0", "h1", "h2"]
    payloads = {h: random.Random(h).randbytes(3000) for h in hosts}
    _commit_epoch(store, hosts, payloads, 7, ec_k=2)

    # Kill THREE of five volumes between the first and second host read:
    # the rest of the restore runs from a 2/5 minority.
    def after_host(h):
        if h == "h0":
            for i in (0, 1, 2):
                store.replicas[i].drop_data()

    got = fetch_payloads(store, hosts, 7, after_host=after_host)
    assert got == payloads


def test_restore_fails_below_k_surviving_fragments():
    store = ReplicatedStore(n_replicas=5)
    hosts = ["h0"]
    payloads = {"h0": b"q" * 1000}
    _commit_epoch(store, hosts, payloads, 1, ec_k=3)
    for i in (0, 1, 4):
        store.replicas[i].drop_data()    # 2 fragments < k=3 survive
    assert fetch_payloads(store, hosts, 1) == {}


def test_vote_needs_k_placeable_fragments():
    store = ReplicatedStore(n_replicas=5)
    ck = CornusCheckpointer(store, "h0", ["h0"], ec_k=3)
    for i in range(3):
        store.fail_replica(i)            # 2 alive < k=3
    with pytest.raises(QuorumUnavailable):
        ck.vote(0, b"payload")


def test_ec_requires_replicated_store():
    with pytest.raises(ValueError, match="replicated"):
        CornusCheckpointer(MemoryStore(), "h0", ["h0"], ec_k=2)


def test_plain_epochs_still_restore_alongside_ec():
    """Plain and EC epochs coexist: restore tries the plain path first."""
    store = ReplicatedStore(n_replicas=5)
    hosts = ["h0"]
    _commit_epoch(store, hosts, {"h0": b"old" * 100}, 1, ec_k=None)
    _commit_epoch(store, hosts, {"h0": b"new" * 100}, 2, ec_k=2)
    assert fetch_payloads(store, hosts, 1) == {"h0": b"old" * 100}
    assert fetch_payloads(store, hosts, 2) == {"h0": b"new" * 100}


def test_restore_params_tree_from_minority():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import numpy as np
    from repro.ckpt.restore import restore_params
    from repro.ckpt.shards import pack_tree, partition_leaves

    params = {"w": jnp.arange(12.0).reshape(3, 4),
              "b": jnp.ones((7,)), "scale": jnp.asarray(2.5)}
    hosts = ["h0", "h1"]
    buckets = partition_leaves(params, len(hosts))
    payloads = {h: pack_tree(params, keys)
                for h, keys in zip(hosts, buckets)}
    store = ReplicatedStore(n_replicas=5)
    _commit_epoch(store, hosts, payloads, 9, ec_k=2)
    for i in (1, 2, 3):
        store.replicas[i].drop_data()
    template = jax.tree_util.tree_map(jnp.zeros_like, params)
    got = restore_params(store, hosts, 9, template)
    for key in params:
        np.testing.assert_array_equal(np.asarray(got[key]),
                                      np.asarray(params[key]))
