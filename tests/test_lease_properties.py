"""Property-based tests of lease semantics under failover schedules.

Hypothesis drives random replica fail/recover schedules, lease terms, and
batch windows through the deterministic sim and asserts the lease layer's
contract: batched and unbatched runs reach identical commit/abort
decisions, AC1-AC3 hold whatever the failover/lease-expiry interleaving,
every slot decides exactly once, and exactly one leaseholder serves
fast-path ops per epoch.
"""
from __future__ import annotations

import pytest

from conftest import hypothesis_or_stubs

HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()

from repro.core import (AZURE_REDIS, BatchConfig, Cluster, Decision,
                        ProtocolConfig, ReplicatedSimStorage, Sim, TxnSpec,
                        Vote)

HORIZON = 500_000.0

# One replica outage with guaranteed recovery: quorum returns eventually,
# so every run terminates and decisions are vote-determined (the executor
# timeouts are set far above any outage + lease-renewal stall).
outage = st.tuples(st.integers(0, 2), st.floats(0.0, 60.0),
                   st.floats(60.0, 400.0))


def run_cluster(n, votes_yes, seed, window_ms, fails, lease_ms,
                protocol="cornus"):
    sim = Sim()
    batch = BatchConfig(window_ms=window_ms, serial=window_ms > 0)
    storage = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3,
                                   seed=seed, batch=batch,
                                   lease_ms=lease_ms)
    for idx, at, rec in fails:
        storage.fail_replica(idx, at, rec)
    nodes = [f"n{i}" for i in range(n)]
    tmo = 5_000.0
    cluster = Cluster(sim, storage, nodes,
                      ProtocolConfig(protocol=protocol,
                                     vote_timeout_ms=tmo,
                                     decision_timeout_ms=tmo,
                                     votereq_timeout_ms=tmo,
                                     termination_retry_ms=tmo,
                                     coop_retry_ms=tmo))
    spec = TxnSpec(txn_id="t", coordinator=nodes[0], participants=nodes,
                   votes={nd: v for nd, v in zip(nodes, votes_yes)})
    cluster.run_txn(spec)
    sim.run(until=HORIZON)
    decisions = {node: s["decision"]
                 for (node, t), s in cluster.local.items()
                 if t == "t" and s["decision"] is not None}
    return decisions, storage


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 5).flatmap(lambda n: st.tuples(
    st.just(n),
    st.lists(st.booleans(), min_size=n, max_size=n),
    st.integers(0, 10_000),
    st.floats(0.1, 5.0),
    st.lists(outage, max_size=2),
    st.sampled_from([20.0, 80.0, 200.0]),
)))
def test_batched_equals_unbatched_decisions_under_failover(params):
    """Across random failover + lease-expiry schedules (with generous
    protocol timeouts so outages stall ops rather than abort txns):
    window=0 and window=w runs reach IDENTICAL per-node decisions, and
    both satisfy AC1-AC3."""
    n, votes, seed, window, fails, lease_ms = params
    d0, _ = run_cluster(n, votes, seed, 0.0, fails, lease_ms)
    d1, _ = run_cluster(n, votes, seed, window, fails, lease_ms)
    assert d0 == d1, (d0, d1)
    for d in (d0, d1):
        assert len(set(d.values())) <= 1, f"split brain: {d}"
        if not all(votes):
            assert Decision.COMMIT not in d.values()
        else:
            assert set(d.values()) <= {Decision.COMMIT}


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000),
       st.lists(outage, max_size=3),
       st.floats(0.0, 5.0),
       st.sampled_from([15.0, 60.0, 200.0]),
       st.lists(st.floats(0.0, 100.0), min_size=2, max_size=8))
def test_single_winner_per_slot_across_epochs(seed, fails, window,
                                              lease_ms, delays):
    """Racing writers on one slot under random failover + lease-expiry
    schedules: every caller observes the SAME first value whatever epoch
    served it, and the merged replica state agrees."""
    sim = Sim()
    batch = BatchConfig(window_ms=window, serial=window > 0)
    storage = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3,
                                   seed=seed, batch=batch,
                                   lease_ms=lease_ms)
    for idx, at, rec in fails:
        storage.fail_replica(idx, at, rec)
    results = {}

    def proposer(name, value, delay):
        def gen():
            yield sim.timeout(delay)
            results[name] = yield storage.log_once("p0", "t", value,
                                                   writer=name)
        sim.process(gen())

    for w, delay in enumerate(delays):
        value = Vote.VOTE_YES if w % 2 == 0 else Vote.ABORT
        proposer(f"w{w}", value, delay)
    sim.run(until=HORIZON)
    assert len(results) == len(delays), results
    assert len(set(results.values())) == 1, results
    assert storage.snapshot().get(("p0", "t")) == \
        next(iter(results.values()))


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000),
       st.lists(outage, max_size=3),
       st.sampled_from([15.0, 60.0, 200.0]),
       st.floats(0.0, 4.0))
def test_one_leaseholder_serves_fast_path_per_epoch(seed, fails, lease_ms,
                                                    window):
    """Observability invariant: epochs strictly increase and, per epoch,
    exactly one holder ever serves fast-path ops."""
    sim = Sim()
    batch = BatchConfig(window_ms=window, serial=window > 0)
    storage = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3,
                                   seed=seed, batch=batch,
                                   lease_ms=lease_ms)
    for idx, at, rec in fails:
        storage.fail_replica(idx, at, rec)

    def writers():
        for i in range(12):
            def gen(i=i):
                yield sim.timeout(i * 25.0)
                yield storage.log_once("p", f"t{i}", Vote.VOTE_YES,
                                       writer="p")
            sim.process(gen())

    writers()
    sim.run(until=HORIZON)
    epochs = [e for e, _h, _t in storage.lease_history]
    assert epochs == sorted(set(epochs)), epochs
    for epoch, by_holder in storage.fast_ops_by_epoch.items():
        assert len(by_holder) == 1, \
            f"epoch {epoch} served by {sorted(by_holder)}"


@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
def test_hypothesis_is_exercising_failovers():
    """Meta-check: the strategies above include genuinely failing leaders
    (guards against silently degenerating to the no-failure path)."""
    d, storage = run_cluster(3, [True, True, True], 0, 2.0,
                             [(0, 0.0, 300.0)], 50.0)
    assert set(d.values()) == {Decision.COMMIT}
    assert storage.lease_acquisitions >= 1
