"""Paper-claim band tests: each benchmark's headline number must stay inside
the band the paper reports (EXPERIMENTS.md §Paper-validation).

Shorter horizons than benchmarks/ for CI speed; bands are correspondingly
loose but still falsifiable.
"""
import pytest

from repro.core import (AZURE_BLOB, AZURE_BLOB_SEPARATE_ACL, AZURE_REDIS,
                        SLOW_REDIS)
from repro.txn import BenchConfig, YCSBWorkload, run_bench

HORIZON = 500.0


def ycsb(theta=0.0, keys=10_000, read_ratio=0.5):
    return lambda nodes, seed: YCSBWorkload(
        nodes, theta=theta, keys_per_partition=keys, read_ratio=read_ratio,
        seed=seed)


def bench(proto, model, wl=None, elr=False, n=4):
    return run_bench(wl or ycsb(), model,
                     BenchConfig(protocol=proto, n_nodes=n,
                                 horizon_ms=HORIZON, elr=elr, seed=9))


def speedup(model, wl=None):
    c = bench("cornus", model, wl)
    t = bench("2pc", model, wl)
    assert c.commits > 50 and t.commits > 50
    return t.avg_latency_ms / c.avg_latency_ms


def test_fig5_speedup_band():
    """Blob speedup in (1.2, 1.9]; Redis smaller but > 1.05."""
    assert 1.2 < speedup(AZURE_BLOB) < 1.95
    assert 1.05 < speedup(AZURE_REDIS) < 1.5


def test_fig5_separate_acl_no_gain():
    s = speedup(AZURE_BLOB_SEPARATE_ACL)
    assert 0.9 < s < 1.15, f"separate-ACL blob should show ~no gain, got {s}"


def test_fig6_readonly_monotone():
    lo = speedup(AZURE_BLOB, ycsb(read_ratio=0.5))            # ~0% RO txns
    hi = speedup(AZURE_BLOB, ycsb(read_ratio=0.8 ** (1 / 16)))  # ~80% RO
    assert lo > hi - 0.05, (lo, hi)
    assert lo > 1.2


def test_fig7_contention_shrinks_gain():
    lo = speedup(AZURE_REDIS, ycsb(theta=0.0, keys=1000))
    hi = speedup(AZURE_REDIS, ycsb(theta=0.9, keys=1000))
    assert hi < lo + 0.05, (lo, hi)
    assert 0.9 < hi < 1.3   # abort-dominated regime: gap nearly closes


def test_fig10_cl_ordering():
    """cornus < CL < 2PC on slow storage."""
    r = {p: run_bench(ycsb(), SLOW_REDIS,
                      BenchConfig(protocol=p, n_nodes=4, horizon_ms=6000.0,
                                  seed=9))
         for p in ("cornus", "cl", "2pc")}
    assert r["cornus"].avg_latency_ms < r["cl"].avg_latency_ms \
        < r["2pc"].avg_latency_ms


def test_fig9_elr():
    cfgs = dict(wl=ycsb(theta=0.9, keys=100))
    base = run_bench(cfgs["wl"], AZURE_REDIS,
                     BenchConfig(protocol="cornus", n_nodes=4,
                                 horizon_ms=800.0, seed=5))
    elr = run_bench(cfgs["wl"], AZURE_REDIS,
                    BenchConfig(protocol="cornus", n_nodes=4,
                                horizon_ms=800.0, seed=5, elr=True))
    assert elr.throughput_tps > base.throughput_tps * 1.02


def test_fig8_bounded_termination():
    from repro.core import Cluster, ProtocolConfig, Sim, SimStorage, TxnSpec
    sim = Sim()
    nodes = [f"n{i}" for i in range(8)]
    cl = Cluster(sim, SimStorage(sim, AZURE_REDIS, seed=1), nodes,
                 ProtocolConfig(protocol="cornus"))
    cl.fail("n0", 1.0)
    cl.run_txn(TxnSpec(txn_id="t", coordinator="n0", participants=nodes))
    sim.run(until=60_000)
    times = [o.termination_ms for o in cl.outcomes.values()
             if o.ran_termination and o.termination_ms > 0]
    assert times, "termination protocol never ran"
    assert max(times) < 25.0, f"unbounded-looking termination: {max(times)}"


def test_table3_rtt_model():
    from repro.core import rtt_table
    want = {"2pc": 5.0, "cornus": 3.0, "cornus-opt1": 2.5, "2pc-coloc": 3.0,
            "cornus-coloc": 2.0, "paxos-commit": 1.5}
    got = {k: v["total"] for k, v in rtt_table().items()}
    assert got == want
