"""Property-based tests of the paper's correctness claims (§3.5, AC1–AC5).

Hypothesis drives randomized failure schedules, vote assignments, latency
seeds and cluster sizes through the deterministic discrete-event sim, and we
assert the five atomic-commit properties plus Lemma 1 (irreversible global
decision) and the paper's Theorem-4 strengthening of AC5 (bounded-time,
recovery-free termination) for Cornus.
"""
from __future__ import annotations

import pytest

# Without hypothesis (dev-only dependency) the @given tests are skipped but
# the module still collects, so the plain example-based tests keep running.
from conftest import hypothesis_or_stubs

HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()

from repro.core import (AZURE_REDIS, Cluster, Decision, ProtocolConfig, Sim,
                        SimStorage, TxnSpec, Vote, global_decision)

HORIZON = 100_000.0


def build(protocol: str, n: int, seed: int, rtt: float = 0.5):
    sim = Sim()
    storage = SimStorage(sim, AZURE_REDIS, seed=seed)
    nodes = [f"n{i}" for i in range(n)]
    cfg = ProtocolConfig(protocol=protocol, rtt_ms=rtt)
    return sim, storage, Cluster(sim, storage, nodes, cfg), nodes


def run_schedule(protocol, n, votes_yes, fail_times, seed,
                 recover_after=2_000.0):
    """Run one txn under a failure schedule; recovered nodes re-resolve."""
    sim, storage, cluster, nodes = build(protocol, n, seed)
    spec = TxnSpec(
        txn_id="t", coordinator=nodes[0], participants=nodes,
        votes={nd: v for nd, v in zip(nodes, votes_yes)})
    for nd, ft in zip(nodes, fail_times):
        if ft is not None:
            cluster.fail(nd, ft, recover_at=recover_after)
    cluster.run_txn(spec)
    sim.run(until=recover_after)
    # Recovery pass (Table 1/2 "During Recovery"): every failed node that
    # recovers resolves the txn from its log / termination protocol.
    for nd, ft in zip(nodes, fail_times):
        if ft is not None:
            cluster.recover_txn(spec, nd)
    sim.run(until=HORIZON)
    return sim, storage, cluster, spec


def decided(cluster, txn="t"):
    out = {}
    for (node, t), st_ in cluster.local.items():
        if t == txn and st_["decision"] is not None:
            out[node] = st_["decision"]
    return out


schedule = st.integers(2, 6).flatmap(lambda n: st.tuples(
    st.just(n),
    st.lists(st.booleans(), min_size=n, max_size=n),
    st.lists(st.one_of(st.none(), st.floats(0.0, 40.0)),
             min_size=n, max_size=n),
    st.integers(0, 10_000),
))


@settings(max_examples=120, deadline=None)
@given(schedule)
def test_cornus_ac1_ac2_agreement(params):
    """AC1: every reached decision equals the global decision; AC2/Lemma 1:
    the storage-level global decision is never contradicted."""
    n, votes, fails, seed = params
    sim, storage, cluster, spec = run_schedule("cornus", n, votes, fails, seed)
    decisions = decided(cluster)
    gd = global_decision(
        {p: storage.store.read_state(p, "t") for p in spec.participants},
        spec.participants)
    assert len(set(decisions.values())) <= 1, f"split brain: {decisions}"
    if decisions:
        d = next(iter(decisions.values()))
        assert gd != Decision.UNDETERMINED
        assert d == gd, f"local {d} != global {gd}"


@settings(max_examples=120, deadline=None)
@given(schedule)
def test_cornus_ac3_no_commit_without_unanimous_yes(params):
    n, votes, fails, seed = params
    _, _, cluster, _ = run_schedule("cornus", n, votes, fails, seed)
    if not all(votes):
        assert Decision.COMMIT not in decided(cluster).values()


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10_000))
def test_cornus_ac4_commit_when_no_failures(n, seed):
    """All yes + no failures ⇒ COMMIT at every node."""
    sim, storage, cluster, spec = run_schedule(
        "cornus", n, [True] * n, [None] * n, seed)
    decisions = decided(cluster)
    assert len(decisions) == n
    assert set(decisions.values()) == {Decision.COMMIT}


@settings(max_examples=80, deadline=None)
@given(schedule)
def test_cornus_ac5_bounded_termination_of_survivors(params):
    """Theorem 4: any compute-layer failures — surviving nodes decide without
    waiting for failed nodes to recover (recovery disabled here)."""
    n, votes, fails, seed = params
    sim, storage, cluster, nodes = build("cornus", n, seed)
    spec = TxnSpec(txn_id="t", coordinator=nodes[0], participants=nodes,
                   votes={nd: v for nd, v in zip(nodes, votes)})
    for nd, ft in zip(nodes, fails):
        if ft is not None:
            cluster.fail(nd, ft)  # never recovers
    cluster.run_txn(spec)
    sim.run(until=HORIZON)
    survivors = [nd for nd, ft in zip(nodes, fails) if ft is None]
    decisions = decided(cluster)
    for s in survivors:
        assert s in decisions, f"survivor {s} undecided (blocked!)"
    assert len({decisions[s] for s in survivors}) <= 1


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 6),
       st.lists(st.booleans(), min_size=2, max_size=6),
       st.integers(0, 10_000))
def test_2pc_agreement_no_failures(n, votes, seed):
    """The 2PC baseline is also a correct AC protocol absent failures."""
    votes = (votes + [True] * n)[:n]
    sim, storage, cluster, spec = run_schedule(
        "2pc", n, votes, [None] * n, seed)
    decisions = decided(cluster)
    assert len(decisions) == n
    expect = Decision.COMMIT if all(votes) else Decision.ABORT
    assert set(decisions.values()) == {expect}


def test_2pc_blocks_on_coordinator_failure_cornus_does_not():
    """The paper's headline fault case (Fig 2b vs Fig 4a)."""
    for proto, should_block in (("2pc", True), ("cornus", False)):
        sim, storage, cluster, nodes = build(proto, 4, seed=7)
        spec = TxnSpec(txn_id="t", coordinator=nodes[0], participants=nodes)
        # Coordinator dies after collecting votes, before any decision msg.
        cluster.fail(nodes[0], 3.0)
        cluster.run_txn(spec)
        sim.run(until=50_000.0)
        survivors = nodes[1:]
        got = decided(cluster)
        if should_block:
            assert all(s not in got for s in survivors)
            assert any(cluster.blocked.get(("t", s)) for s in survivors)
        else:
            assert all(got.get(s) == Decision.COMMIT for s in survivors)


def test_termination_writes_abort_on_behalf_of_silent_participant():
    """Fig 4b: participant dies before logging its vote → coordinator's
    termination protocol CAS-forces ABORT into its log slot."""
    sim, storage, cluster, nodes = build("cornus", 3, seed=3)
    spec = TxnSpec(txn_id="t", coordinator=nodes[0], participants=nodes)
    cluster.fail("n2", 0.05)  # dies before logging anything
    done = cluster.run_txn(spec)
    sim.run(until=50_000.0)
    assert done.value.decision == Decision.ABORT
    assert storage.store.read_state("n2", "t") == Vote.ABORT
    assert storage.store.writer_of("n2", "t") in ("n0", "n1")


def test_log_once_first_writer_wins():
    from repro.core import MemoryStore
    s = MemoryStore()
    assert s.log_once("p", "t", Vote.VOTE_YES, "p") == Vote.VOTE_YES
    assert s.log_once("p", "t", Vote.ABORT, "peer") == Vote.VOTE_YES
    assert s.read_state("p", "t") == Vote.VOTE_YES
    assert s.cas_losses == 1


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 5), st.integers(0, 1000), st.floats(0.1, 30.0))
def test_cornus_concurrent_termination_race_is_safe(n, seed, fail_t):
    """Coordinator AND participants all racing the termination protocol
    (everyone times out at once) still yields one consistent decision."""
    sim, storage, cluster, nodes = build("cornus", n, seed)
    # Tiny decision timeout forces every participant into termination even
    # though the coordinator is alive — maximal CAS contention.
    cluster.cfg.decision_timeout_ms = 0.5
    cluster.cfg.vote_timeout_ms = 0.5
    spec = TxnSpec(txn_id="t", coordinator=nodes[0], participants=nodes)
    cluster.run_txn(spec)
    sim.run(until=HORIZON)
    decisions = decided(cluster)
    assert len(decisions) == n
    assert len(set(decisions.values())) == 1


def test_readonly_not_known_upfront_subtlety():
    """§3.6 second case: when read-only-ness is discovered only at prepare
    time, a Cornus read-only participant MUST still LogOnce(VOTE-YES) (a
    missing vote reads as abortable by the termination protocol), while 2PC
    may skip its prepare log entirely."""
    for proto, must_log in (("cornus", True), ("2pc", False)):
        sim, storage, cluster, nodes = build(proto, 3, seed=11)
        spec = TxnSpec(txn_id="t", coordinator=nodes[0], participants=nodes,
                       read_only=frozenset({"n2"}),
                       read_only_known_upfront=False)
        done = cluster.run_txn(spec)
        sim.run(until=10_000)
        assert done.value.decision == Decision.COMMIT
        logged = storage.store.read_state("n2", "t")
        if must_log:
            assert logged in (Vote.VOTE_YES, Vote.COMMIT), \
                f"cornus read-only participant must log, got {logged}"
        else:
            assert logged is None, \
                f"2pc read-only participant should skip logging, got {logged}"


def test_readonly_unlogged_cornus_participant_is_abortable():
    """The WHY of the rule above: if a Cornus read-only participant crashed
    before logging, peers' termination protocol CAS-forces ABORT into its
    empty slot — absence of VOTE-YES must mean abortable, so live read-only
    participants must write it."""
    sim, storage, cluster, nodes = build("cornus", 3, seed=12)
    spec = TxnSpec(txn_id="t", coordinator=nodes[0], participants=nodes,
                   read_only=frozenset({"n2"}),
                   read_only_known_upfront=False)
    cluster.fail("n2", 0.01)     # dies before its (mandatory) vote log
    done = cluster.run_txn(spec)
    sim.run(until=50_000)
    assert done.value.decision == Decision.ABORT
    assert storage.store.read_state("n2", "t") == Vote.ABORT
