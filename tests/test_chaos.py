"""Chaos plane (core.chaos) + atomicity checker (core.history).

Covers the fault-injection machinery in isolation (schedules, nemesis
plans, retry policy, circuit breaker, threaded-store decorator, repro
bundles), the idempotent delivery guard, the checker's detection of each
violation class on crafted evidence, and end-to-end chaotic runs that must
come out machine-certified (zero AC1–AC3 / writer-of / recoverability
violations).
"""
import json
import os
from types import SimpleNamespace

import pytest

from conftest import hypothesis_or_stubs
from repro.core import (AZURE_REDIS, Cluster, Decision, MemoryStore,
                        ProtocolConfig, Sim, TxnSpec, Vote)
from repro.core.chaos import (ChaosStore, CircuitBreaker, FaultSchedule,
                              Nemesis, RetryPolicy, load_repro_bundle,
                              write_repro_bundle)
from repro.core.history import (HistoryRecorder, check_history,
                                collect_decisions)
from repro.core.protocols.transport import Transport
from repro.txn import BenchConfig, YCSBWorkload, run_bench

HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()

wl = lambda nodes, seed: YCSBWorkload(nodes, seed=seed)
NODES = ["n0", "n1", "n2", "n3"]


# ---------------------------------------------------------------------------
# FaultSchedule: determinism + serialization
# ---------------------------------------------------------------------------
def test_schedule_generation_is_deterministic():
    a = FaultSchedule.generate(7, NODES, 500.0, 3, "full")
    b = FaultSchedule.generate(7, NODES, 500.0, 3, "full")
    assert a.to_dict() == b.to_dict()
    c = FaultSchedule.generate(8, NODES, 500.0, 3, "full")
    assert a.to_dict() != c.to_dict()


@pytest.mark.parametrize("mix", ["messages", "partition", "crash", "torn",
                                 "skew", "full"])
def test_schedule_json_round_trip(mix):
    sched = FaultSchedule.generate(3, NODES, 400.0, 3, mix)
    back = FaultSchedule.from_json(sched.to_json())
    assert back.to_dict() == sched.to_dict()


def test_schedule_generate_rejects_unknown_mix():
    with pytest.raises(ValueError, match="unknown fault mix"):
        FaultSchedule.generate(0, NODES, 100.0, 0, "nonsense")


# ---------------------------------------------------------------------------
# Nemesis plans: partitions, torn writes, clock skew
# ---------------------------------------------------------------------------
def _nemesis(**kw):
    sim = Sim()
    sched = FaultSchedule(seed=1, **kw)
    return sim, Nemesis(sched, sim)


def test_partition_cuts_links_then_heals():
    from repro.core.chaos import NetPartition
    sim, nem = _nemesis(partitions=[NetPartition(
        at=10.0, heal_at=50.0, side_a=("n0",), side_b=("n1",),
        symmetric=True)])
    sim._schedule(20.0, lambda: None)
    sim.run(until=20.0)
    assert nem.message_plan("n0", "n1") is None      # cut
    assert nem.message_plan("n1", "n0") is None      # symmetric
    assert nem.message_plan("n0", "n2") is not None  # unaffected link
    sim._schedule(60.0, lambda: None)
    sim.run(until=60.0)
    assert nem.message_plan("n0", "n1") is not None  # healed


def test_torn_write_keeps_prefix_inside_window_only():
    from repro.core.chaos import TornWrite
    sim, nem = _nemesis(torn=[TornWrite(at=5.0, until=30.0, p=1.0, keep=1)])
    sim._schedule(10.0, lambda: None)
    sim.run(until=10.0)
    assert nem.torn_targets([0, 1, 2]) == [0]
    sim._schedule(40.0, lambda: None)
    sim.run(until=40.0)
    assert nem.torn_targets([0, 1, 2]) == [0, 1, 2]


def test_clock_skew_active_inside_window_only():
    from repro.core.chaos import ClockSkew
    sim, nem = _nemesis(skews=[ClockSkew(at=5.0, until=30.0, skew_ms=25.0)])
    assert nem.skew_ms() == 0.0
    sim._schedule(10.0, lambda: None)
    sim.run(until=10.0)
    assert nem.skew_ms() == 25.0
    sim._schedule(40.0, lambda: None)
    sim.run(until=40.0)
    assert nem.skew_ms() == 0.0


# ---------------------------------------------------------------------------
# Idempotent delivery guard (transport regression)
# ---------------------------------------------------------------------------
def test_duplicate_slot_delivery_is_suppressed_and_counted():
    sim = Sim()
    tr = Transport(sim, ["n0", "n1"], ProtocolConfig())
    assert tr._deliver_guarded("n0", "t", "decision", Decision.COMMIT,
                               batch=True)
    assert not tr._deliver_guarded("n0", "t", "decision", Decision.COMMIT,
                                   batch=True)
    assert tr.deliveries == 1
    assert tr.duplicate_deliveries == 1
    assert tr.slot("n0", "t", "decision").value == Decision.COMMIT


# ---------------------------------------------------------------------------
# Retry policy + circuit breaker
# ---------------------------------------------------------------------------
def test_retry_policy_backoff_is_jittered_exponential():
    import random
    pol = RetryPolicy(base_ms=4.0, factor=2.0, max_ms=64.0)
    rng = random.Random(0)
    for attempt in range(1, 9):
        raw = min(4.0 * 2.0 ** (attempt - 1), 64.0)
        b = pol.backoff_ms(attempt, rng)
        assert 0.5 * raw <= b <= 1.5 * raw


def test_circuit_breaker_trips_half_opens_and_recloses():
    br = CircuitBreaker(threshold=3, cooldown_ms=40.0)
    assert br.state("p") == CircuitBreaker.CLOSED
    for _ in range(3):
        br.note_failure("p", now=0.0)
    assert br.state("p") == CircuitBreaker.OPEN
    assert br.trips == 1
    assert br.admission_delay_ms("p", now=10.0) > 0.0  # held out while OPEN
    assert br.admission_delay_ms("p", now=100.0) == 0.0  # cooldown elapsed
    assert br.state("p") == CircuitBreaker.HALF_OPEN
    assert br.half_opens == 1
    br.note_success("p")
    assert br.state("p") == CircuitBreaker.CLOSED
    br.note_failure("p", now=200.0)                # single failure: stays
    assert br.state("p") == CircuitBreaker.CLOSED
    assert br.state("q") == CircuitBreaker.CLOSED  # per-partition isolation


def test_circuit_breaker_failed_probe_retrips():
    br = CircuitBreaker(threshold=2, cooldown_ms=10.0)
    br.note_failure("p", now=0.0)
    br.note_failure("p", now=0.0)
    assert br.state("p") == CircuitBreaker.OPEN
    assert br.admission_delay_ms("p", now=20.0) == 0.0   # half-open probe
    br.note_failure("p", now=20.0)                       # probe failed
    assert br.state("p") == CircuitBreaker.OPEN
    assert br.trips == 2


# ---------------------------------------------------------------------------
# Threaded-store chaos decorator
# ---------------------------------------------------------------------------
def test_chaos_store_drops_retry_then_force_through():
    store = ChaosStore(MemoryStore(), seed=3, drop_p=1.0, max_retries=2,
                       retry=RetryPolicy(base_ms=0.01, max_ms=0.02))
    assert store.log_once("p", "t", Vote.VOTE_YES,
                          writer="p") == Vote.VOTE_YES
    assert store.ops_dropped > 0
    assert store.retries > 0
    # Dropped attempts never mutate state twice: slot decided exactly once.
    assert store.read_state("p", "t") == Vote.VOTE_YES


def test_chaos_store_injects_delay():
    store = ChaosStore(MemoryStore(), seed=1, delay_ms=0.1)
    assert store.log_once("p", "t", Vote.ABORT, writer="q") == Vote.ABORT
    assert store.ops_delayed > 0


def test_store_config_wraps_chaos_store():
    from repro.core import StoreConfig, build_store
    plain = build_store(StoreConfig(backend="memory"))
    assert not isinstance(plain, ChaosStore)
    wrapped = build_store(StoreConfig(backend="memory", chaos_drop_p=0.5))
    assert isinstance(wrapped, ChaosStore)


# ---------------------------------------------------------------------------
# Failure-repro bundles
# ---------------------------------------------------------------------------
def test_repro_bundle_round_trip(tmp_path):
    sched = FaultSchedule.generate(5, NODES, 200.0, 3, "full")
    cfgd = {"protocol": "cornus", "seed": 5, "horizon_ms": 200.0}
    path = write_repro_bundle(sched, cfgd, ["[AC1] txn=t: mixed"],
                              out_dir=str(tmp_path))
    with open(path) as f:
        payload = json.load(f)
    assert payload["schema"] == 1
    assert payload["violations"] == ["[AC1] txn=t: mixed"]
    back, cfg2 = load_repro_bundle(path)
    assert back.to_dict() == sched.to_dict()
    assert cfg2 == cfgd


def test_repro_bundle_honours_env_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("CHAOS_REPRO_DIR", str(tmp_path / "failures"))
    sched = FaultSchedule.generate(1, NODES, 100.0, 0, "messages")
    path = write_repro_bundle(sched, {"protocol": "2pc"}, [])
    assert path.startswith(str(tmp_path / "failures"))
    assert os.path.exists(path)


# ---------------------------------------------------------------------------
# Checker: each violation class on crafted evidence
# ---------------------------------------------------------------------------
def _ctx(local=None, outcomes=None, specs=None):
    return SimpleNamespace(local=local or {}, outcomes=outcomes or {},
                           specs=specs or {})


def _spec(txn="t", coordinator="n0", participants=("n0", "n1"),
          read_only=(), **kw):
    return TxnSpec(txn_id=txn, coordinator=coordinator,
                   participants=list(participants),
                   read_only=frozenset(read_only), **kw)


def test_checker_flags_mixed_decisions_ac1():
    ctx = _ctx(local={("n0", "t"): {"decision": Decision.COMMIT},
                      ("n1", "t"): {"decision": Decision.ABORT}},
               specs={"t": _spec()})
    rules = [v.rule for v in check_history(None, ctx)]
    assert "AC1" in rules


def test_checker_flags_commit_over_no_vote_ac2():
    spec = _spec(votes={"n1": False})
    ctx = _ctx(local={("n0", "t"): {"decision": Decision.COMMIT},
                      ("n1", "t"): {"decision": Decision.COMMIT}},
               specs={"t": spec})
    rules = [v.rule for v in check_history(None, ctx)]
    assert "AC2" in rules


def test_checker_flags_changed_decision_ac3():
    out = SimpleNamespace(decision=Decision.ABORT)
    ctx = _ctx(local={("n0", "t"): {"decision": Decision.COMMIT}},
               outcomes={("t", "n0:recovery"): out},
               specs={"t": _spec(participants=("n0",))})
    rules = [v.rule for v in check_history(None, ctx)]
    assert "AC3" in rules


def test_checker_flags_foreign_yes_vote_writer_of():
    sim = Sim()
    hist = HistoryRecorder(sim)
    ev = sim.event()
    hist.record(ev, "log_once", "n1", "t", Vote.VOTE_YES, writer="n2")
    ev.trigger(Vote.VOTE_YES)
    sim.run(until=1.0)
    rules = [v.rule for v in check_history(hist, _ctx())]
    assert "writer-of" in rules


def test_checker_flags_unrecoverable_commit():
    ctx = _ctx(local={("n0", "t"): {"decision": Decision.COMMIT},
                      ("n1", "t"): {"decision": Decision.COMMIT}},
               specs={"t": _spec()})
    viols = check_history(None, ctx,
                          snapshot={("n0", "t"): Vote.COMMIT})  # n1 missing
    assert any(v.rule == "recoverability" for v in viols)


def test_checker_recoverability_consults_coordinator_for_cl():
    """participant_logs=False (CL): empty participant slots are BY DESIGN;
    only the coordinator's batched record certifies recoverability."""
    ctx = _ctx(local={("n0", "t"): {"decision": Decision.COMMIT},
                      ("n1", "t"): {"decision": Decision.COMMIT}},
               specs={"t": _spec()})
    snap = {("n0", "t"): Vote.COMMIT}
    assert not [v for v in check_history(None, ctx, snapshot=snap,
                                         participant_logs=False)]
    assert [v.rule for v in check_history(None, ctx, snapshot={},
                                          participant_logs=False)] \
        == ["recoverability"]


def test_checker_ignores_read_only_participants_trivial_commit():
    """§3.6: a known-upfront read-only participant concludes COMMIT the
    moment its reads finish — that conclusion carries no information and
    must not count as disagreement."""
    spec = _spec(participants=("n0", "n1", "n2"), read_only=("n2",))
    ctx = _ctx(local={("n0", "t"): {"decision": Decision.ABORT},
                      ("n1", "t"): {"decision": Decision.ABORT},
                      ("n2", "t"): {"decision": Decision.COMMIT}},
               specs={"t": spec})
    assert check_history(None, ctx) == []


def test_collect_decisions_merges_live_and_recovery():
    out = SimpleNamespace(decision=Decision.COMMIT)
    und = SimpleNamespace(decision=Decision.UNDETERMINED)
    ctx = _ctx(local={("n0", "t"): {"decision": Decision.COMMIT}},
               outcomes={("t", "n1:recovery"): out, ("t", "n2"): und})
    d = collect_decisions(ctx)
    assert d == {"t": {"n0": Decision.COMMIT,
                       "n1:recovery": Decision.COMMIT}}


# ---------------------------------------------------------------------------
# End-to-end: chaotic runs come out machine-certified
# ---------------------------------------------------------------------------
def _chaotic(proto, seed, mix="full", replication=1, horizon=300.0):
    sched = FaultSchedule.generate(seed, NODES, horizon,
                                   replication if replication > 1 else 0,
                                   mix)
    cfg = BenchConfig(protocol=proto, n_nodes=4, threads_per_node=2,
                      horizon_ms=horizon, seed=seed,
                      replication=replication, retry_fresh_ids=True,
                      chaos=sched, record_history=True)
    return run_bench(wl, AZURE_REDIS, cfg)


@pytest.mark.parametrize("proto", ["cornus", "2pc"])
def test_chaotic_run_certified_and_fault_counters_wired(proto):
    res = _chaotic(proto, seed=1)
    assert res.violations == 0, res.violation_details
    assert res.commits > 0
    assert res.gaveups == 0
    assert res.msgs_dropped + res.msgs_delayed + res.msgs_duplicated > 0
    assert res.crash_restarts > 0 and res.recoveries_run > 0
    bd = res.breakdown()
    for key in ("msgs_dropped", "duplicate_deliveries", "guard_retries",
                "breaker_trips", "crash_restarts", "recoveries_run",
                "violations", "torn_writes"):
        assert key in bd


def test_chaotic_run_replicated_torn_writes_certified():
    res = _chaotic("cornus", seed=2, replication=3)
    assert res.violations == 0, res.violation_details
    assert res.torn_writes > 0
    assert res.commits > 0


def test_chaos_runs_are_deterministic():
    a = _chaotic("cornus", seed=4, mix="messages")
    b = _chaotic("cornus", seed=4, mix="messages")
    assert (a.commits, a.aborts, a.msgs_dropped, a.msgs_delayed,
            a.recoveries_run) == \
           (b.commits, b.aborts, b.msgs_dropped, b.msgs_delayed,
            b.recoveries_run)


def test_no_chaos_run_reports_checker_not_run_and_zero_counters():
    cfg = BenchConfig(protocol="cornus", n_nodes=4, threads_per_node=2,
                      horizon_ms=100.0, seed=0)
    res = run_bench(wl, AZURE_REDIS, cfg)
    assert res.violations == -1            # checker not armed
    assert res.msgs_dropped == 0 and res.guard_retries == 0
    assert res.crash_restarts == 0


def test_message_duplication_suppressed_by_delivery_guard():
    from repro.core.chaos import LinkChaos
    sched = FaultSchedule(seed=9, links=[LinkChaos(
        src="*", dst="*", at=0.0, until=300.0, dup_p=1.0)])
    cfg = BenchConfig(protocol="cornus", n_nodes=4, threads_per_node=2,
                      horizon_ms=300.0, seed=9, retry_fresh_ids=True,
                      chaos=sched, record_history=True)
    res = run_bench(wl, AZURE_REDIS, cfg)
    assert res.violations == 0, res.violation_details
    assert res.msgs_duplicated > 0
    assert res.duplicate_deliveries > 0    # the guard absorbed the copies


# ---------------------------------------------------------------------------
# Property: any generated schedule keeps the run certified (repro bundle
# written on failure so the seed can be replayed)
# ---------------------------------------------------------------------------
@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
@given(seed=st.integers(min_value=0, max_value=10_000),
       mix=st.sampled_from(["messages", "partition", "crash", "full"]))
@settings(max_examples=8, deadline=None)
def test_property_chaos_never_violates_atomicity(seed, mix):
    res = _chaotic("cornus", seed=seed, mix=mix, horizon=200.0)
    if res.violations:
        sched = FaultSchedule.generate(seed, NODES, 200.0, 0, mix)
        path = write_repro_bundle(
            sched, {"protocol": "cornus", "n_nodes": 4,
                    "threads_per_node": 2, "horizon_ms": 200.0,
                    "seed": seed, "replication": 1,
                    "retry_fresh_ids": True},
            res.violation_details)
        raise AssertionError(
            f"violations under seed={seed} mix={mix} "
            f"(repro bundle: {path}): {res.violation_details}")
