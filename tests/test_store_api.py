"""Unified store API: registry names, factory construction, membership
plumbing, and the per-lane adaptive-timeout policy the redesign threads
through ``ProtocolConfig.timeout(kind, lane=...)``."""
from __future__ import annotations

import pytest

from repro.core import (AZURE_REDIS, AdaptiveTimeouts, BatchConfig,
                        BatchingStore, DecisionCacheConfig, EwmaStat,
                        FileStore, LeaseKeeper, MembershipConfig, MemoryStore,
                        QuorumUnavailable, ReplicatedSimStorage,
                        ReplicatedStore, Sim, SimStorage, StoreConfig, Vote,
                        build_store, get_store,
                        registered_stores)
from repro.core.stores import is_simulated

ALL_ON = DecisionCacheConfig(cache=True, singleflight=True, push=True)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
def test_registered_backends():
    names = registered_stores()
    for expected in ("memory", "file", "replicated", "sim",
                     "replicated-sim"):
        assert expected in names


def test_unknown_backend_lists_registered():
    with pytest.raises(KeyError) as ei:
        get_store("redis")
    msg = str(ei.value)
    assert "redis" in msg and "memory" in msg and "replicated-sim" in msg


def test_is_simulated():
    assert is_simulated("sim") and is_simulated("replicated-sim")
    assert not is_simulated("memory") and not is_simulated("replicated")


# ---------------------------------------------------------------------------
# Factory construction
# ---------------------------------------------------------------------------
def test_build_memory_and_control_plane():
    plain = build_store(StoreConfig(backend="memory"))
    assert isinstance(plain, MemoryStore) and plain.control is None
    stormy = build_store(StoreConfig(backend="memory", decisions=ALL_ON))
    assert stormy.control is not None
    # Same observable counter surface as the sim services.
    assert stormy.decision_cache_hits == 0
    assert stormy.singleflight_hits == 0
    assert stormy.decisions_pushed == 0


def test_build_file_needs_root(tmp_path):
    with pytest.raises(ValueError):
        build_store(StoreConfig(backend="file"))
    store = build_store(StoreConfig(backend="file", root=str(tmp_path)))
    assert isinstance(store, FileStore)
    assert store.log_once("h0", "t1", Vote.VOTE_YES, writer="h0") \
        == Vote.VOTE_YES


def test_build_replicated():
    store = build_store(StoreConfig(backend="replicated", replication=5,
                                    seed=11))
    assert isinstance(store, ReplicatedStore) and store.n == 5


def test_simulated_backends_require_sim():
    with pytest.raises(ValueError):
        build_store(StoreConfig(backend="sim"))
    sim = Sim()
    assert isinstance(build_store(StoreConfig(backend="sim"), sim=sim),
                      SimStorage)
    assert isinstance(
        build_store(StoreConfig(backend="replicated-sim", model=AZURE_REDIS),
                    sim=sim), ReplicatedSimStorage)


def test_batching_wraps_threaded_backends():
    store = build_store(StoreConfig(backend="memory", batching=True,
                                    window_s=0.0, max_batch=8))
    assert isinstance(store, BatchingStore)
    assert isinstance(store.inner, MemoryStore)


# ---------------------------------------------------------------------------
# Membership plumbing (make_store shim removed — factory is the only door)
# ---------------------------------------------------------------------------
def test_make_store_shim_is_gone():
    import repro.core
    import repro.core.stores
    assert not hasattr(repro.core, "make_store")
    assert not hasattr(repro.core.stores, "make_store")


def test_membership_config_normalizes_and_quorums():
    m = MembershipConfig(1, (2, 0, 1, 1))
    assert m.replica_ids == (0, 1, 2)
    assert m.n == 3 and m.quorum == 2
    assert m.quorum_of([0, 1]) and not m.quorum_of([2])
    # quorum_of counts only THIS config's members.
    assert not m.quorum_of([7, 8, 9])


def test_build_replicated_with_membership():
    store = build_store(StoreConfig(backend="replicated",
                                    membership=(0, 2, 4)))
    assert store.n == 3 and store.quorum == 2
    assert len(store.replicas) == 5       # table sized for the id space
    assert store.membership.replica_ids == (0, 2, 4)


def test_build_replicated_sim_with_membership():
    sim = Sim()
    store = build_store(
        StoreConfig(backend="replicated-sim", model=AZURE_REDIS,
                    replication=5, membership=(0, 1, 2)), sim=sim)
    assert store.n == 3 and store.quorum == 2
    assert store.member_ids == [0, 1, 2]


# ---------------------------------------------------------------------------
# Per-lane EWMAs / AdaptiveTimeouts (the global-dilution fix)
# ---------------------------------------------------------------------------
def test_ewma_stat_matches_legacy_update_law():
    # dev updates against the PRE-update mean — the exact order the global
    # write_lat_ewma/dev fields always used.
    st = EwmaStat()
    ewma, dev = None, 0.0
    for ms in (4.0, 12.0, 2.0, 40.0, 7.5):
        st.note(ms)
        if ewma is None:
            ewma, dev = ms, ms / 4.0
        else:
            dev = 0.75 * dev + 0.25 * abs(ms - ewma)
            ewma = 0.75 * ewma + 0.25 * ms
    assert st.ewma == pytest.approx(ewma)
    assert st.dev == pytest.approx(dev)


class _FakeLaneStorage:
    """Storage stats double: one saturated lane, quiet global aggregate."""

    write_lat_ewma = 1.0
    write_lat_dev = 0.1

    def lane_write_latency(self, lane):
        return (400.0, 40.0) if lane == "hot" else None


def test_per_lane_timeouts_isolate_the_hot_lane():
    pol = AdaptiveTimeouts(_FakeLaneStorage(), jitter=0.0, per_lane=True)
    base = 25.0
    # Hot lane: raised by ITS EWMA (capped at 64x base).
    hot = pol.timeout_ms("vote", base, lane="hot")
    assert hot == pytest.approx(min(64.0 * base, 4.0 * 400.0 + 8.0 * 40.0))
    # Never-observed lane: static floor, NOT the global aggregate and NOT
    # the hot lane's congestion.
    assert pol.timeout_ms("vote", base, lane="cold") == base
    # No lane named: the service-global EWMA path, unchanged.
    assert pol.timeout_ms("vote", base) == base  # 4*1+8*0.1 < base floor


def test_global_ewma_dilution_regression():
    """The bug the per-lane policy fixes: under zipf skew one hot lane's
    queueing drowns in the many idle lanes' fast writes, so the GLOBAL
    policy under-raises the hot lane's deadline.  Per-lane must raise the
    hot lane's timeout strictly above the global policy's while keeping
    cold lanes at the static floor."""
    sim = Sim()
    storage = SimStorage(sim, AZURE_REDIS, seed=0)
    hot, cold = "p0", "p1"
    # 1 slow hot write among many fast cold writes (zipf-ish mix) — drive
    # the mixin's bookkeeping directly; stats are recorded per-lane
    # unconditionally.
    storage._note_write_latency(500.0, lane=hot)
    for _ in range(50):
        storage._note_write_latency(1.0, lane=cold)
    base = 25.0
    global_pol = AdaptiveTimeouts(storage, jitter=0.0)
    lane_pol = AdaptiveTimeouts(storage, jitter=0.0, per_lane=True)
    # Global EWMA was diluted toward the fast lane...
    assert global_pol.timeout_ms("vote", base, lane=hot) < \
        lane_pol.timeout_ms("vote", base, lane=hot)
    # ...per-lane keeps the hot signal hot (hits the 64x cap here)...
    assert lane_pol.timeout_ms("vote", base, lane=hot) == \
        pytest.approx(64.0 * base)
    # ...and the cold lane stays at its own (floor) deadline.
    assert lane_pol.timeout_ms("vote", base, lane=cold) == base


def test_sim_storage_records_lane_stats_unconditionally():
    sim = Sim()
    storage = SimStorage(sim, AZURE_REDIS, seed=0)
    done = {}

    def proc():
        v = yield storage.log_once("pA", "t1", Vote.VOTE_YES, writer="pA")
        done["v"] = v

    sim.process(proc())
    sim.run(until=10_000.0)
    assert done["v"] == Vote.VOTE_YES
    assert storage.lane_write_latency("pA") is not None
    assert storage.lane_write_latency("pB") is None


# ---------------------------------------------------------------------------
# LeaseKeeper (automatic acquisition / renewal / degradation)
# ---------------------------------------------------------------------------
def test_lease_keeper_unsupported_store_is_slow_path():
    keeper = LeaseKeeper(MemoryStore(), holder="h0")
    assert not keeper.supported
    assert keeper.ensure() is None and keeper.failures == 0


def test_lease_keeper_acquires_and_reuses():
    store = ReplicatedStore(n_replicas=3, seed=1)
    keeper = LeaseKeeper(store, holder="h0", duration_s=60.0)
    lease = keeper.ensure()
    assert lease is not None and lease.holder == "h0"
    assert keeper.acquisitions == 1
    # Far from expiry: the SAME lease comes back, no second round.
    assert keeper.ensure() is lease
    assert keeper.acquisitions == 1 and keeper.renewals == 0


def test_lease_keeper_renews_near_expiry():
    store = ReplicatedStore(n_replicas=3, seed=1)
    keeper = LeaseKeeper(store, holder="h0", duration_s=1e-4)
    first = keeper.ensure()
    assert first is not None
    import time as _time
    _time.sleep(2e-4)                    # expire it
    second = keeper.ensure()
    assert second is not None and second.epoch > first.epoch
    assert keeper.renewals >= 1


def test_lease_keeper_defers_to_live_peer():
    store = ReplicatedStore(n_replicas=3, seed=1)
    store.acquire_lease("peer", duration_s=60.0)
    keeper = LeaseKeeper(store, holder="h0")
    assert keeper.ensure() is None       # stealing would thrash epochs
    assert keeper.acquisitions == 0


def test_lease_keeper_degrades_on_quorum_loss():
    store = ReplicatedStore(n_replicas=3, seed=1)
    store.fail_replica(0)
    store.fail_replica(1)
    keeper = LeaseKeeper(store, holder="h0")
    assert keeper.ensure() is None       # no quorum: degrade, don't raise
    assert keeper.failures == 1
    # The degradation is SURFACED, not silent: counted and flagged.
    assert keeper.degradations == 1 and keeper.degraded
    store.recover_replica(0)
    assert keeper.ensure() is not None   # quorum back: fast path returns
    assert keeper.reengagements == 1 and not keeper.degraded


def test_lease_keeper_logs_degradation_transitions(caplog):
    import logging
    store = ReplicatedStore(n_replicas=3, seed=1)
    store.fail_replica(0)
    store.fail_replica(1)
    keeper = LeaseKeeper(store, holder="h0")
    with caplog.at_level(logging.INFO, logger="repro.core.control"):
        keeper.ensure()                  # -> slow: one WARNING
        keeper.ensure()                  # still slow: NO second line
        store.recover_replica(0)
        keeper.ensure()                  # -> fast: one INFO
    slow = [r for r in caplog.records if "slow path" in r.message]
    fast = [r for r in caplog.records if "re-engaged" in r.message]
    assert len(slow) == 1 and slow[0].levelno == logging.WARNING
    assert len(fast) == 1 and fast[0].levelno == logging.INFO
    assert keeper.degradations == 2      # every slow answer counts
