"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED same-family config and runs:
  * one forward pass (loss finite, logits shaped (B,S,padded_vocab))
  * one SGD train step (grads finite, params update)
  * prefill + one decode step where the family supports decode
on a single CPU device.  Full configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, forward, init_cache, init_model,
                          prefill, smoke)

# Full-family forward/train/decode sweeps take minutes on CPU.
pytestmark = pytest.mark.slow


def make_batch(cfg, B=2, S=32, rng_seed=0):
    rng = np.random.RandomState(rng_seed)
    batch = {}
    if cfg.input_mode == "tokens":
        toks = rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32)
        batch["tokens"] = jnp.asarray(toks)
        batch["labels"] = jnp.asarray(toks)
    elif cfg.input_mode == "embeds":
        batch["frame_embeds"] = jnp.asarray(
            rng.randn(B, S, cfg.d_model).astype(np.float32))
        batch["labels"] = jnp.asarray(
            rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32))
    else:  # mixed VLM
        n_patch = max(1, int(S * cfg.patch_frac))
        n_text = S - n_patch
        batch["patch_embeds"] = jnp.asarray(
            rng.randn(B, n_patch, cfg.d_model).astype(np.float32))
        toks = rng.randint(0, cfg.vocab_size, (B, n_text)).astype(np.int32)
        batch["tokens"] = jnp.asarray(toks)
        batch["labels"] = jnp.asarray(
            rng.randint(0, cfg.vocab_size, (B, S)).astype(np.int32))
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = smoke(get_config(arch))
            params = init_model(cfg, jax.random.key(0))
            cache[arch] = (cfg, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch, built):
    cfg, params = built(arch)
    batch = make_batch(cfg)
    loss, logits = jax.jit(lambda p, b: forward(cfg, p, b))(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert jnp.isfinite(loss), f"{arch}: loss {loss}"
    assert not jnp.isnan(logits).any(), f"{arch}: NaN logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_updates_params(arch, built):
    cfg, params = built(arch)
    batch = make_batch(cfg)

    @jax.jit
    def step(p, b):
        (loss, _), grads = jax.value_and_grad(
            lambda pp: forward(cfg, pp, b), has_aux=True)(p)
        new = jax.tree_util.tree_map(lambda w, g: w - 1e-3 * g, p, grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                             for g in jax.tree_util.tree_leaves(grads)))
        return new, loss, gnorm

    new_params, loss, gnorm = step(params, batch)
    assert jnp.isfinite(loss) and jnp.isfinite(gnorm) and gnorm > 0, \
        f"{arch}: loss={loss} gnorm={gnorm}"
    changed = any(
        not jnp.allclose(a, b)
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert changed, f"{arch}: no param changed"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch, built):
    cfg, params = built(arch)
    B, S, max_len = 2, 16, 24
    batch = make_batch(cfg, B=B, S=S)
    batch.pop("labels")
    logits, cache, pos = jax.jit(
        lambda p, b: prefill(cfg, p, b, max_len))(params, batch)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert not jnp.isnan(logits).any()

    tok = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1).astype(jnp.int32)
    if cfg.input_mode == "tokens":
        step_in = {"tokens": tok[:, None]}
    elif cfg.input_mode == "embeds":
        step_in = {"frame_embeds": jnp.zeros((B, 1, cfg.d_model))}
    else:
        step_in = {"tokens": tok[:, None],
                   "patch_embeds": jnp.zeros((B, 0, cfg.d_model))}
    logits2, cache2 = jax.jit(
        lambda p, b, c, pp: decode_step(cfg, p, b, c, pp)
    )(params, step_in, cache, jnp.int32(S))
    assert logits2.shape == (B, 1, cfg.padded_vocab)
    assert not jnp.isnan(logits2).any(), f"{arch}: NaN decode logits"


def test_decode_matches_forward_dense():
    """Teacher-forced decode == train forward logits (dense arch, exactness
    of the KV-cache path)."""
    cfg = smoke(get_config("llama3.2-1b"))
    params = init_model(cfg, jax.random.key(0))
    B, S = 1, 12
    toks = jax.random.randint(jax.random.key(5), (B, S), 0, cfg.vocab_size)
    _, full_logits = forward(cfg, params, {"tokens": toks, "labels": toks})

    logits, cache, _ = prefill(cfg, params, {"tokens": toks[:, :4]},
                               max_len=S)
    outs = [logits]
    for t in range(4, S):
        logits, cache = decode_step(cfg, params, {"tokens": toks[:, t:t + 1]},
                                    cache, jnp.int32(t))
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)  # positions 3..S-1
    np.testing.assert_allclose(
        np.asarray(full_logits[:, 3:], np.float32),
        np.asarray(dec, np.float32), rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_hybrid():
    """Same exactness check through mamba + MoE + attention (jamba).

    capacity_factor is raised so no token is capacity-dropped: drops are a
    train-time approximation and legitimately differ between the batched
    and single-token paths.
    """
    import dataclasses
    cfg = dataclasses.replace(smoke(get_config("jamba-v0.1-52b")),
                              capacity_factor=8.0)
    params = init_model(cfg, jax.random.key(1))
    B, S = 1, 10
    toks = jax.random.randint(jax.random.key(6), (B, S), 0, cfg.vocab_size)
    _, full_logits = forward(cfg, params, {"tokens": toks, "labels": toks})
    logits, cache, _ = prefill(cfg, params, {"tokens": toks[:, :5]},
                               max_len=S)
    outs = [logits]
    for t in range(5, S):
        logits, cache = decode_step(cfg, params, {"tokens": toks[:, t:t + 1]},
                                    cache, jnp.int32(t))
        outs.append(logits)
    dec = jnp.concatenate(outs, axis=1)  # positions 4..S-1
    np.testing.assert_allclose(
        np.asarray(full_logits[:, 4:], np.float32),
        np.asarray(dec, np.float32), rtol=5e-2, atol=5e-2)


def test_param_counts_match_table():
    """Full configs land near their published sizes (±25%)."""
    expected = {
        "minicpm-2b": 2.7e9,       # 2.4B + large tied embed table
        "llama3.2-1b": 1.24e9,
        "gemma2-2b": 2.6e9,
        "gemma3-4b": 4.3e9,
        "qwen3-moe-235b-a22b": 235e9,
        "kimi-k2-1t-a32b": 1.03e12,
        "qwen2-vl-72b": 71e9,
        "jamba-v0.1-52b": 52e9,
        "xlstm-125m": 0.125e9,
        "musicgen-medium": 1.5e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert 0.6 * want < got < 1.45 * want, \
            f"{arch}: {got/1e9:.2f}B vs expected {want/1e9:.2f}B"
