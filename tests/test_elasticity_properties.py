"""Property-based tests of elastic membership under chaos.

Hypothesis replays random join/leave/kill/lease-expiry schedules through
the deterministic sim and the threaded store and asserts the
reconfiguration contract: AC1-AC3 hold across config changes, every slot
decides exactly once whatever configs served it, scheduled changes all
install once quorum allows, and a removed replica's stale writes can
never be chosen (retired ids are never consulted again).
"""
from __future__ import annotations

import pytest

from conftest import hypothesis_or_stubs

HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()

from repro.core import (AZURE_REDIS, BatchConfig, Cluster, Decision,
                        ProtocolConfig, ReplicatedSimStorage,
                        ReplicatedStore, Sim, TxnSpec, Vote)

HORIZON = 500_000.0

# One replica outage with guaranteed recovery (same shape as the lease
# property suite): quorum returns eventually, so every run terminates.
outage = st.tuples(st.integers(0, 2), st.floats(0.0, 60.0),
                   st.floats(60.0, 400.0))

# A live membership-change schedule: 1-2 changes to R in {3,4,5} at
# random times, possibly overlapping the outages (the store serializes
# changes and waits out total outages).
reconfig = st.tuples(st.floats(5.0, 300.0), st.integers(3, 5))


def expected_installs(schedule) -> int:
    """Changes that actually flip membership: the store serializes them in
    schedule order, and a change to the current R is a no-op."""
    cur, installs = 3, 0
    for _at, n in sorted(schedule, key=lambda c: c[0]):
        if n != cur:
            cur, installs = n, installs + 1
    return installs


def run_cluster(n, votes_yes, seed, window_ms, fails, lease_ms, changes,
                protocol="cornus"):
    sim = Sim()
    batch = BatchConfig(window_ms=window_ms, serial=window_ms > 0)
    storage = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3,
                                   seed=seed, batch=batch,
                                   lease_ms=lease_ms)
    for idx, at, rec in fails:
        storage.fail_replica(idx, at, rec)
    for at, n_new in changes:
        storage.schedule_reconfigure(at, n_new)
    nodes = [f"n{i}" for i in range(n)]
    tmo = 5_000.0
    cluster = Cluster(sim, storage, nodes,
                      ProtocolConfig(protocol=protocol,
                                     vote_timeout_ms=tmo,
                                     decision_timeout_ms=tmo,
                                     votereq_timeout_ms=tmo,
                                     termination_retry_ms=tmo,
                                     coop_retry_ms=tmo))
    spec = TxnSpec(txn_id="t", coordinator=nodes[0], participants=nodes,
                   votes={nd: v for nd, v in zip(nodes, votes_yes)})
    cluster.run_txn(spec)
    sim.run(until=HORIZON)
    decisions = {node: s["decision"]
                 for (node, t), s in cluster.local.items()
                 if t == "t" and s["decision"] is not None}
    return decisions, storage


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 5).flatmap(lambda n: st.tuples(
    st.just(n),
    st.lists(st.booleans(), min_size=n, max_size=n),
    st.integers(0, 10_000),
    st.floats(0.0, 4.0),
    st.lists(outage, max_size=2),
    st.sampled_from([20.0, 80.0, 200.0]),
    st.lists(reconfig, min_size=1, max_size=2),
)))
def test_ac_invariants_hold_across_config_changes(params):
    """AC1-AC3 across random join/leave/kill/lease-expiry schedules: all
    nodes reach ONE decision, COMMIT only on unanimous YES, and every
    effective scheduled change installs (the schedule completes)."""
    n, votes, seed, window, fails, lease_ms, changes = params
    d, storage = run_cluster(n, votes, seed, window, fails, lease_ms,
                             changes)
    assert len(d) == n, f"undecided nodes: {d}"
    assert len(set(d.values())) == 1, f"split brain: {d}"
    if not all(votes):
        assert Decision.COMMIT not in d.values()
    else:
        assert set(d.values()) == {Decision.COMMIT}
    assert storage.reconfigurations == expected_installs(changes)
    for _started, cutover, installed, old_n, new_n in \
            storage.reconfig_history:
        assert installed >= cutover and old_n != new_n


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000),
       st.lists(outage, max_size=2),
       st.floats(0.0, 4.0),
       st.sampled_from([15.0, 60.0, 200.0]),
       st.lists(st.floats(0.0, 200.0), min_size=2, max_size=8),
       st.lists(reconfig, min_size=1, max_size=2))
def test_single_winner_per_slot_across_configs(seed, fails, window,
                                               lease_ms, delays, changes):
    """Racing writers on one slot while membership changes mid-race:
    every caller observes the SAME first value whatever config served it,
    and the merged member state agrees."""
    sim = Sim()
    batch = BatchConfig(window_ms=window, serial=window > 0)
    storage = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3,
                                   seed=seed, batch=batch,
                                   lease_ms=lease_ms)
    for idx, at, rec in fails:
        storage.fail_replica(idx, at, rec)
    for at, n_new in changes:
        storage.schedule_reconfigure(at, n_new)
    results = {}

    def proposer(name, value, delay):
        def gen():
            yield sim.timeout(delay)
            results[name] = yield storage.log_once("p0", "t", value,
                                                   writer=name)
        sim.process(gen())

    for w, delay in enumerate(delays):
        value = Vote.VOTE_YES if w % 2 == 0 else Vote.ABORT
        proposer(f"w{w}", value, delay)
    sim.run(until=HORIZON)
    assert len(results) == len(delays), results
    assert len(set(results.values())) == 1, results
    assert storage.snapshot().get(("p0", "t")) == \
        next(iter(results.values()))


# ---------------------------------------------------------------------------
# Threaded store: removed replicas and chaos schedules
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000),
       st.sampled_from([Vote.VOTE_YES, Vote.ABORT]),
       st.sampled_from([Vote.VOTE_YES, Vote.ABORT]),
       st.integers(2, 40))
def test_removed_replica_stale_writes_never_chosen(seed, chosen, stale,
                                                   stale_epoch):
    """Retire a replica, then poison its volume with arbitrarily
    high-ballot stale state: reads, re-proposals, snapshots, and a later
    joiner's state transfer must never surface the poisoned value —
    retired ids are simply never consulted again."""
    store = ReplicatedStore(n_replicas=3, seed=seed)
    assert store.log_once("p", "t1", chosen, writer="w") == chosen
    removed = max(store.membership.replica_ids)
    store.remove_replica(removed)
    assert removed not in store.membership.replica_ids
    # Poison the retired volume: a fabricated high-ballot acceptance and a
    # divergent decided slot.
    store.replicas[removed].accept(("p", "t1"), (stale_epoch, 1, removed),
                                   stale)
    store.replicas[removed].repair(("p", "t2"), stale, 1, True)
    # The chosen value survives on every path.
    assert store.log_once("p", "t1", stale, writer="w2") == chosen
    assert store.snapshot().get(("p", "t1")) == chosen
    assert ("p", "t2") not in store.snapshot()
    # A NEW joiner transfers state from members only: the poison does not
    # propagate, and the fresh id is never the retired one.
    new_id = store.add_replica()
    assert new_id != removed
    assert store.replicas[new_id].read(("p", "t2"))[0] is None
    assert store.log_once("p", "t1", stale, writer="w3") == chosen


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000),
       st.lists(st.sampled_from(["grow", "shrink", "kill", "revive",
                                 "write"]),
                min_size=4, max_size=14))
def test_threaded_chaos_schedule_keeps_decisions_stable(seed, ops):
    """Random interleavings of join/leave/kill/revive with first-write
    races: a decided slot's value never changes across any membership
    trajectory, and the final snapshot agrees with every return value."""
    store = ReplicatedStore(n_replicas=3, seed=seed)
    decided = {}
    killed = None
    k = 0
    for op in ops:
        m = store.membership.replica_ids
        if op == "grow" and store.n < 6:
            store.add_replica()
        elif op == "shrink" and store.n > 3:
            store.remove_replica(max(m))
        elif op == "kill" and killed is None and store.n >= 3:
            # Keep quorum: fail one member only.
            killed = max(m)
            store.fail_replica(killed)
        elif op == "revive" and killed is not None:
            if killed in store.membership.replica_ids:
                store.revive_replica(killed)
            else:
                store.recover_replica(killed)   # retired while dead
            killed = None
        elif op == "write":
            txn = f"t{k}"
            k += 1
            first = store.log_once("p", txn, Vote.VOTE_YES, writer="w")
            again = store.log_once("p", txn, Vote.ABORT, writer="w2")
            assert first == again == Vote.VOTE_YES
            decided[("p", txn)] = first
    if killed is not None and killed in store.membership.replica_ids:
        store.recover_replica(killed)
    snap = store.snapshot()
    for key, value in decided.items():
        assert snap.get(key) == value, (key, snap.get(key), value)


def test_lease_hands_over_across_reconfiguration():
    """The group-commit identity survives a config change: the holder's
    lease is reinstalled at the bump ballot, not silently dropped."""
    store = ReplicatedStore(n_replicas=3, seed=7)
    lease = store.acquire_lease("leader-0", duration_s=60.0)
    assert lease is not None
    store.set_replication(5, holder="leader-0")
    after = store.current_lease()
    assert after is not None and after.holder == "leader-0"
    assert after.epoch > lease.epoch
    assert store.n == 5
    assert store.log_once("p", "tx", Vote.VOTE_YES,
                          writer="leader-0") == Vote.VOTE_YES


@pytest.mark.skipif(not HAS_HYPOTHESIS, reason="hypothesis not installed")
def test_hypothesis_is_exercising_reconfigurations():
    """Meta-check: the strategies above genuinely install config changes
    mid-run (guards against degenerating to the fixed-membership path)."""
    d, storage = run_cluster(3, [True, True, True], 0, 2.0,
                             [(0, 0.0, 300.0)], 50.0,
                             [(10.0, 5), (150.0, 3)])
    assert set(d.values()) == {Decision.COMMIT}
    assert storage.reconfigurations == 2
    assert [(o, n) for (_s, _c, _i, o, n)
            in storage.reconfig_history] == [(3, 5), (5, 3)]
