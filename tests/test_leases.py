"""Leader leases & epoch ballots: the post-failover phase-1-free fast path.

A new leader acquires an epoch lease with ONE bulk prepare round and then
serves every slot with owner-ballot single accepts (batched flushes
included), so a replica outage no longer degrades the replicated log to
per-op prepare+accept forever.  Safety never rests on lease timing: an
expired or superseded leaseholder's accepts fail at the replicas and fall
back to the full proposer.
"""
from __future__ import annotations

import pytest

from repro.core import (AZURE_REDIS, Cluster, Decision, LatencyModel,
                        ProtocolConfig, RegionTopology, ReplicatedSimStorage,
                        ReplicatedStore, Sim, StoreLease, TxnSpec, Vote,
                        predicted_caller_latency_ms)
from repro.core.storage import OWNER_BALLOT, BatchConfig
from repro.txn import BenchConfig, YCSBWorkload, run_bench


# ---------------------------------------------------------------------------
# Sim storage: lease acquisition and the restored fast path
# ---------------------------------------------------------------------------
def _log_once_seq(storage, sim, n, part="p", writer="p", spacing=10.0):
    lat = {}

    def one(i):
        def gen():
            yield sim.timeout(i * spacing)
            t0 = sim.now
            got = yield storage.log_once(part, f"t{i}", Vote.VOTE_YES,
                                         writer=writer)
            lat[i] = (sim.now - t0, got)
        sim.process(gen())

    for i in range(n):
        one(i)
    sim.run(until=100_000.0)
    return lat


def test_failover_leader_acquires_lease_once_then_serves_fast():
    """Replica 0 dead from t=0: the first op pays one bulk prepare round
    (the epoch acquisition); every subsequent op is a single owner-ballot
    accept — not per-op prepare+accept."""
    sim = Sim()
    st = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3, seed=1)
    st.fail_replica(0, at=0.0)
    lat = _log_once_seq(st, sim, 8)
    assert all(v == Vote.VOTE_YES for _, v in lat.values())
    assert st.lease_acquisitions == 1
    assert st.fast_path_ops == 8 and st.fallback_ops == 0
    (epoch, holder, _t), = st.lease_history
    assert epoch == 2 and holder == 1
    # The acquisition is amortized: later ops are strictly cheaper than
    # the first (which waited out the bulk prepare).
    assert max(lat[i][0] for i in range(1, 8)) < lat[0][0]


def test_no_failure_keeps_implicit_epoch1_lease():
    sim = Sim()
    st = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3, seed=1)
    lat = _log_once_seq(st, sim, 4)
    assert all(v == Vote.VOTE_YES for _, v in lat.values())
    assert st.lease_acquisitions == 0 and st.lease_history == []
    assert st.fast_path_ops == 4 and st.fallback_ops == 0


def test_lease_expiry_renews_with_fresh_epoch():
    sim = Sim()
    st = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3, seed=1,
                              lease_ms=25.0)
    st.fail_replica(0, at=0.0)
    _log_once_seq(st, sim, 6, spacing=30.0)   # every op outlives the lease
    assert st.lease_acquisitions >= 2
    epochs = [e for e, _h, _t in st.lease_history]
    assert epochs == sorted(set(epochs)), "epochs must strictly increase"
    assert st.lease_expiries >= 1


def test_returning_initial_leader_supersedes_failover_lease():
    """Replica 0 recovers after replica 1 took an epoch: routing goes back
    to replica 0, which must acquire a FRESH epoch (its implicit epoch-1
    promise is stale) — and every op still decides exactly once."""
    sim = Sim()
    st = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3, seed=1)
    st.fail_replica(0, at=0.0, recover_at=50.0)
    lat = _log_once_seq(st, sim, 8, spacing=20.0)
    assert all(v == Vote.VOTE_YES for _, v in lat.values())
    holders = [h for _e, h, _t in st.lease_history]
    assert holders[0] == 1 and 0 in holders[1:]
    epochs = [e for e, _h, _t in st.lease_history]
    assert epochs == sorted(set(epochs))


def test_superseded_leaseholder_falls_back_safely():
    """A slot-level terminator races the leaseholder on one slot: exactly
    one value wins, both callers observe it (single-winner-per-slot across
    epochs)."""
    for seed in range(8):
        sim = Sim()
        st = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3, seed=seed)
        st.fail_replica(0, at=0.0)
        results = {}

        def prop(name, value, delay):
            def gen():
                yield sim.timeout(delay)
                results[name] = yield st.log_once("p", "t", value,
                                                  writer=name)
            sim.process(gen())

        prop("p", Vote.VOTE_YES, 0.0)
        prop("q", Vote.ABORT, float(seed % 4))
        sim.run(until=100_000.0)
        assert len(set(results.values())) == 1, (seed, results)
        assert st.snapshot()[("p", "t")] == results["p"]


def test_postfailover_batched_flush_uses_lease_ballot():
    """Concurrent same-partition writes AFTER failover still coalesce into
    one accept round (the gate is "current leaseholder", not "initial
    leader")."""
    sim = Sim()
    st = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3, seed=1,
                              batch=BatchConfig(window_ms=2.0, serial=True))
    st.fail_replica(0, at=0.0)
    evs = [st.log_once("p", f"t{i}", Vote.VOTE_YES, writer=f"w{i}")
           for i in range(10)]
    sim.run(until=100_000.0)
    assert all(ev.value == Vote.VOTE_YES for ev in evs)
    assert st._ingress.max_batch_seen == 10
    assert st.lease_acquisitions == 1
    assert st.fast_path_ops == 10 and st.fallback_ops == 0


def test_postfailover_caller_latency_returns_to_table3():
    """Zero service times, uniform topology, leader 0 dead: once the lease
    is acquired, a cornus commit costs EXACTLY the Table-3 RTT count again
    — the fast path is fully restored, not approximately restored."""
    rtt = 20.0
    topo = RegionTopology.uniform("t3", ("r0",), rtt)
    model = LatencyModel("null", conditional_write_ms=0.0,
                         plain_write_ms=0.0, read_ms=0.0, jitter=0.0)
    sim = Sim()
    storage = ReplicatedSimStorage(sim, model, n_replicas=3, seed=0,
                                   topology=topo, lease_ms=1e9)
    storage.fail_replica(0, at=0.0)
    nodes = ["c", "p0", "p1"]
    tmo = 50.0 * rtt
    cfg = ProtocolConfig(protocol="cornus", topology=topo,
                         vote_timeout_ms=tmo, decision_timeout_ms=tmo,
                         votereq_timeout_ms=tmo, termination_retry_ms=tmo,
                         coop_retry_ms=tmo)
    cl = Cluster(sim, storage, nodes, cfg)
    cl.run_txn(TxnSpec(txn_id="t1", coordinator="c",
                       participants=["p0", "p1"]))
    sim.run(until=5_000.0)
    first = cl.outcomes[("t1", "c")]
    assert first.decision == Decision.COMMIT
    cl.run_txn(TxnSpec(txn_id="t2", coordinator="c",
                       participants=["p0", "p1"]))
    sim.run(until=10_000.0)
    second = cl.outcomes[("t2", "c")]
    assert second.decision == Decision.COMMIT
    predicted = predicted_caller_latency_ms("cornus", rtt)
    # First commit additionally waits out the one-time bulk prepare.
    assert predicted < first.caller_latency_ms <= predicted + 2 * rtt
    assert second.caller_latency_ms == predicted
    assert storage.lease_acquisitions == 1


# ---------------------------------------------------------------------------
# Acceptance: post-failover steady-state throughput within 1.2x of prefail
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("proto", ["cornus", "2pc"])
def test_postfailover_throughput_within_bound(proto):
    def wl(nodes, seed):
        return YCSBWorkload(nodes, accesses_per_txn=4, partition_theta=0.9,
                            keys_per_partition=10_000, seed=seed)

    tput = {}
    for name, fails in (("prefail", ()), ("postfail", ((0, 0.0),))):
        cfg = BenchConfig(protocol=proto, n_nodes=4, threads_per_node=8,
                          horizon_ms=300.0, replication=3, seed=3,
                          storage_serial=True, batch_max=64,
                          timeout_ms=60.0, replica_failures=fails)
        r = run_bench(wl, AZURE_REDIS, cfg)
        tput[name] = r.throughput_tps
        if name == "postfail":
            assert r.lease_acquisitions >= 1
            assert r.fast_path_ops > 10 * max(r.fallback_ops, 1)
    assert tput["prefail"] <= 1.2 * tput["postfail"], tput


# ---------------------------------------------------------------------------
# Regression: _finish_fallback must route via the first ALIVE replica
# ---------------------------------------------------------------------------
def test_fallback_log_waits_out_total_outage_instead_of_scattering():
    """A batched plain log whose flush finds every replica dead: the
    fallback must wait for a leader, NOT scatter from dead replica 0's
    position (`_leader_idx() or 0` conflated "leader is 0" with "nobody
    is alive")."""
    sim = Sim()
    st = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3, seed=1,
                              batch=BatchConfig(window_ms=5.0, serial=True))
    ev = st.log("p", "t", Vote.COMMIT, writer="p")
    for i in range(3):
        st.fail_replica(i, at=1.0, recover_at=500.0)
    sim.run(until=400.0)
    assert not ev.triggered
    trips_during_outage = st.round_trips
    sim.run(until=450.0)     # still down: no futile scatter spinning
    assert st.round_trips == trips_during_outage
    sim.run(until=100_000.0)
    assert ev.value == Vote.COMMIT
    assert st.snapshot()[("p", "t")] == Vote.COMMIT


# ---------------------------------------------------------------------------
# Threaded ReplicatedStore leases (wall-clock bounded)
# ---------------------------------------------------------------------------
def test_threaded_store_lease_grants_fast_path_to_holder():
    st = ReplicatedStore(n_replicas=3)
    lease = st.acquire_lease("h0", duration_s=30.0)
    assert isinstance(lease, StoreLease) and lease.epoch == 2
    assert st.log_once("pX", "t1", Vote.VOTE_YES, writer="h0") \
        == Vote.VOTE_YES
    # Non-owner slot, but leaseholder: served on the fast path.
    assert st.fast_path_ops == 1 and st.fallback_ops == 0
    # A competing CAS still wins the slot race rules (single winner).
    assert st.log_once("pX", "t1", Vote.ABORT, writer="other") \
        == Vote.VOTE_YES


def test_threaded_store_expired_lease_falls_back():
    st = ReplicatedStore(n_replicas=3)
    st.acquire_lease("h0", duration_s=0.0)          # born expired
    assert st.current_lease() is None
    assert st.log_once("pX", "t1", Vote.VOTE_YES, writer="h0") \
        == Vote.VOTE_YES
    assert st.fallback_ops == 1                     # paid prepare+accept
    assert st.read_state("pX", "t1") == Vote.VOTE_YES


def test_partial_lease_recovery_pins_slot_off_fast_path():
    """The reporter of an in-flight value dies BETWEEN the bulk prepare
    and the recovery accept round, so the re-propose misses quorum: the
    slot must be PINNED — a later conflicting write through the valid
    lease goes via the full proposer and adopts the possibly-chosen value
    instead of overwriting it at the epoch ballot."""
    sim = Sim()
    st = ReplicatedSimStorage(sim, AZURE_REDIS, n_replicas=3, seed=1)
    key = ("p", "tV")
    # V chosen in epoch 1 by {r0, r2}; the proposer crashed before learn.
    st.replicas[0].accept(key, OWNER_BALLOT, Vote.VOTE_YES)
    st.replicas[2].accept(key, OWNER_BALLOT, Vote.VOTE_YES)
    st.fail_replica(0, at=0.0)
    # r2 reports V during prepare_epoch (~t=1.4) but is down for the
    # recovery accept (~t=3.8); it recovers with its epoch-1 accept only.
    st.fail_replica(2, at=2.5, recover_at=30.0)
    out = {}

    def trigger():
        out["t0"] = yield st.log_once("q", "t0", Vote.VOTE_YES, writer="q")

    sim.process(trigger())
    sim.run(until=40.0)
    assert st.lease_acquisitions == 1
    assert key in st._pinned, "unrecovered in-flight slot must be pinned"

    def conflicting():
        out["v"] = yield st.log_once("p", "tV", Vote.ABORT, writer="w")

    sim.process(conflicting())
    sim.run(until=100_000.0)
    assert out["v"] == Vote.VOTE_YES, \
        "fast path must not overwrite the possibly-chosen value"
    assert st.snapshot()[key] == Vote.VOTE_YES
    assert key not in st._pinned, "settled slot should be unpinned"


def test_threaded_partial_recovery_pins_slot():
    """Threaded store: a recovery re-propose that cannot reach quorum
    (slot promises held above the new epoch ballot) pins the slot, and
    the leaseholder's conflicting CAS adopts the in-flight value."""
    st = ReplicatedStore(n_replicas=3)
    key = ("p", "t")
    st.replicas[0].accept(key, OWNER_BALLOT, Vote.VOTE_YES)
    # Competing slot-level proposer promoted promises on a majority above
    # the epoch-2 ballot the lease will use.
    st.replicas[1].prepare(key, (9, 2, 99))
    st.replicas[2].prepare(key, (9, 2, 99))
    st.acquire_lease("h1", duration_s=30.0)
    assert key in st._pinned
    assert st.log_once("p", "t", Vote.ABORT, writer="h1") == Vote.VOTE_YES
    assert st.read_state("p", "t") == Vote.VOTE_YES


def test_threaded_store_get_data_prefers_fresh_rewrite():
    """A replica that was down during a payload rewrite recovers with its
    old copy intact (crash, not amnesia): quorum readers must pick the
    freshest version, not whichever alive replica answers first."""
    st = ReplicatedStore(n_replicas=3)
    st.put_data("h0", "s", b"v1")
    st.fail_replica(0)
    st.put_data("h0", "s", b"v2")       # lands on replicas 1, 2 only
    st.recover_replica(0)
    assert st.get_data("h0", "s") == b"v2"


def test_threaded_store_lease_completes_inflight_slots():
    """An accepted-but-undecided value left by a crashed proposer is
    completed by the next lease acquisition (Multi-Paxos recovery), so
    round-1 accepts can never contradict a possibly-chosen value."""
    st = ReplicatedStore(n_replicas=3)
    # Simulate a proposer that died after a quorum of accepts, pre-learn.
    for r in st.replicas:
        r.accept(("p", "t"), OWNER_BALLOT, Vote.VOTE_YES)
    st.acquire_lease("h1", duration_s=30.0)
    # The lease must have completed the slot with the in-flight value;
    # the leaseholder's own CAS of a DIFFERENT value must lose.
    assert st.log_once("p", "t", Vote.ABORT, writer="h1") == Vote.VOTE_YES
    assert st.read_state("p", "t") == Vote.VOTE_YES
