"""End-to-end trainer tests: loss goes down, checkpoints commit, a mid-epoch
crash restarts EXACTLY (the Cornus restore + stateless pipeline combination),
and elastic restarts onto different fleet sizes work.
"""
import os

import numpy as np
import pytest

from repro.ckpt import latest_committed
from repro.core.state import Decision
from repro.core.storage import FileStore
from repro.launch.train import (MidCheckpointCrash, RunConfig, RunResult,
                                train, _hosts)

# Real multi-step training runs — minutes of CPU per test.
pytestmark = pytest.mark.slow


def base_run(tmp, **kw):
    d = dict(arch="llama3.2-1b", steps=24, batch=4, seq_len=64,
             ckpt_every=8, ckpt_dir=str(tmp), n_hosts=3, log_every=0,
             lr=3e-3, seed=7)
    d.update(kw)
    return RunConfig(**d)


def test_loss_decreases_and_ckpts_commit(tmp_path):
    # 32 steps (not 24) and wide 8-step averaging windows: at 24 steps the
    # loss plateaus for some seeds (warmup covers 20 of them, so barely 4
    # run at full lr) and the 4-step window verdict flips seed-dependently.
    # 12 full-lr steps + 8-step windows give a stable margin.
    res = train(base_run(tmp_path, steps=32))
    assert res.steps_done == 32
    first = np.mean(res.losses[:8])
    last = np.mean(res.losses[-8:])
    assert last < first, f"no learning: {first} -> {last}"
    assert len(res.ckpt_outcomes) == 4
    assert all(o.decision == Decision.COMMIT for o in res.ckpt_outcomes)
    store = FileStore(str(tmp_path))
    assert latest_committed(store, _hosts(3)) == 32


def test_crash_restart_is_exact(tmp_path):
    """Kill mid-checkpoint at step 16; restart must resolve the in-flight
    epoch (force-abort), restore epoch 8, and REPRODUCE the uncrashed loss
    curve exactly — checkpoint+data determinism end-to-end."""
    golden = train(base_run(tmp_path / "golden"))

    with pytest.raises(MidCheckpointCrash):
        train(base_run(tmp_path / "crash", die_mid_checkpoint_at=16))
    store = FileStore(str(tmp_path / "crash"))
    # In-flight epoch 16 resolves to ABORT; epoch 8 is the restore point.
    assert latest_committed(store, _hosts(3)) == 8

    resumed = train(base_run(tmp_path / "crash", resume=True))
    assert resumed.restored_from == 8
    # Steps 8..24 must match the golden run bit-for-bit (same data, same
    # restored state). Compare the overlapping region.
    np.testing.assert_allclose(resumed.losses, golden.losses[8:], rtol=1e-5)


def test_elastic_restart_smaller_fleet(tmp_path):
    train(base_run(tmp_path, steps=8, ckpt_every=8, n_hosts=4))
    res = train(base_run(tmp_path, steps=16, ckpt_every=8, n_hosts=2,
                         resume=True))
    # restore read the 4-host epoch, then the 2-host fleet kept going
    assert res.restored_from == 8
    assert res.steps_done == 16
    store = FileStore(str(tmp_path))
    assert latest_committed(store, _hosts(2)) == 16


def test_async_checkpoint_commits(tmp_path):
    res = train(base_run(tmp_path, async_ckpt=True))
    assert res.ckpt_outcomes and all(
        o.decision == Decision.COMMIT for o in res.ckpt_outcomes)


def test_byte_corpus_training(tmp_path):
    """Train on real bytes (this test file) — loss must drop fast on code."""
    src = os.path.abspath(__file__)
    res = train(base_run(tmp_path, data_source=f"bytes:{src}", steps=30,
                         ckpt_every=30))
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])
