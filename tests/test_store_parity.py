"""Sim-vs-threaded store parity: the SAME operation schedule replayed on
``SimStorage`` (discrete-event) and ``MemoryStore`` (real threads' store)
must converge to the SAME log state, writer winners, derived decisions —
and, with the unified control plane on, the same decision-cache counters.

Plus properties of the threaded control plane under genuinely concurrent
racing terminators: one winner per slot, txn-level agreement, and counter
conservation (every ``log_once`` call is exactly one of performed /
cache-answered / singleflight-joined).  Property-based when hypothesis is
installed; seeded deterministic versions always run.
"""
from __future__ import annotations

import random
import threading
import time

import pytest

from conftest import hypothesis_or_stubs

HAS_HYPOTHESIS, given, settings, st = hypothesis_or_stubs()

from repro.core import (AZURE_REDIS, Decision, DecisionCacheConfig,
                        MemoryStore, Sim, SimStorage, Vote, global_decision)

ALL_ON = DecisionCacheConfig(cache=True, singleflight=True, push=True)
NODES = ["p0", "p1", "p2", "p3"]


# ---------------------------------------------------------------------------
# Differential parity: one schedule, two backends
# ---------------------------------------------------------------------------
def make_schedule(seed: int, n_txns: int = 6):
    """Deterministic interleaved op list: per txn, participants CAS their
    VOTE-YES while a terminator may CAS ABORT anywhere in the sequence."""
    rng = random.Random(seed)
    ops = []
    for t in range(n_txns):
        txn = f"t{t}"
        parts = rng.sample(NODES, rng.randint(2, len(NODES)))
        txn_ops = [("vote", p, txn, p) for p in parts]
        if rng.random() < 0.5:
            terminator = rng.choice(parts)
            # The terminator CASes ABORT into EVERY slot (Algorithm 1).
            txn_ops += [("term", p, txn, terminator) for p in parts]
        rng.shuffle(txn_ops)
        ops.append((txn, parts, txn_ops))
    # Interleave txns' ops into one global schedule.
    flat = [op for _, _, txn_ops in ops for op in txn_ops]
    rng.shuffle(flat)
    return ops, flat


def replay_threaded(flat, decisions):
    store = MemoryStore(decisions=decisions)
    for kind, p, txn, writer in flat:
        store.log_once(p, txn, Vote.VOTE_YES if kind == "vote"
                       else Vote.ABORT, writer=writer)
    return store


def replay_sim(flat, decisions):
    sim = Sim()
    store = SimStorage(sim, AZURE_REDIS, seed=0, decisions=decisions)

    # Strictly sequential arrival (each op starts only after the previous
    # completed), so the schedule ORDER — not sim timing — decides races,
    # exactly like the sequential threaded replay.
    def runner():
        for kind, p, txn, writer in flat:
            yield store.log_once(p, txn, Vote.VOTE_YES if kind == "vote"
                                 else Vote.ABORT, writer=writer)

    sim.process(runner())
    sim.run(until=len(flat) * 1000.0 + 10_000.0)
    # SimStorage's ground truth lives in its inner MemoryStore: return that
    # (with the sim service's counters grafted on) so assertions read both
    # backends through one synchronous surface.
    inner = store.store
    inner.sim_decision_cache_hits = store.decision_cache_hits
    inner.sim_singleflight_hits = store.singleflight_hits
    return inner


def outcome_of(store, parts, txn):
    states = {p: store.read_state(p, txn) for p in parts}
    return global_decision(states, parts)


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("storm", [False, True])
def test_same_schedule_same_state_and_decisions(seed, storm):
    decisions = ALL_ON if storm else None
    ops, flat = make_schedule(seed)
    threaded = replay_threaded(flat, decisions)
    simmed = replay_sim(flat, decisions)
    for txn, parts, _ in ops:
        for p in parts:
            assert threaded.read_state(p, txn) == simmed.read_state(p, txn)
            assert threaded.writer_of(p, txn) == simmed.writer_of(p, txn)
        assert outcome_of(threaded, parts, txn) == \
            outcome_of(simmed, parts, txn)
    if storm:
        # Same schedule, same control-plane semantics: identical counters.
        assert threaded.decision_cache_hits == simmed.sim_decision_cache_hits
        assert threaded.singleflight_hits == simmed.sim_singleflight_hits


if HAS_HYPOTHESIS:
    @given(seed=st.integers(min_value=0, max_value=10_000))
    @settings(max_examples=25, deadline=None)
    def test_parity_property(seed):
        ops, flat = make_schedule(seed)
        threaded = replay_threaded(flat, ALL_ON)
        simmed = replay_sim(flat, ALL_ON)
        for txn, parts, _ in ops:
            assert outcome_of(threaded, parts, txn) == \
                outcome_of(simmed, parts, txn)
            for p in parts:
                assert threaded.writer_of(p, txn) == simmed.writer_of(p, txn)
        assert threaded.decision_cache_hits == simmed.sim_decision_cache_hits


# ---------------------------------------------------------------------------
# Threaded control plane under racing terminators
# ---------------------------------------------------------------------------
class _GatedStore(MemoryStore):
    """MemoryStore whose CAS parks until released — forces genuine overlap
    so singleflight joins are deterministic, not a race lottery."""

    def __init__(self, decisions=None):
        super().__init__(decisions=decisions)
        self.gate = threading.Event()

    def _log_once_direct(self, partition, txn, state, writer=""):
        self.gate.wait(timeout=5.0)
        return super()._log_once_direct(partition, txn, state, writer)


def test_singleflight_joins_and_cache_hits_deterministic():
    store = _GatedStore(decisions=ALL_ON)
    results = []

    def call():
        results.append(store.log_once("p0", "t0", Vote.ABORT, writer="w"))

    racers = [threading.Thread(target=call) for _ in range(4)]
    for r in racers:
        r.start()
    time.sleep(0.05)                     # all four are in log_once now
    store.gate.set()
    for r in racers:
        r.join()
    # One leader performed, three joined its in-flight round.
    assert store.cas_attempts == 1
    assert store.singleflight_hits == 3
    assert results == [Vote.ABORT] * 4
    # The txn now holds a terminal record: later calls are cache hits, the
    # op itself never runs (cas_attempts unchanged).
    assert store.log_once("p1", "t0", Vote.VOTE_YES, writer="p1") \
        == Vote.ABORT
    assert store.decision_cache_hits == 1
    assert store.cas_attempts == 1


def test_singleflight_joiners_share_leader_exception():
    class _Exploding(_GatedStore):
        def _log_once_direct(self, partition, txn, state, writer=""):
            self.gate.wait(timeout=5.0)
            raise RuntimeError("quorum lost mid-round")

    store = _Exploding(decisions=ALL_ON)
    errors = []

    def call():
        try:
            store.log_once("p0", "t0", Vote.ABORT, writer="w")
        except RuntimeError as e:
            errors.append(str(e))

    racers = [threading.Thread(target=call) for _ in range(3)]
    for r in racers:
        r.start()
    time.sleep(0.05)
    store.gate.set()
    for r in racers:
        r.join()
    # A joiner of a failed round must NOT pretend it succeeded.
    assert errors == ["quorum lost mid-round"] * 3


def race_terminators(seed: int, racers: int = 4, slots: int = 3):
    """Concurrent voter + ABORT racers over one txn's slots; returns the
    store and every caller's observed return value."""
    rng = random.Random(seed)
    store = MemoryStore(decisions=ALL_ON)
    parts = [f"p{i}" for i in range(slots)]
    txn = "t0"
    observed = []
    lock = threading.Lock()

    def voter():
        for p in parts:
            time.sleep(rng.random() * 1e-3)
            got = store.log_once(p, txn, Vote.VOTE_YES, writer=p)
            with lock:
                observed.append((p, Vote.VOTE_YES, got))

    def terminator(tid):
        r = random.Random(seed * 997 + tid)
        for p in sorted(parts, key=lambda _: r.random()):
            time.sleep(r.random() * 1e-3)
            got = store.log_once(p, txn, Vote.ABORT, writer=f"term{tid}")
            with lock:
                observed.append((p, Vote.ABORT, got))

    threads = [threading.Thread(target=voter)] + \
        [threading.Thread(target=terminator, args=(t,))
         for t in range(racers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return store, parts, txn, observed


def check_race_invariants(store, parts, txn, observed, calls):
    finals = {p: store.read_state(p, txn) for p in parts}
    # Terminal txn decision, if any (ABORT is the only decision written).
    terminal = Vote.ABORT if any(v == Vote.ABORT for v in finals.values()) \
        else None
    # One winner per slot: every observed return is the slot's final value
    # or the txn's terminal decision (a cache answer) — never a third value.
    for p, _attempt, got in observed:
        assert got in {finals[p], terminal} - {None}
    # writer_of consistent with the recorded value's writer kind.
    for p in parts:
        w = store.writer_of(p, txn)
        assert (finals[p] == Vote.ABORT) == (w is not None
                                             and w.startswith("term"))
    # Counter conservation: performed + cache-answered + joined == calls.
    assert store.cas_attempts + store.decision_cache_hits + \
        store.singleflight_hits == calls


@pytest.mark.parametrize("seed", range(6))
def test_racing_terminators_invariants(seed):
    racers, slots = 4, 3
    store, parts, txn, observed = race_terminators(seed, racers, slots)
    check_race_invariants(store, parts, txn, observed,
                          calls=slots * (racers + 1))


if HAS_HYPOTHESIS:
    @given(seed=st.integers(min_value=0, max_value=10_000),
           racers=st.integers(min_value=1, max_value=6),
           slots=st.integers(min_value=1, max_value=4))
    @settings(max_examples=20, deadline=None)
    def test_racing_terminators_property(seed, racers, slots):
        store, parts, txn, observed = race_terminators(seed, racers, slots)
        check_race_invariants(store, parts, txn, observed,
                              calls=slots * (racers + 1))
