"""Wall-clock harness smoke tests (threaded stores, real threads) and the
checkpoint committer's automatic lease upkeep."""
from __future__ import annotations

import pytest

from repro.ckpt.commit import CornusCheckpointer
from repro.core import Decision, ReplicatedStore, Vote
from repro.txn.threaded import (WALLCLOCK_BACKENDS, WallclockConfig,
                                run_wallclock, wallclock_rows)


def small(protocol, backend, **kw):
    base = dict(protocol=protocol, backend=backend, workers=2,
                txns_per_worker=16, service_delay_ms=0.3,
                straggler_every=4, straggler_delay_ms=30.0,
                terminators=2, seed=5)
    base.update(kw)
    return WallclockConfig(**base)


def test_rows_cover_table3():
    rows = wallclock_rows()
    assert set(rows) == {"2pc", "cornus", "cornus-opt1", "2pc-coloc",
                         "cornus-coloc", "paxos-commit"}
    for protocol, backend in rows.values():
        assert backend in WALLCLOCK_BACKENDS.values()


@pytest.mark.parametrize("protocol", ["cornus", "2pc"])
def test_memory_rows_commit_and_storm_counters(protocol):
    r = run_wallclock(small(protocol, "memory"))
    assert r.commits + r.terminated == 2 * 16
    assert r.commits > 0
    assert r.throughput_tps > 0
    # The straggler storm really engaged the threaded control plane: with a
    # 30ms stall and sub-ms racer rounds the terminators always win some.
    assert r.terminated > 0
    assert r.singleflight_hits > 0
    assert r.decisions_pushed > 0
    if protocol == "cornus":
        # The woken straggler's own LogOnce vote finds the terminal record
        # in the index.  (2PC votes go through plain ``log``, so its cache
        # hits only appear when racers arrive after the commit record —
        # timing-dependent; the bench checks the aggregate instead.)
        assert r.decision_cache_hits > 0


def test_replicated_row_rides_the_lease_fast_path():
    r = run_wallclock(small("cornus", "replicated"))
    assert r.commits > 0
    assert r.lease_acquisitions >= 1
    assert r.fast_path_ops > 0


def test_storm_off_means_no_control_counters():
    from repro.core import DecisionCacheConfig
    r = run_wallclock(small("cornus", "memory", straggler_every=0,
                            decisions=DecisionCacheConfig()))
    assert r.commits == 2 * 16
    assert r.decision_cache_hits == 0
    assert r.singleflight_hits == 0
    assert r.decisions_pushed == 0


# ---------------------------------------------------------------------------
# Checkpoint committer + LeaseKeeper
# ---------------------------------------------------------------------------
def test_checkpointer_acquires_lease_on_replicated_store():
    store = ReplicatedStore(n_replicas=3, seed=2)
    hosts = ["h0", "h1"]
    cps = {h: CornusCheckpointer(store, h, hosts, straggler_timeout_s=2.0)
           for h in hosts}
    for h in hosts:
        assert cps[h].vote(1, b"shard") == Vote.VOTE_YES
    d, forced = cps["h0"].resolve(1)
    assert d == Decision.COMMIT and forced == 0
    # The first committer to write holds the lease; its votes rode the
    # phase-1-free fast path.
    assert store.lease_acquisitions >= 1
    assert store.fast_path_ops > 0


def test_checkpointer_degrades_when_lease_unavailable():
    store = ReplicatedStore(n_replicas=3, seed=2)
    cp = CornusCheckpointer(store, "h0", ["h0", "h1"],
                            straggler_timeout_s=0.1, poll_interval_s=0.01)
    store.fail_replica(0)
    store.fail_replica(1)
    # No quorum: lease upkeep degrades to the slow path (host identity)
    # without raising out of the renewal attempt.
    assert cp._writer() == "h0"
    assert cp.lease.failures == 1
    store.recover_replica(0)
    store.recover_replica(1)
    # Quorum back: the epoch fast path engages and the epoch commits.
    out = cp.save(7, b"payload")
    # h1 never votes, so h0's termination protocol force-aborts it — the
    # save completes (non-blocking) rather than erroring.
    assert out.decision == Decision.ABORT
    assert store.lease_acquisitions >= 1


def test_checkpointer_on_plain_store_never_touches_leases(tmp_path):
    from repro.core import FileStore
    store = FileStore(str(tmp_path))
    cp = CornusCheckpointer(store, "h0", ["h0"])
    assert not cp.lease.supported
    out = cp.save(1, b"x")
    assert out.decision == Decision.COMMIT
