"""Serving SLO accounting: tail latency, goodput, TTFT, disruption.

One ``LatencyRecorder`` per engine run collects per-step samples from all
client threads; ``report()`` folds them into an ``SloReport`` — the unit
the serve bench sweeps per (protocol, arrival rate, batch mode) cell:

  p50/p95/p99        – end-to-end step latency (queue + decode + commit),
                       nearest-rank percentiles (``txn.executor.percentile``).
  tail amplification – p99/p50: how much worse the tail is than the median.
                       This is where 2PC's extra forced decision write
                       shows up even when medians look comparable.
  goodput            – committed steps that ALSO met their deadline, per
                       second.  Drops, rejects, aborts, and late commits
                       all count against goodput but not against raw
                       throughput.
  TTFT               – time-to-first-token per session (first step's
                       end-to-end latency, the user-visible startup cost).
  disruption         – throughput inside a marked window (a checkpoint
                       publish, a replica kill) divided by throughput
                       outside it; 1.0 = the event was free.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from ..txn.executor import percentile

__all__ = ["LatencyRecorder", "SloReport", "windowed_tput"]


def windowed_tput(times: List[float], start: float, end: float) -> float:
    """Completions per second inside [start, end)."""
    if end <= start:
        return 0.0
    n = sum(1 for t in times if start <= t < end)
    return n / (end - start)


@dataclass
class SloReport:
    protocol: str = ""
    arrival: str = "closed"
    batch_mode: str = "batched"
    # Counts.
    completed: int = 0          # steps that came back from decode
    committed: int = 0          # ... and committed their txn
    aborted: int = 0            # ... but the commit lost to a termination
    dropped: int = 0            # shed by deadline or shutdown
    rejected: int = 0           # shed by backpressure
    # Rates.
    elapsed_s: float = 0.0
    throughput_tps: float = 0.0
    goodput_tps: float = 0.0
    # Latency (ms).
    p50_ms: float = 0.0
    p95_ms: float = 0.0
    p99_ms: float = 0.0
    tail_amplification: float = 0.0
    ttft_p50_ms: float = 0.0
    ttft_p99_ms: float = 0.0
    mean_batch: float = 0.0
    # Throughput inside the marked event window / outside it (None when no
    # window was marked).
    publish_disruption: Optional[float] = None
    # Durability lifecycle (all zero unless the store was built with a
    # LifecycleConfig): anti-entropy repairs, volumes quarantined, slots
    # truncated by the GC watermark, and slots still behind it at run end.
    scrub_repairs: int = 0
    quarantines: int = 0
    gc_truncations: int = 0
    watermark_lag: int = 0

    def to_dict(self) -> dict:
        return {k: v for k, v in self.__dict__.items()}


class LatencyRecorder:
    """Thread-safe sample sink shared by every client thread of one run."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._lat_ms: List[float] = []
        self._ttft_ms: List[float] = []
        self._done_at: List[float] = []      # monotonic completion stamps
        self._good: int = 0
        self.committed = 0
        self.aborted = 0
        self.dropped = 0
        self.rejected = 0
        self._windows: List[Tuple[float, float]] = []

    # -- sample intake ------------------------------------------------------
    def record_step(self, latency_ms: float, committed: bool,
                    within_deadline: bool, t_done: float,
                    first: bool = False) -> None:
        with self._lock:
            self._lat_ms.append(latency_ms)
            self._done_at.append(t_done)
            if first:
                self._ttft_ms.append(latency_ms)
            if committed:
                self.committed += 1
                if within_deadline:
                    self._good += 1
            else:
                self.aborted += 1

    def record_drop(self) -> None:
        with self._lock:
            self.dropped += 1

    def record_reject(self) -> None:
        with self._lock:
            self.rejected += 1

    def mark_window(self, start: float, end: float) -> None:
        """Mark a disruption window (publish / failure injection)."""
        with self._lock:
            self._windows.append((start, end))

    # -- folding ------------------------------------------------------------
    def report(self, elapsed_s: float, run_start: float,
               protocol: str = "", arrival: str = "closed",
               batch_mode: str = "batched",
               mean_batch: float = 0.0) -> SloReport:
        with self._lock:
            lat = list(self._lat_ms)
            ttft = list(self._ttft_ms)
            done = list(self._done_at)
            windows = list(self._windows)
            rep = SloReport(
                protocol=protocol, arrival=arrival, batch_mode=batch_mode,
                completed=len(lat), committed=self.committed,
                aborted=self.aborted, dropped=self.dropped,
                rejected=self.rejected, elapsed_s=elapsed_s,
                mean_batch=mean_batch)
        rep.throughput_tps = (rep.committed / elapsed_s
                              if elapsed_s > 0 else 0.0)
        rep.goodput_tps = self._good / elapsed_s if elapsed_s > 0 else 0.0
        rep.p50_ms = percentile(lat, 0.50)
        rep.p95_ms = percentile(lat, 0.95)
        rep.p99_ms = percentile(lat, 0.99)
        rep.tail_amplification = (rep.p99_ms / rep.p50_ms
                                  if rep.p50_ms > 0 else 0.0)
        rep.ttft_p50_ms = percentile(ttft, 0.50)
        rep.ttft_p99_ms = percentile(ttft, 0.99)
        if windows:
            run_end = run_start + elapsed_s
            inside = 0.0
            in_n = 0
            for (ws, we) in windows:
                ws, we = max(ws, run_start), min(we, run_end)
                if we > ws:
                    inside += we - ws
                    in_n += sum(1 for t in done if ws <= t < we)
            outside = max(1e-9, elapsed_s - inside)
            out_rate = (len(done) - in_n) / outside
            in_rate = in_n / inside if inside > 0 else 0.0
            rep.publish_disruption = (in_rate / out_rate
                                      if out_rate > 0 else 1.0)
        return rep
