"""Transactional model serving: every session step is an atomic commit.

The subsystem the paper's numbers argue for: an inference session's state
changes (open, per-token KV-cache update, close) are distributed
transactions over the partitioned KV store, committed through any
registered protocol — so the Cornus-vs-2PC latency gap shows up directly
as serving tail latency, goodput, and publish-window disruption.

  session    – sessions as transactions (``SessionManager``/``commit_txn``)
  admission  – continuous-batching ingress (bounded queue, backpressure,
               deadline drops; Pallas decode or a latency-model stub)
  engine     – closed/open-loop serving with failure + publish injection
  publisher  – background Cornus checkpoint epochs mid-traffic
  slo        – p50/p95/p99, tail amplification, goodput, TTFT, disruption
"""
from .admission import (AdmissionConfig, ContinuousBatcher, StepRequest,
                        StubDecode, make_decode)
from .engine import EngineConfig, ServeEngine, ServeResult, run_serve
from .publisher import CheckpointPublisher, PublishRecord
from .session import (Session, SessionConfig, SessionManager, StepOutcome,
                      build_session_store)
from .slo import LatencyRecorder, SloReport

__all__ = [
    "AdmissionConfig", "CheckpointPublisher", "ContinuousBatcher",
    "EngineConfig", "LatencyRecorder", "PublishRecord", "ServeEngine",
    "ServeResult", "Session", "SessionConfig", "SessionManager",
    "SloReport", "StepOutcome", "StepRequest", "StubDecode",
    "build_session_store", "make_decode", "run_serve",
]
