"""The serving engine: sessions × admission × commit × publish, one loop.

``ServeEngine.run()`` drives a full serving experiment:

  clients    – closed-loop (each client streams its session's steps
               back-to-back) or open-loop (Poisson arrivals at ``rate_rps``
               with load shedding) arrival processes.
  admission  – every step goes through the ``ContinuousBatcher``; the
               decode result is only acknowledged after the step's KV-cache
               update COMMITS through the session's protocol.  End-to-end
               step latency = queue + decode + commit.
  publish    – between the ``publish_at`` and ``publish_until`` fractions
               of the run a background ``CheckpointPublisher`` commits
               snapshot epochs through the same store; the recorder marks
               the window so the report can price the disruption.
  failures   – ``kill_replica_at`` fails one replica of a replicated store
               mid-run (quorum survives, serving must too);
               ``revive_replica_at`` brings the killed replica back through
               recovery-driven state transfer (kill-then-rejoin);
               ``scale_at``/``scale_to`` fire a live membership change
               (``set_replication``) — a scale event is a fault-injection
               hook like the others; ``stall_at`` parks one session step
               mid-vote and lets a scavenger CAS-terminate it (the
               non-blocking §3.3 path) — the engine keeps serving through
               all of them.

The engine never stalls on any of these: that is the claim the serve bench
gates (publish-window throughput ≥ 80% of steady state, with a replica
volume dead).
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .admission import AdmissionConfig, ContinuousBatcher, StepRequest, \
    make_decode
from .publisher import CheckpointPublisher, PublishRecord
from .session import Session, SessionConfig, SessionManager, \
    build_session_store
from .slo import LatencyRecorder, SloReport

__all__ = ["EngineConfig", "ServeEngine", "ServeResult", "run_serve"]


@dataclass
class EngineConfig:
    session: SessionConfig = field(default_factory=SessionConfig)
    admission: AdmissionConfig = field(default_factory=AdmissionConfig)
    decode: str = "stub"               # "stub" | "pallas" | "auto"
    decode_kwargs: Dict = field(default_factory=dict)
    clients: int = 8
    steps_per_session: int = 25        # closed loop
    arrival: str = "closed"            # "closed" | "open"
    rate_rps: float = 400.0            # open loop arrival rate
    duration_s: float = 1.5            # open loop run length
    batch_mode: str = "batched"        # "batched" | "unbatched"
    max_inflight: int = 256            # open loop shed bound
    # Background publishing window, as fractions of run progress.
    publish_at: Optional[float] = None
    publish_until: Optional[float] = None     # default publish_at + 0.3
    publish_hosts: int = 2
    publish_payload_bytes: int = 1 << 12
    publish_interval_s: float = 0.02
    # Failure injection.
    kill_replica_at: Optional[float] = None   # replicated backend only
    revive_replica_at: Optional[float] = None  # rejoin the killed replica
    scale_at: Optional[float] = None          # live membership change...
    scale_to: Optional[int] = None            # ...to this replication R
    stall_at: Optional[float] = None          # park a step, scavenge it
    stall_ms: float = 50.0
    seed: int = 0


@dataclass
class ServeResult:
    report: SloReport
    publishes: List[PublishRecord] = field(default_factory=list)
    counters: Dict[str, float] = field(default_factory=dict)


class ServeEngine:
    def __init__(self, cfg: EngineConfig) -> None:
        self.cfg = cfg
        adm = cfg.admission
        if cfg.batch_mode == "unbatched":
            # Same queue, same deadlines — batches of one.  The sweep's
            # control arm: what continuous batching buys.
            adm = AdmissionConfig(
                max_batch=1, window_ms=0.0, queue_depth=adm.queue_depth,
                backpressure=adm.backpressure, deadline_ms=adm.deadline_ms)
        elif cfg.batch_mode != "batched":
            raise ValueError(f"batch_mode must be 'batched' or "
                             f"'unbatched', got {cfg.batch_mode!r}")
        self.adm = adm
        self.store = build_session_store(cfg.session)
        self.mgr = SessionManager(self.store, cfg.session)
        self.batcher = ContinuousBatcher(
            make_decode(cfg.decode, **cfg.decode_kwargs), adm)
        self.recorder = LatencyRecorder()
        self.publisher: Optional[CheckpointPublisher] = None
        self._pub_started_at: Optional[float] = None
        self._fired = set()
        self._done_steps = 0
        self._lock = threading.Lock()
        self._stall_pending = False
        self.replica_killed: Optional[int] = None
        self.replica_revived: Optional[int] = None
        self._scale_thread: Optional[threading.Thread] = None

    # -- progress-fraction event triggers -----------------------------------
    def _maybe_fire(self, frac: float) -> None:
        cfg = self.cfg
        if (cfg.kill_replica_at is not None and frac >= cfg.kill_replica_at
                and "kill" not in self._fired):
            with self._lock:
                if "kill" in self._fired:
                    return
                self._fired.add("kill")
            if hasattr(self.store, "fail_replica"):
                # Kill the highest MEMBER replica: never index 0, which sim
                # configs treat as the leader-colocated one, and never a
                # retired id (a non-member kill is a no-op after scale-in).
                m = getattr(self.store, "membership", None)
                idx = (max(m.replica_ids) if m is not None
                       else len(self.store.replicas) - 1)
                self.store.fail_replica(idx)
                self.replica_killed = idx
        if (cfg.revive_replica_at is not None
                and frac >= cfg.revive_replica_at
                and "revive" not in self._fired):
            with self._lock:
                if "revive" in self._fired:
                    return
                self._fired.add("revive")
            idx = self.replica_killed
            if idx is not None and hasattr(self.store, "revive_replica"):
                # Rejoin through recovery-driven state transfer, not a bare
                # liveness flip: the volume missed writes while dead.
                self.store.revive_replica(idx)
                self.replica_revived = idx
            elif idx is not None and hasattr(self.store, "recover_replica"):
                self.store.recover_replica(idx)
                self.replica_revived = idx
        if (cfg.scale_at is not None and cfg.scale_to is not None
                and frac >= cfg.scale_at and "scale" not in self._fired):
            with self._lock:
                if "scale" in self._fired:
                    return
                self._fired.add("scale")
            if hasattr(self.store, "set_replication"):
                # Reconfiguration does bulk state transfer + an epoch bump;
                # run it beside the serving loop, not inside a step.
                th = threading.Thread(
                    target=self.store.set_replication,
                    args=(cfg.scale_to,), daemon=True)
                th.start()
                self._scale_thread = th
        if (cfg.publish_at is not None and frac >= cfg.publish_at
                and "pub" not in self._fired):
            with self._lock:
                if "pub" in self._fired:
                    return
                self._fired.add("pub")
            hosts = [f"pub{i}" for i in range(cfg.publish_hosts)]
            self.publisher = CheckpointPublisher(
                self.store, hosts,
                payload_bytes=cfg.publish_payload_bytes,
                interval_s=cfg.publish_interval_s).start()
            self._pub_started_at = time.monotonic()
        until = (cfg.publish_until if cfg.publish_until is not None
                 else (cfg.publish_at + 0.3
                       if cfg.publish_at is not None else None))
        if (until is not None and frac >= until
                and "pub" in self._fired and "pub_stop" not in self._fired):
            with self._lock:
                if "pub_stop" in self._fired:
                    return
                self._fired.add("pub_stop")
            self._stop_publisher()
        if (cfg.stall_at is not None and frac >= cfg.stall_at
                and "stall" not in self._fired):
            with self._lock:
                if "stall" in self._fired:
                    return
                self._fired.add("stall")
                self._stall_pending = True

    def _stop_publisher(self) -> None:
        if self.publisher is not None and self._pub_started_at is not None:
            self.publisher.stop()
            self.recorder.mark_window(self._pub_started_at,
                                      time.monotonic())
            self._pub_started_at = None

    def _take_stall(self, session: Session):
        """Claim the pending coordinator stall: returns a ``before_vote``
        that parks THIS step mid-vote while a scavenger CAS-terminates it
        — the step must come back ABORTED, not hang."""
        with self._lock:
            if not self._stall_pending:
                return None
            self._stall_pending = False
        mgr, cfg = self.mgr, self.cfg
        txn = session.step_txn(session.steps)
        parts = list(session.partitions)

        def park(i: int, _p: str) -> None:
            if i == len(parts) - 1:
                threading.Thread(
                    target=mgr.terminate_step,
                    args=(session.sid, txn, parts), daemon=True).start()
                time.sleep(cfg.stall_ms / 1e3)

        return park

    # -- one step end-to-end -------------------------------------------------
    def _serve_step(self, session: Session, step: int) -> None:
        t0 = time.monotonic()
        req = StepRequest(session.sid, step)
        if not self.batcher.submit(req):
            self.recorder.record_reject()
            return
        req.done.wait(timeout=30.0)
        if req.dropped or req.result is None:
            self.recorder.record_drop()
            return
        out = self.mgr.step(session, before_vote=self._take_stall(session))
        t1 = time.monotonic()
        within = req.deadline_at is None or t1 <= req.deadline_at
        self.recorder.record_step((t1 - t0) * 1e3, out.committed, within,
                                  t1, first=(step == 0))
        with self._lock:
            self._done_steps += 1

    # -- arrival processes ---------------------------------------------------
    def _run_closed(self) -> None:
        cfg = self.cfg
        total = max(1, cfg.clients * cfg.steps_per_session)

        def client_loop(ci: int) -> None:
            session = self.mgr.open_session(f"c{ci}")
            if not session.open:
                return
            for step in range(cfg.steps_per_session):
                self._maybe_fire(self._done_steps / total)
                self._serve_step(session, step)
            self.mgr.close_session(session)

        threads = [threading.Thread(target=client_loop, args=(ci,),
                                    daemon=True)
                   for ci in range(cfg.clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    def _run_open(self) -> None:
        cfg = self.cfg
        rng = random.Random(cfg.seed)
        sessions = [self.mgr.open_session(f"c{ci}")
                    for ci in range(cfg.clients)]
        locks = [threading.Lock() for _ in sessions]
        inflight = threading.Semaphore(cfg.max_inflight)
        workers: List[threading.Thread] = []
        t0 = time.monotonic()
        k = 0
        while True:
            now = time.monotonic()
            frac = (now - t0) / cfg.duration_s
            if frac >= 1.0:
                break
            self._maybe_fire(frac)

            def request(idx: int = k % len(sessions)) -> None:
                try:
                    # Steps of one session serialize (its step counter and
                    # KV length are a single stream); different sessions
                    # ride the batcher concurrently.
                    with locks[idx]:
                        s = sessions[idx]
                        if s.open:
                            self._serve_step(s, s.steps)
                finally:
                    inflight.release()

            if inflight.acquire(blocking=False):
                th = threading.Thread(target=request, daemon=True)
                th.start()
                workers.append(th)
            else:
                self.recorder.record_reject()   # open-loop load shedding
            k += 1
            time.sleep(rng.expovariate(cfg.rate_rps))
        for th in workers:
            th.join(timeout=30.0)
        for s, lk in zip(sessions, locks):
            with lk:
                if s.open:
                    self.mgr.close_session(s)

    # -- entry point ---------------------------------------------------------
    def run(self) -> ServeResult:
        cfg = self.cfg
        self.batcher.start()
        run_start = time.monotonic()
        try:
            if cfg.arrival == "closed":
                self._run_closed()
            elif cfg.arrival == "open":
                self._run_open()
            else:
                raise ValueError(f"arrival must be 'closed' or 'open', "
                                 f"got {cfg.arrival!r}")
        finally:
            elapsed = time.monotonic() - run_start
            self._stop_publisher()
            self.batcher.stop()
            if self._scale_thread is not None:
                self._scale_thread.join(timeout=30.0)
        report = self.recorder.report(
            elapsed, run_start, protocol=cfg.session.protocol,
            arrival=cfg.arrival, batch_mode=cfg.batch_mode,
            mean_batch=self.batcher.mean_batch)
        # Durability lifecycle counters (zero on stores built without a
        # LifecycleConfig — getattr keeps legacy stores working).
        report.scrub_repairs = getattr(self.store, "scrub_repairs", 0)
        report.quarantines = getattr(self.store, "quarantines", 0)
        report.gc_truncations = getattr(self.store, "gc_truncations", 0)
        wl = getattr(self.store, "watermark_lag", None)
        report.watermark_lag = wl() if callable(wl) else 0
        counters = {
            "submitted": self.batcher.submitted,
            "batches": self.batcher.batches,
            "max_batch_seen": self.batcher.max_batch_seen,
            "opens": self.mgr.opens,
            "closes": self.mgr.closes,
            "steps_committed": self.mgr.steps_committed,
            "steps_aborted": self.mgr.steps_aborted,
            "terminations": self.mgr.terminations,
            "decision_cache_hits": getattr(self.store,
                                           "decision_cache_hits", 0),
            "singleflight_hits": getattr(self.store,
                                         "singleflight_hits", 0),
            "fast_path_ops": getattr(self.store, "fast_path_ops", 0),
            "fallback_ops": getattr(self.store, "fallback_ops", 0),
            "replica_killed": (-1 if self.replica_killed is None
                               else self.replica_killed),
            "replica_revived": (-1 if self.replica_revived is None
                                else self.replica_revived),
            "reconfigurations": getattr(self.store, "reconfigurations", 0),
            "state_transfers": getattr(self.store, "state_transfers", 0),
            "replication": getattr(self.store, "n", 0),
            "lease_degradations": (self.mgr.keeper.degradations
                                   if self.mgr.keeper is not None else 0),
            "lease_reengagements": (self.mgr.keeper.reengagements
                                    if self.mgr.keeper is not None else 0),
        }
        pubs = list(self.publisher.records) if self.publisher else []
        return ServeResult(report=report, publishes=pubs,
                           counters=counters)


def run_serve(cfg: EngineConfig) -> ServeResult:
    """One-shot convenience: build an engine, run it, return the result."""
    return ServeEngine(cfg).run()
