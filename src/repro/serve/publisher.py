"""Background checkpoint publisher: model snapshots commit mid-traffic.

A serving fleet periodically publishes a new model snapshot (weights after
an online update, adapter swap, KV-prefix warmup...).  The publish is a
Cornus checkpoint epoch — every publisher host uploads its shard and
LogOnce-votes through ``CornusCheckpointer`` — run against the SAME store
the live session traffic is committing through.  The point the engine test
makes: because Cornus puts no eager decision record on the critical path
and its termination protocol never blocks, a publish (or a replica volume
dying under one) dents serving throughput by a bounded, small amount
instead of stalling the ingress queue behind a wedged coordinator.

The publisher is payload-agnostic: pass ``payload_of(epoch, host)`` to
publish real packed pytrees (``ckpt.shards.pack_tree``); the default is
seeded synthetic bytes so the serve bench never needs jax.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

from ..ckpt.commit import CornusCheckpointer
from ..core.state import Decision

__all__ = ["CheckpointPublisher", "PublishRecord"]


@dataclass
class PublishRecord:
    epoch: int
    decision: Decision
    ms: float                    # wall-clock for the whole epoch
    t_start: float               # monotonic stamps for window accounting
    t_end: float
    forced_aborts: int = 0


def _default_payload(nbytes: int) -> Callable[[int, str], bytes]:
    def payload_of(epoch: int, host: str) -> bytes:
        rng = random.Random((epoch, host))
        return rng.randbytes(nbytes)
    return payload_of


class CheckpointPublisher:
    """Commits snapshot epochs through ``CornusCheckpointer``s, one per
    publisher host, voting concurrently like a real fleet.

    ``publish_once`` runs a full epoch synchronously (the caller decides
    threading); ``start``/``stop`` run epochs every ``interval_s`` on a
    daemon thread for always-on background publishing.
    """

    def __init__(self, store, hosts: Sequence[str] = ("pub0", "pub1"),
                 payload_of: Optional[Callable[[int, str], bytes]] = None,
                 payload_bytes: int = 1 << 12,
                 interval_s: float = 0.25,
                 straggler_timeout_s: float = 2.0,
                 epoch0: int = 0) -> None:
        self.store = store
        self.hosts = list(hosts)
        self.payload_of = payload_of or _default_payload(payload_bytes)
        self.interval_s = interval_s
        self._ckpt = {h: CornusCheckpointer(
            store, h, self.hosts, straggler_timeout_s=straggler_timeout_s,
            poll_interval_s=0.005) for h in self.hosts}
        self._epoch = epoch0
        self.records: List[PublishRecord] = []
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- one epoch ----------------------------------------------------------
    def publish_once(self) -> PublishRecord:
        with self._lock:
            epoch = self._epoch
            self._epoch += 1
        t0 = time.monotonic()
        outcomes = [None] * len(self.hosts)

        def voter(i: int, h: str) -> None:
            outcomes[i] = self._ckpt[h].save(epoch,
                                             self.payload_of(epoch, h))

        threads = [threading.Thread(target=voter, args=(i, h), daemon=True)
                   for i, h in enumerate(self.hosts)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        t1 = time.monotonic()
        # All hosts converge on one decision (Lemma 1); any host's outcome
        # is the epoch's.
        decision = outcomes[0].decision if outcomes[0] else Decision.ABORT
        rec = PublishRecord(
            epoch=epoch, decision=decision, ms=(t1 - t0) * 1e3,
            t_start=t0, t_end=t1,
            forced_aborts=sum(o.forced_aborts for o in outcomes if o))
        with self._lock:
            self.records.append(rec)
        return rec

    # -- background loop ----------------------------------------------------
    def start(self) -> "CheckpointPublisher":
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        # First epoch fires immediately — a publish window that closes
        # within one interval still publishes.
        while True:
            try:
                self.publish_once()
            except Exception:
                # A failed publish (quorum loss mid-epoch) must never take
                # down serving; the next interval retries a fresh epoch.
                pass
            if self._stop.wait(self.interval_s):
                return

    def stop(self) -> List[PublishRecord]:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        with self._lock:
            return list(self.records)

    @property
    def committed_epochs(self) -> List[int]:
        with self._lock:
            return [r.epoch for r in self.records
                    if r.decision == Decision.COMMIT]
