"""Inference sessions as distributed transactions.

Every externally visible state change of an inference session — create,
per-token KV-cache/session-state update, close — is an atomic commit across
the storage partitions holding that session's KV-cache shards.  The commit
runs whatever registered ``CommitProtocol`` the config names, replaying the
Table-3 storage choreography (``repro.txn.threaded.commit_txn``) against a
threaded store built through the unified ``build_store`` factory:

  cornus family – one LogOnce(VOTE-YES) per shard partition, nothing else
                  on the critical path (commit == the collective vote).
  2pc           – one forced vote log per shard partition PLUS an eager
                  forced commit record before the step is acknowledged —
                  the extra write Cornus removes from every session step.
  cl            – a single coordinator decision record.

Writer identity rides on a ``LeaseKeeper`` when the store supports leases
(the replicated quorum store): steady-state session traffic then commits
through the phase-1-free owner-ballot fast path, and quorum loss degrades
to the full-prepare slow path instead of erroring.

Sessions are NOT blocked by a stalled peer: a session step parked mid-vote
(its serving thread died, GCed, or preempted) can be terminated by anyone
via ``terminate_step`` — LogOnce first-writer-wins makes the race safe, and
the parked step observes the terminal record instead of committing (the
paper's non-blocking property, §3.3).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

from ..core.control import LeaseKeeper, STORM_CONTROL, DecisionCacheConfig
from ..core.protocols import get_protocol
from ..core.state import Vote
from ..core.stores import StoreConfig, build_store, is_simulated
from ..txn.threaded import commit_txn

__all__ = ["Session", "SessionConfig", "SessionManager", "StepOutcome",
           "build_session_store"]


@dataclass
class SessionConfig:
    """How sessions map onto transactions and storage."""

    protocol: str = "cornus"           # any registered protocol name
    backend: str = "memory"            # threaded store registry name
    replication: int = 3               # replicated backend only
    kv_partitions: int = 8             # storage partitions holding KV shards
    participants_per_txn: int = 2      # shard partitions per session
    decisions: DecisionCacheConfig = field(default=STORM_CONTROL)
    lease: bool = True                 # LeaseKeeper writer identity
    service_delay_ms: float = 0.0      # injected per forced store op
    seed: int = 0


def build_session_store(cfg: SessionConfig):
    """Construct the session store through the unified factory.

    Simulated backends are rejected up front: session commits block the
    calling serving thread, while sim backends return Events that only a
    ``Sim`` loop can drive."""
    if is_simulated(cfg.backend):
        raise ValueError(
            f"SessionConfig.backend {cfg.backend!r} is a simulated store; "
            f"sessions commit from real serving threads — use a threaded "
            f"backend (memory / replicated / file)")
    return build_store(StoreConfig(
        backend=cfg.backend, seed=cfg.seed, decisions=cfg.decisions,
        replication=cfg.replication,
        service_delay_ms=cfg.service_delay_ms))


@dataclass
class Session:
    """One inference session: id, its KV-shard partitions, and a step
    cursor.  The partition list is stable for the session's lifetime —
    every step transaction commits across the same participant set."""

    sid: str
    client: str
    partitions: List[str]
    kv_len: int = 0                    # tokens appended so far
    steps: int = 0                     # step txns issued (committed or not)
    open: bool = False
    closed: bool = False

    @property
    def coordinator(self) -> str:
        return self.partitions[0]

    def step_txn(self, step: int) -> str:
        return f"{self.sid}/t{step}"


@dataclass
class StepOutcome:
    session: str
    step: int
    committed: bool
    commit_ms: float = 0.0


class SessionManager:
    """Opens, steps, closes, and terminates sessions over one store.

    Thread-safe: many serving threads drive their own sessions through a
    shared manager (the store and the lease keeper are the shared state).
    """

    def __init__(self, store, cfg: SessionConfig,
                 holder: str = "serve-leader") -> None:
        self.store = store
        self.cfg = cfg
        self.proto = get_protocol(cfg.protocol)
        self.keeper = (LeaseKeeper(store, holder=holder)
                       if cfg.lease and hasattr(store, "acquire_lease")
                       else None)
        self._lock = threading.Lock()
        self._next_sid = 0
        self.opens = 0
        self.closes = 0
        self.steps_committed = 0
        self.steps_aborted = 0
        self.terminations = 0

    # -- writer identity ----------------------------------------------------
    def writer_for(self, p: str) -> str:
        """Lease holder's identity when we hold a live lease (replicated
        fast path), else the partition itself (slow path / plain store)."""
        if self.keeper is not None:
            lease = self.keeper.ensure()
            if lease is not None:
                return lease.holder
        return p

    # -- placement ----------------------------------------------------------
    def _partitions_for(self, n: int) -> List[str]:
        """Deterministic shard placement: ``participants_per_txn``
        consecutive KV partitions starting at a session-derived offset, so
        load spreads while a session's participant set stays fixed."""
        k = max(1, min(self.cfg.participants_per_txn,
                       self.cfg.kv_partitions))
        base = (n * 2654435761 + self.cfg.seed) % self.cfg.kv_partitions
        return [f"kv{(base + i) % self.cfg.kv_partitions}"
                for i in range(k)]

    # -- lifecycle ----------------------------------------------------------
    def open_session(self, client: str) -> Session:
        with self._lock:
            n = self._next_sid
            self._next_sid += 1
        s = Session(sid=f"{client}-s{n}", client=client,
                    partitions=self._partitions_for(n))
        ok, _ms = self._commit(f"{s.sid}/open", s)
        s.open = ok
        if ok:
            with self._lock:
                self.opens += 1
        return s

    def step(self, session: Session,
             before_vote: Optional[Callable[[int, str], None]] = None
             ) -> StepOutcome:
        """Commit one KV-cache update transactionally across the session's
        shard partitions.  ``before_vote`` is the straggler-injection hook
        (the engine parks here to prove non-blocking termination)."""
        step = session.steps
        session.steps += 1
        ok, ms = self._commit(session.step_txn(step), session,
                              before_vote=before_vote)
        if ok:
            session.kv_len += 1
            with self._lock:
                self.steps_committed += 1
        else:
            with self._lock:
                self.steps_aborted += 1
        return StepOutcome(session.sid, step, ok, commit_ms=ms)

    def close_session(self, session: Session) -> bool:
        ok, _ms = self._commit(f"{session.sid}/close", session)
        session.closed = ok
        if ok:
            with self._lock:
                self.closes += 1
        return ok

    # -- termination (non-blocking resolution of a parked step) -------------
    def terminate_step(self, session_id: str, step_txn: str,
                       partitions: Sequence[str],
                       writer: str = "scavenger") -> bool:
        """CAS ABORT into every slot of a parked step transaction.

        Anyone may run this against a step whose serving thread stalled;
        first-writer-wins makes concurrent terminators and the (still
        parked) original committer converge on one outcome.  Returns True
        when the step ends ABORTED, False when its votes had already all
        landed (the step commits under the stalled thread's feet)."""
        results = []
        for p in partitions:
            try:
                results.append(self.store.log_once(p, step_txn, Vote.ABORT,
                                                   writer=writer))
            except Exception:
                return False           # quorum loss: leave it unresolved
        with self._lock:
            self.terminations += 1
        return any(r == Vote.ABORT for r in results)

    # -- the commit choreography -------------------------------------------
    def _commit(self, txn: str, session: Session,
                before_vote: Optional[Callable[[int, str], None]] = None
                ) -> tuple:
        t0 = time.monotonic()
        ok = commit_txn(self.store, self.proto, txn, session.coordinator,
                        session.partitions, writer_for=self.writer_for,
                        before_vote=before_vote)
        return ok, (time.monotonic() - t0) * 1e3
