"""Continuous-batching admission control for the serving engine.

The ingress queue is the same shape as the storage layer's
``GroupCommitIngress``: requests that arrive while a decode is in flight
coalesce into the next batch; a formation ``window_ms`` (counted from the
first request in the batch) trades per-step latency for batch occupancy;
a full batch flushes immediately.  On top of that it adds the two things
a serving frontend needs that a storage lane does not:

  backpressure – the queue is bounded (``queue_depth``); a submit against
                 a full queue either blocks the client (closed-loop) or is
                 rejected immediately (open-loop load shedding).
  deadlines    – each request carries an absolute deadline; requests that
                 expire while queued are dropped at batch formation,
                 before any decode compute is spent on them.

The decode call itself is pluggable: ``PallasDecode`` drives the
``kernels.decode_attention.flash_decode`` TPU kernel over a pooled KV
cache when jax is importable; ``StubDecode`` is a deterministic latency
model (one base cost per batch plus a per-item term — the same
amortization shape as the storage batch lanes) used by the wall-clock
benches so CI throughput is machine-independent.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

__all__ = ["AdmissionConfig", "ContinuousBatcher", "PallasDecode",
           "StepRequest", "StubDecode", "make_decode"]


@dataclass
class AdmissionConfig:
    max_batch: int = 8
    window_ms: float = 2.0          # batch formation window from 1st arrival
    queue_depth: int = 64           # bounded ingress queue
    backpressure: str = "block"     # "block" | "reject" on a full queue
    deadline_ms: Optional[float] = None   # per-request; None = no deadline

    def __post_init__(self) -> None:
        if self.backpressure not in ("block", "reject"):
            raise ValueError(f"backpressure must be 'block' or 'reject', "
                             f"got {self.backpressure!r}")


class StepRequest:
    """One decode step for one session, in flight through the batcher."""

    __slots__ = ("session", "token", "submitted_at", "deadline_at", "done",
                 "result", "dropped", "batch_size", "decode_ms")

    def __init__(self, session: str, token: int,
                 deadline_at: Optional[float] = None) -> None:
        self.session = session
        self.token = token
        self.submitted_at = time.monotonic()
        self.deadline_at = deadline_at
        self.done = threading.Event()
        self.result: Optional[int] = None
        self.dropped = False
        self.batch_size = 0
        self.decode_ms = 0.0


class StubDecode:
    """Latency-modeled batched decode: one batch costs
    ``base_ms + per_item_ms * len(batch)`` of sleep — batching amortizes
    the base term exactly like a storage flush amortizes a round trip.
    The returned token is a deterministic hash of (session, token)."""

    def __init__(self, base_ms: float = 1.0, per_item_ms: float = 0.1,
                 vocab: int = 50_000) -> None:
        self.base_ms = base_ms
        self.per_item_ms = per_item_ms
        self.vocab = vocab

    def __call__(self, reqs: Sequence[StepRequest]) -> List[int]:
        time.sleep((self.base_ms + self.per_item_ms * len(reqs)) / 1e3)
        return [(hash((r.session, r.token)) & 0x7FFFFFFF) % self.vocab
                for r in reqs]


class PallasDecode:
    """flash_decode-backed batched decode over a pooled KV cache.

    Maintains one preallocated (slots, Hkv, T, hd) K/V pool; each session
    owns a slot and a valid-prefix length.  A batch gathers its sessions'
    cache rows, runs ONE ``flash_decode`` call for the whole batch (the
    continuous-batching payoff: the memory-bound kernel streams every
    session's cache in a single grid), then appends the new K/V at each
    session's write position.  Q/K/V projections of the incoming token are
    stand-ins (seeded random features) — the subsystem under test is the
    batching + commit loop, not the LM weights.
    """

    def __init__(self, slots: int = 64, q_heads: int = 4, kv_heads: int = 2,
                 head_dim: int = 64, max_len: int = 256,
                 block_kv: int = 128, seed: int = 0,
                 interpret: Optional[bool] = None) -> None:
        import jax
        import jax.numpy as jnp
        from ..kernels.decode_attention import flash_decode
        self._jax, self._jnp = jax, jnp
        self._flash_decode = flash_decode
        self.slots = slots
        self.q_heads = q_heads
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        self.max_len = max_len
        self.block_kv = block_kv
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        self._k = jnp.zeros((slots, kv_heads, max_len, head_dim),
                            jnp.float32)
        self._v = jnp.zeros((slots, kv_heads, max_len, head_dim),
                            jnp.float32)
        self._lens = [0] * slots
        self._by_session = {}
        self._free = list(range(slots))
        self._rng = jax.random.key(seed)
        self._lock = threading.Lock()

    def _slot_of(self, session: str) -> int:
        with self._lock:
            i = self._by_session.get(session)
            if i is None:
                if not self._free:
                    # Recycle the least-recently registered slot: a serving
                    # pool evicts idle sessions; the commit layer, not the
                    # cache, is the session's ground truth.
                    i = min(self._by_session.values())
                    stale = next(s for s, j in self._by_session.items()
                                 if j == i)
                    del self._by_session[stale]
                else:
                    i = self._free.pop()
                self._by_session[session] = i
                self._lens[i] = 0
            return i

    def release(self, session: str) -> None:
        with self._lock:
            i = self._by_session.pop(session, None)
            if i is not None:
                self._free.append(i)
                self._lens[i] = 0

    def __call__(self, reqs: Sequence[StepRequest]) -> List[int]:
        jax, jnp = self._jax, self._jnp
        idx = [self._slot_of(r.session) for r in reqs]
        B = len(reqs)
        self._rng, sub = jax.random.split(self._rng)
        q = jax.random.normal(
            sub, (B, self.q_heads, 1, self.head_dim), jnp.float32)
        kv_new = jax.random.normal(
            sub, (2, B, self.kv_heads, 1, self.head_dim), jnp.float32)
        gather = jnp.asarray(idx, jnp.int32)
        # Append this step's K/V at each session's write position FIRST so
        # the query attends to its own token even on an empty cache.
        for b, i in enumerate(idx):
            pos = min(self._lens[i], self.max_len - 1)
            self._k = self._k.at[i, :, pos].set(kv_new[0, b, :, 0])
            self._v = self._v.at[i, :, pos].set(kv_new[1, b, :, 0])
            self._lens[i] = pos + 1
        k = jnp.take(self._k, gather, axis=0)
        v = jnp.take(self._v, gather, axis=0)
        kv_len = max(self._lens[i] for i in idx)
        out = self._flash_decode(q, k, v, jnp.int32(kv_len),
                                 block_kv=self.block_kv,
                                 interpret=self.interpret)
        # Reduce each session's attention output to a token id — a stand-in
        # for the LM head (deterministic given the seeded projections).
        scores = jnp.sum(jnp.abs(out), axis=(1, 2, 3))
        return [int(s * 1e4) % 50_000 for s in jax.device_get(scores)]


def make_decode(kind: str = "auto", **kwargs):
    """'stub' | 'pallas' | 'auto' (pallas when jax imports, else stub)."""
    if kind == "stub":
        return StubDecode(**kwargs)
    if kind in ("pallas", "auto"):
        try:
            return PallasDecode(**kwargs)
        except ImportError:
            if kind == "pallas":
                raise
            return StubDecode()
    raise ValueError(f"unknown decode backend {kind!r}")


class ContinuousBatcher:
    """Bounded ingress queue + one decode worker forming batches.

    ``submit`` returns True when the request was admitted (its ``done``
    event will fire with either a result or ``dropped=True``), False when
    it was load-shed by ``reject`` backpressure.  ``stop()`` drains
    nothing: queued requests are failed as dropped so no client blocks
    forever across shutdown.
    """

    def __init__(self, decode, cfg: AdmissionConfig) -> None:
        self.decode = decode
        self.cfg = cfg
        self._queue: List[StepRequest] = []
        self._cv = threading.Condition()
        self._stopped = False
        self._thread: Optional[threading.Thread] = None
        # Counters (same spirit as GroupCommitIngress's).
        self.submitted = 0
        self.rejected = 0
        self.dropped = 0
        self.batches = 0
        self.decoded = 0
        self.max_batch_seen = 0

    # -- client side --------------------------------------------------------
    def submit(self, req: StepRequest) -> bool:
        if self.cfg.deadline_ms is not None and req.deadline_at is None:
            req.deadline_at = req.submitted_at + self.cfg.deadline_ms / 1e3
        with self._cv:
            while (len(self._queue) >= self.cfg.queue_depth
                   and not self._stopped):
                if self.cfg.backpressure == "reject":
                    self.rejected += 1
                    return False
                self._cv.wait(timeout=0.05)
            if self._stopped:
                self.rejected += 1
                return False
            self._queue.append(req)
            self.submitted += 1
            self._cv.notify_all()
        return True

    # -- worker side --------------------------------------------------------
    def start(self) -> "ContinuousBatcher":
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        with self._cv:
            self._stopped = True
            leftovers = self._queue
            self._queue = []
            self._cv.notify_all()
        for req in leftovers:
            req.dropped = True
            req.done.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None

    def _take_batch(self) -> List[StepRequest]:
        """Block until a batch is formed: first arrival starts the window;
        the batch closes when the window elapses or ``max_batch`` queued."""
        with self._cv:
            while not self._queue and not self._stopped:
                self._cv.wait(timeout=0.05)
            if self._stopped and not self._queue:
                return []
            deadline = time.monotonic() + self.cfg.window_ms / 1e3
            while (len(self._queue) < self.cfg.max_batch
                   and not self._stopped):
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cv.wait(timeout=remaining)
            batch = self._queue[:self.cfg.max_batch]
            self._queue = self._queue[len(batch):]
            self._cv.notify_all()     # wake blocked submitters
            return batch

    def _loop(self) -> None:
        while True:
            batch = self._take_batch()
            if not batch:
                if self._stopped:
                    return
                continue
            now = time.monotonic()
            live: List[StepRequest] = []
            for req in batch:
                if req.deadline_at is not None and now >= req.deadline_at:
                    # Expired while queued: shed BEFORE spending decode
                    # compute on a result nobody will wait for.
                    req.dropped = True
                    self.dropped += 1
                    req.done.set()
                else:
                    live.append(req)
            if not live:
                continue
            self.batches += 1
            self.max_batch_seen = max(self.max_batch_seen, len(live))
            t0 = time.monotonic()
            try:
                results = self.decode(live)
            except Exception:
                # A decode failure fails the batch's requests, never the
                # serving loop (clients see a drop and may retry).
                for req in live:
                    req.dropped = True
                    self.dropped += 1
                    req.done.set()
                continue
            ms = (time.monotonic() - t0) * 1e3
            for req, tok in zip(live, results):
                req.result = tok
                req.batch_size = len(live)
                req.decode_ms = ms
                self.decoded += 1
                req.done.set()

    @property
    def mean_batch(self) -> float:
        return self.decoded / self.batches if self.batches else 0.0
