"""Int8 gradient compression with error feedback.

Beyond-paper distributed-optimization lever: quantize gradients to int8
(per-tensor scale) before the data-parallel all-reduce, carry the
quantization residual in an error-feedback buffer so the bias vanishes over
steps.  Cuts the DP collective term ~4× for fp32 / ~2× for bf16 grads.

Used through ``train.make_train_step(..., compress=CompressionConfig())``;
the quantize→psum→dequantize happens inside a shard_map over the batch axes
so the HLO all-reduce really moves int8 bytes (visible in the dry-run's
collective-bytes parse).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CompressionConfig:
    bits: int = 8
    stochastic: bool = False  # deterministic rounding keeps tests exact


def _qmax(bits: int) -> float:
    return float(2 ** (bits - 1) - 1)


def compress_gradients(grads, cfg: CompressionConfig, error_buf=None):
    """Quantize a grad pytree to int8 + per-tensor fp32 scales.

    Returns (q_tree, scales_tree, new_error_buf_residuals_source) — the
    residual is computed AFTER dequantization by ``error_feedback_update``.
    """
    if error_buf is not None:
        grads = jax.tree_util.tree_map(
            lambda g, e: g.astype(jnp.float32) + e.astype(jnp.float32),
            grads, error_buf)
    qmax = _qmax(cfg.bits)

    def q(g):
        g32 = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / qmax
        qv = jnp.clip(jnp.round(g32 / scale), -qmax, qmax).astype(jnp.int8)
        return qv, scale

    flat, tdef = jax.tree_util.tree_flatten(grads)
    qs = [q(g) for g in flat]
    q_tree = jax.tree_util.tree_unflatten(tdef, [a for a, _ in qs])
    s_tree = jax.tree_util.tree_unflatten(tdef, [b for _, b in qs])
    return q_tree, s_tree, grads


def decompress_gradients(q_tree, s_tree, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, s_tree)


def error_feedback_update(pre_quant_grads, dequantized):
    """Residual = what the quantizer lost this step (feeds the next one)."""
    return jax.tree_util.tree_map(
        lambda g, d: (g.astype(jnp.float32) - d.astype(jnp.float32)),
        pre_quant_grads, dequantized)
