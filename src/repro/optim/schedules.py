"""LR schedules: WSD (MiniCPM's Warmup-Stable-Decay) and cosine."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(step, *, warmup: int, stable: int, decay: int,
                 final_frac: float = 0.1):
    """MiniCPM WSD: linear warmup → constant → exponential-ish decay.

    Returns a multiplier in [0, 1] applied to the peak LR.
    """
    step = jnp.asarray(step, jnp.float32)
    w, s, d = float(warmup), float(stable), float(decay)
    warm = step / jnp.maximum(w, 1.0)
    in_decay = jnp.clip((step - w - s) / jnp.maximum(d, 1.0), 0.0, 1.0)
    decay_mult = final_frac ** in_decay          # exp decay to final_frac
    return jnp.where(step < w, warm, decay_mult)


def cosine_schedule(step, *, warmup: int, total: int, final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(float(warmup), 1.0)
    t = jnp.clip((step - warmup) / jnp.maximum(float(total - warmup), 1.0),
                 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, cos)
