from .adamw import AdamWConfig, adamw_init, adamw_update, global_norm
from .schedules import cosine_schedule, wsd_schedule
from .compress import (CompressionConfig, compress_gradients,
                       decompress_gradients, error_feedback_update)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "wsd_schedule", "cosine_schedule", "CompressionConfig",
           "compress_gradients", "decompress_gradients",
           "error_feedback_update"]
