"""AdamW, self-contained (no optax on this box).

State dtype is configurable: fp32 (default) or bf16 moments — the bf16
option halves optimizer bytes, which is what lets the 1T-param MoE cell fit
the 512-chip multi-pod mesh (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32   # jnp.bfloat16 halves optimizer memory


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr_scale: jax.Array | float = 1.0):
    """One AdamW step with global-norm clipping. Returns (params, state)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return (newp.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype))

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}
