"""Operation histories + the atomic-commit checker (machine-verified AC1–3).

A Jepsen-style verifier for the commit layer: every storage operation the
protocols issue (``log_once`` / ``log`` / ``log_batch`` / ``read_state``)
is recorded into an append-only :class:`HistoryRecorder` — call time,
return time, and the value the storage answered — and every per-node
conclusion lands in the shared ``TxnContext``.  After a run (chaotic or
not), :func:`check_run` validates the paper's correctness obligations over
that evidence instead of trusting the protocols' own bookkeeping:

  AC1  no two nodes decide differently (no mixed COMMIT/ABORT per txn) —
       Lemma 1's agreement clause, across live decisions AND post-crash
       ``recover()`` conclusions.
  AC2  COMMIT only if every participant voted yes (checked against the
       ``TxnSpec``'s intended votes).
  AC3  a decision, once made, never changes: each node's recovery
       conclusion matches its live one, and no log slot is ever observed
       holding both terminal values.
  W    writer-of consistency: a participant's VOTE-YES is only ever
       written by the participant itself (Alg. 1 — peers may CAS ABORT
       into a slot, never a yes-vote on another's behalf).
  R    recoverability: a committed txn's participants all have a durable
       VOTE-YES/COMMIT record in the final storage snapshot, so any
       future ``recover()`` re-derives COMMIT (Definition 1).  The abort
       direction is deliberately unchecked — presumed abort legally
       leaves all-yes logs behind for aborted coordinators.
  AC-GC truncation preserves recoverability: every slot the GC watermark
       removed was settled (its txn's terminal decision durable) when it
       was removed, the journaled decision matches what the nodes
       actually decided, and a committed txn's missing snapshot slot is
       only forgiven when the truncation journal holds its COMMIT.

Recording is observation-only (list appends + event subscriptions): with
``history is None`` — the default — every run is bit-identical to one
built without this module.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .state import Decision, TxnSpec, Vote

__all__ = ["HistoryOp", "HistoryRecorder", "Violation", "check_history",
           "check_run", "collect_decisions"]


@dataclass
class HistoryOp:
    """One storage operation as the caller saw it."""

    kind: str                       # log_once | log | log_batch | read
    partition: str
    txn: str
    state: Optional[Vote]           # argument (None for reads)
    writer: str
    t_call: float
    t_ret: Optional[float] = None   # None = never completed (chaos ate it)
    result: Optional[Vote] = None   # what storage answered


class HistoryRecorder:
    """Append-only log of storage ops; attached via ``storage.history``."""

    def __init__(self, sim):
        self.sim = sim
        self.ops: List[HistoryOp] = []

    def record(self, ev, kind: str, partition: str, txn: str,
               state: Optional[Vote] = None, writer: str = ""):
        """Record the call now and its completion when ``ev`` triggers;
        returns ``ev`` unchanged so call sites stay expressions."""
        op = HistoryOp(kind, partition, txn, state, writer, self.sim.now)
        self.ops.append(op)

        def done(e):
            op.t_ret = self.sim.now
            op.result = e.value

        ev.subscribe(done)
        return ev

    # -- derived views ------------------------------------------------------
    def slot_observations(self) -> Dict[Tuple[str, str], Set[Vote]]:
        """Terminal values ever observed (as op results) per log slot."""
        obs: Dict[Tuple[str, str], Set[Vote]] = {}
        for op in self.ops:
            if isinstance(op.result, Vote) and op.result.is_decision():
                obs.setdefault((op.partition, op.txn), set()).add(op.result)
        return obs


@dataclass
class Violation:
    rule: str          # AC1 | AC2 | AC3 | writer-of | recoverability
    txn: str
    detail: str

    def __str__(self) -> str:
        return f"[{self.rule}] txn={self.txn}: {self.detail}"


def _base_node(node: str) -> str:
    return node[:-len(":recovery")] if node.endswith(":recovery") else node


def collect_decisions(ctx) -> Dict[str, Dict[str, Decision]]:
    """txn -> {node -> terminal Decision}, merging live per-node decisions
    (``ctx.local``) with recorded outcomes — including the ``:recovery``
    conclusions a crash–restart produced.  UNDETERMINED (gave up /
    blocked) is not a decision and is excluded."""
    out: Dict[str, Dict[str, Decision]] = {}
    for (node, txn), st in ctx.local.items():
        d = st.get("decision")
        if d in (Decision.COMMIT, Decision.ABORT):
            out.setdefault(txn, {})[node] = d
    for (txn, node), outcome in ctx.outcomes.items():
        if outcome.decision in (Decision.COMMIT, Decision.ABORT):
            out.setdefault(txn, {}).setdefault(node, outcome.decision)
    return out


def check_history(history: Optional[HistoryRecorder], ctx,
                  specs: Optional[Dict[str, TxnSpec]] = None,
                  snapshot: Optional[Dict[Tuple[str, str], Vote]] = None,
                  participant_logs: bool = True,
                  gc_log: Optional[Sequence] = None,
                  ) -> List[Violation]:
    """Validate AC1–AC3 + writer-of + recoverability (+ AC-GC when a
    truncation journal is supplied); returns violations (empty = the run
    is certified).

    Every rule is deliberately one-sided so chaos cannot manufacture false
    positives: stale reads are legal (only *conflicting terminal* slot
    values violate AC3), presumed abort is legal (recoverability only
    constrains COMMIT), and txns with no registered spec (e.g. the
    single-partition fast path) are skipped where the spec is needed.
    """
    specs = specs if specs is not None else getattr(ctx, "specs", {})
    violations: List[Violation] = []
    decisions = collect_decisions(ctx)
    gc_index: Dict[Tuple[str, str], object] = {}
    if gc_log:
        for e in gc_log:
            gc_index[(e.partition, e.txn)] = e
        # AC-GC — every truncation was justified and journaled truthfully.
        for e in gc_log:
            if not e.settled or e.decision is None:
                violations.append(Violation(
                    "AC-GC", e.txn,
                    f"slot {e.partition} truncated while unsettled "
                    f"(journal decision={e.decision})"))
                continue
            by_node = decisions.get(e.txn)
            if by_node:
                reached = {d.value for d in by_node.values()}
                if e.decision not in reached:
                    violations.append(Violation(
                        "AC-GC", e.txn,
                        f"journal says {e.decision} but nodes decided "
                        f"{sorted(reached)}"))

    for txn, by_node in sorted(decisions.items()):
        spec = specs.get(txn)
        if spec is not None:
            # A read-only participant's conclusion is trivially COMMIT the
            # moment its reads finish (§3.6 — it has nothing at stake and
            # never votes), so it carries no information about the global
            # decision; only the coordinator's and the writers' count.
            by_node = {n: d for n, d in by_node.items()
                       if _base_node(n) == spec.coordinator
                       or _base_node(n) not in spec.read_only}
        kinds = set(by_node.values())
        # AC1 — agreement across every node's conclusion.
        if len(kinds) > 1:
            violations.append(Violation(
                "AC1", txn,
                f"mixed decisions {sorted((n, d.value) for n, d in by_node.items())}"))
        # AC3 — each node's recovery conclusion matches its live one.
        per_base: Dict[str, Set[Decision]] = {}
        for node, d in by_node.items():
            per_base.setdefault(_base_node(node), set()).add(d)
        for base, ds in sorted(per_base.items()):
            if len(ds) > 1:
                violations.append(Violation(
                    "AC3", txn,
                    f"node {base} changed its decision: {sorted(d.value for d in ds)}"))
        if spec is None:
            continue
        if Decision.COMMIT in kinds:
            # AC2 — commit requires unanimous yes-votes.
            naysayers = [p for p in spec.participants
                         if not spec.vote_of(p)]
            if naysayers:
                violations.append(Violation(
                    "AC2", txn, f"committed over no-votes from {naysayers}"))
            # R — committed txns are durably recoverable.  With
            # ``participant_logs=False`` (CL) the participants' slots are
            # empty BY DESIGN; all durable state is the coordinator's
            # batched record, which recovery consults instead.
            if snapshot is not None and participant_logs:
                for p in spec.participants:
                    if p in spec.read_only:
                        continue
                    v = snapshot.get((p, txn))
                    if v in (Vote.VOTE_YES, Vote.COMMIT):
                        continue
                    # A truncated slot is recoverable through the GC
                    # journal's tombstone — but ONLY if it holds COMMIT.
                    e = gc_index.get((p, txn))
                    if v is None and e is not None \
                            and e.decision == Vote.COMMIT.value:
                        continue
                    violations.append(Violation(
                        "recoverability", txn,
                        f"committed but {p}'s durable slot is {v}"))
            elif snapshot is not None:
                v = snapshot.get((spec.coordinator, txn))
                if v != Vote.COMMIT:
                    e = gc_index.get((spec.coordinator, txn))
                    if not (v is None and e is not None
                            and e.decision == Vote.COMMIT.value):
                        violations.append(Violation(
                            "recoverability", txn,
                            f"committed but coordinator {spec.coordinator}'s "
                            f"durable record is {v}"))

    if history is not None:
        # AC3 — no slot ever serves both terminal values.
        for (partition, txn), obs in sorted(
                history.slot_observations().items()):
            if Vote.COMMIT in obs and Vote.ABORT in obs:
                violations.append(Violation(
                    "AC3", txn,
                    f"slot {partition} observed both COMMIT and ABORT"))
        # W — yes-votes are only ever self-written.
        for op in history.ops:
            if (op.kind == "log_once" and op.state == Vote.VOTE_YES
                    and op.writer and op.writer != op.partition):
                violations.append(Violation(
                    "writer-of", op.txn,
                    f"{op.writer} wrote VOTE-YES into {op.partition}'s slot"))
    return violations


def check_run(ctx, storage=None,
              history: Optional[HistoryRecorder] = None,
              participant_logs: bool = True) -> List[Violation]:
    """Post-run convenience: pull the history off the storage, take its
    final durable snapshot (ground truth), and check everything."""
    if history is None and storage is not None:
        history = getattr(storage, "history", None)
    snapshot = None
    if storage is not None and hasattr(storage, "snapshot"):
        snapshot = storage.snapshot()
    return check_history(history, ctx, snapshot=snapshot,
                         participant_logs=participant_logs,
                         gc_log=getattr(storage, "gc_log", None))
