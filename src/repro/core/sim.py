"""Deterministic discrete-event simulation kernel.

A minimal simpy-style engine: processes are Python generators that yield
``Event`` objects and are resumed when the event triggers.  Everything is
driven off a single heap, so runs are bit-reproducible given a seed — which
is what lets the paper's latency figures and the hypothesis failure-schedule
property tests be deterministic on CPU.

Only the features the protocol needs are implemented:
  * ``sim.timeout(dt, value)``        – fires after dt
  * ``sim.event()``                   – manually triggered
  * ``sim.process(gen)``              – spawn; returns its done-Event
  * ``sim.timer(dt, fn)``             – cancellable callback (batch windows)
  * ``AnyOf`` / ``AllOf``             – composite waits (for vote collection
                                        with timeouts)
"""
from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, Generator, Iterable, List, Optional


class Event:
    __slots__ = ("sim", "triggered", "value", "callbacks")

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self.triggered = False
        self.value: Any = None
        self.callbacks: List[Callable[["Event"], None]] = []

    def trigger(self, value: Any = None) -> "Event":
        if self.triggered:  # idempotent: late triggers are ignored
            return self
        self.triggered = True
        self.value = value
        # Defer callbacks through the queue so ordering is heap-deterministic.
        self.sim._schedule(self.sim.now, self._run_callbacks)
        return self

    def _run_callbacks(self) -> None:
        cbs, self.callbacks = self.callbacks, []
        for cb in cbs:
            cb(self)

    def subscribe(self, cb: Callable[["Event"], None]) -> None:
        if self.triggered:
            self.sim._schedule(self.sim.now, lambda: cb(self))
        else:
            self.callbacks.append(cb)

    def unsubscribe(self, cb: Callable[["Event"], None]) -> None:
        """Detach a callback registered with ``subscribe`` (no-op if it
        already ran or was never attached)."""
        try:
            self.callbacks.remove(cb)
        except ValueError:
            pass


class AnyOf(Event):
    """Triggers with (index, value) of the first sub-event to fire.

    The composite detaches itself from every sub-event the moment the
    first one fires: long-lived losers (e.g. a transport message slot
    that outlives thousands of timed-out waits) would otherwise keep the
    callback — and through it the whole composite — alive forever.
    """

    def __init__(self, sim: "Sim", events: Iterable[Event]):
        super().__init__(sim)
        self._subs: List = []
        for i, ev in enumerate(events):
            cb = (lambda e, i=i: self._first(i, e))
            self._subs.append((ev, cb))
            ev.subscribe(cb)

    def _first(self, i: int, ev: Event) -> None:
        if self.triggered:
            return
        self.trigger((i, ev.value))
        for sub, cb in self._subs:
            sub.unsubscribe(cb)
        self._subs = []


class AllOf(Event):
    """Triggers with the list of all sub-event values once all fired."""

    def __init__(self, sim: "Sim", events: Iterable[Event]):
        super().__init__(sim)
        self._events = list(events)
        self._pending = len(self._events)
        if self._pending == 0:
            self.trigger([])
        for ev in self._events:
            ev.subscribe(self._one_done)

    def _one_done(self, _ev: Event) -> None:
        self._pending -= 1
        if self._pending == 0 and not self.triggered:
            self.trigger([e.value for e in self._events])


class Timer:
    """Cancellable scheduled callback — the batch-window primitive.

    Unlike ``timeout`` (an Event processes yield on), a Timer is owned by
    infrastructure code that may need to disarm it before it fires: a
    group-commit lane cancels its window timer when the batch fills up or
    the lane flushes for another reason.
    """

    __slots__ = ("_fn", "cancelled")

    def __init__(self, sim: "Sim", delay: float, fn: Callable[[], None]):
        self._fn = fn
        self.cancelled = False
        sim._schedule(sim.now + max(0.0, delay), self._fire)

    def _fire(self) -> None:
        if not self.cancelled:
            self._fn()

    def cancel(self) -> None:
        self.cancelled = True


class Process(Event):
    """Drives a generator; the Process *is* its completion event."""

    def __init__(self, sim: "Sim", gen: Generator):
        super().__init__(sim)
        self._gen = gen
        sim._schedule(sim.now, lambda: self._step(None))

    def _step(self, send_value: Any) -> None:
        try:
            target = self._gen.send(send_value)
        except StopIteration as stop:
            self.trigger(stop.value)
            return
        if not isinstance(target, Event):
            raise TypeError(f"process yielded non-Event: {target!r}")
        target.subscribe(lambda ev: self._step(ev.value))


class Sim:
    def __init__(self) -> None:
        self.now: float = 0.0
        self._heap: list = []
        self._seq = itertools.count()

    # -- scheduling ---------------------------------------------------------
    def _schedule(self, at: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (at, next(self._seq), fn))

    def run(self, until: float = float("inf")) -> None:
        while self._heap and self._heap[0][0] <= until:
            at, _, fn = heapq.heappop(self._heap)
            self.now = max(self.now, at)
            fn()
        if until != float("inf"):
            self.now = max(self.now, until)

    # -- primitives ---------------------------------------------------------
    def event(self) -> Event:
        return Event(self)

    def timeout(self, dt: float, value: Any = None) -> Event:
        ev = Event(self)
        self._schedule(self.now + max(0.0, dt), lambda: ev.trigger(value))
        return ev

    def timer(self, dt: float, fn: Callable[[], None]) -> Timer:
        return Timer(self, dt, fn)

    def process(self, gen: Generator) -> Process:
        return Process(self, gen)

    def any_of(self, events: Iterable[Event]) -> AnyOf:
        return AnyOf(self, events)

    def all_of(self, events: Iterable[Event]) -> AllOf:
        return AllOf(self, events)
