"""Backend-agnostic storage control plane.

The termination-storm controls of PR 5 (decision cache, singleflight,
decision push), the adaptive timeout policy, and leadership-lease upkeep
used to live as parallel copies: one eager implementation inside the
simulated services (``SimStorage`` / ``ReplicatedSimStorage``), a missing
one in the threaded stores real deployments would use.  This module is the
single control-plane core BOTH backends consume:

  * ``DecisionCacheConfig`` / ``DecisionIndex`` — per-service index of
    terminal txn records, singleflight table, and decision watchers.  The
    sim services drive it with sim Events; ``ThreadControlPlane`` drives
    the same index with real threads.
  * ``EwmaStat`` / ``AdaptiveTimeouts`` — write-latency EWMA+dev tracking
    (now per *lane*, i.e. per partition, so a single hot partition's
    queueing signal is not diluted by idle ones) and the raise-only
    timeout policy that reads it.
  * ``ThreadControlPlane`` — the blocking-store twin of the sim's
    ``_DecisionCacheMixin``: wraps a store's ``log_once`` with cache
    lookup + singleflight + watcher push, and observes per-lane write
    latency for the adaptive policy.
  * ``LeaseKeeper`` — automatic acquisition/renewal of a store leadership
    lease for long-lived committers (the checkpoint loop); renewal failure
    degrades to the full-prepare slow path instead of erroring.

Nothing here schedules sim events or consumes a shared rng: attaching any
of these to a run in which they never fire cannot perturb it.
"""
from __future__ import annotations

import logging
import random
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from .state import Vote

log = logging.getLogger(__name__)


class QuorumUnavailable(RuntimeError):
    """Fewer than a majority of replicas reachable (or proposer starved)."""


# --------------------------------------------------------------------------
# Decision cache / singleflight / push (termination-storm controls)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class DecisionCacheConfig:
    """Knobs for the storage-side decision cache (termination storms).

    The paper's LogOnce semantics — "returns the existing value" — mean
    that once a transaction's log set holds a terminal record, every later
    LogOnce arrival should *read* the decision, not re-run agreement
    (Gray & Lamport frame the same point for Paxos Commit).  Under a
    saturated serial log lane, timed-out participants racing full
    termination rounds against the queue is exactly the storm that
    inverts the cornus-vs-2PC ordering; these knobs kill it at the
    storage service:

      cache        – once ANY slot of a txn holds a terminal record
                     (COMMIT/ABORT), answer later ``log_once`` calls for
                     that txn from the index: ONE cheap read, no CAS / no
                     Paxos round, no serial-lane occupancy.
      singleflight – concurrent in-flight ``log_once`` rounds for one
                     identical (partition, txn, state) coalesce into ONE
                     round whose result every caller shares (a joiner's
                     CAS could never have mutated the slot anyway).
      push         – proactively deliver a txn's first terminal value to
                     registered watchers (still-waiting participants), so
                     most of them never time out at all.

    The DEFAULT config is inactive: behaviour (and the rng stream) is
    bit-identical to the pre-cache service.  With knobs on, per-node
    decisions keep AC1–AC3 — only round trips disappear.
    """

    cache: bool = False
    singleflight: bool = False
    push: bool = False

    @property
    def active(self) -> bool:
        return self.cache or self.singleflight or self.push


STORM_CONTROL = DecisionCacheConfig(cache=True, singleflight=True, push=True)


class DecisionIndex:
    """Per-service index of terminal txn records + singleflight table +
    decision watchers.  Owned by ``SimStorage`` / ``ReplicatedSimStorage``
    (driven with sim Events) and by ``ThreadControlPlane`` (driven with
    real threads, under its lock)."""

    def __init__(self, cfg: DecisionCacheConfig) -> None:
        self.cfg = cfg
        self.txn_decision: Dict[str, Vote] = {}
        self._watchers: Dict[str, List[Callable[[Vote], None]]] = {}
        self.inflight: Dict[Tuple[str, str, str], object] = {}
        self.hits = 0                  # log_once answered from the index
        self.singleflight_hits = 0     # log_once joined an in-flight round
        self.pushes = 0                # watcher deliveries

    def note(self, partition: str, txn: str,
             value: Optional[Vote]) -> None:
        """Record a terminal value applied/observed for ``txn``; the FIRST
        terminal record fires any registered watchers."""
        if value is None or not value.is_decision():
            return
        if txn in self.txn_decision:
            return
        self.txn_decision[txn] = value
        for cb in self._watchers.pop(txn, ()):
            self.pushes += 1
            cb(value)

    def lookup(self, txn: str) -> Optional[Vote]:
        if not self.cfg.cache:
            return None
        return self.txn_decision.get(txn)

    def watch(self, txn: str, cb: Callable[[Vote], None]) -> None:
        if not self.cfg.push:
            return
        v = self.txn_decision.get(txn)
        if v is not None:
            self.pushes += 1
            cb(v)
        else:
            self._watchers.setdefault(txn, []).append(cb)

    def join(self, key: Tuple[str, str, str]):
        """The in-flight identical round's completion event, if any."""
        if not self.cfg.singleflight:
            return None
        return self.inflight.get(key)

    def lead(self, key: Tuple[str, str, str], ev) -> None:
        if not self.cfg.singleflight:
            return
        self.inflight[key] = ev
        ev.subscribe(lambda _e, key=key: self.inflight.pop(key, None))


# --------------------------------------------------------------------------
# Write-latency observation (per-lane EWMAs) + adaptive timeouts
# --------------------------------------------------------------------------
class EwmaStat:
    """One EWMA + mean-absolute-deviation tracker (the update law the
    global ``write_lat_ewma``/``write_lat_dev`` fields have always used:
    dev updates against the PRE-update mean, alpha 0.25)."""

    __slots__ = ("ewma", "dev")

    def __init__(self) -> None:
        self.ewma: Optional[float] = None
        self.dev = 0.0

    def note(self, ms: float) -> None:
        if self.ewma is None:
            self.ewma = ms
            self.dev = ms / 4.0
        else:
            self.dev = 0.75 * self.dev + 0.25 * abs(ms - self.ewma)
            self.ewma = 0.75 * self.ewma + 0.25 * ms


class AdaptiveTimeouts:
    """EWMA-driven protocol timeouts with desynchronizing jitter.

    The static timeout formula in ``run_bench`` is tuned to the no-load
    service tail; behind a saturated serial log lane the *observed* write
    latency (queueing included) exceeds it by orders of magnitude, and a
    timeout below the real tail self-amplifies: every spuriously timed-out
    participant races a termination round against the same queue — the
    storm that inverts the cornus-vs-2PC ordering.  The policy

      * floors every timeout at the static base, so a run whose static
        timeouts never fire behaves identically (raise-only);
      * raises it to ``k_mean·EWMA + k_dev·dev`` of the storage service's
        observed write latency, clamped to ``cap_factor``× the base;
      * multiplies by a deterministic raise-only jitter from its OWN rng,
        so closed-loop workers that do time out don't re-fire in lockstep.

    With ``per_lane=True`` a call that names a lane (the partition whose
    write the caller is waiting on) reads that LANE's EWMA+dev instead of
    the service-global one: one hot partition's queueing signal raises its
    own deadlines undiluted, while cold lanes keep the static floor.  The
    default (``per_lane=False``) ignores the lane argument entirely, so
    existing runs are bit-identical.

    The policy only reads storage counters — it consumes no shared rng and
    schedules no events, so attaching it cannot perturb a run in which no
    timeout fires.
    """

    def __init__(self, storage, seed: int = 0, k_mean: float = 4.0,
                 k_dev: float = 8.0, cap_factor: float = 64.0,
                 jitter: float = 0.25, per_lane: bool = False) -> None:
        self.storage = storage
        self.k_mean = k_mean
        self.k_dev = k_dev
        self.cap_factor = cap_factor
        self.jitter = jitter
        self.per_lane = per_lane
        self._rng = random.Random(seed ^ 0x7E0117)

    def _observed(self, lane: Optional[str]) -> Tuple[Optional[float], float]:
        if self.per_lane and lane is not None:
            lane_fn = getattr(self.storage, "lane_write_latency", None)
            got = lane_fn(lane) if lane_fn is not None else None
            if got is not None:
                return got
            # Lane never observed: keep the static floor rather than
            # inheriting another lane's congestion through the global EWMA.
            return None, 0.0
        return (getattr(self.storage, "write_lat_ewma", None),
                getattr(self.storage, "write_lat_dev", 0.0))

    def timeout_ms(self, kind: str, base_ms: float,
                   lane: Optional[str] = None) -> float:
        ewma, dev = self._observed(lane)
        t = base_ms
        if ewma is not None:
            t = max(base_ms, min(self.cap_factor * base_ms,
                                 self.k_mean * ewma + self.k_dev * dev))
        if self.jitter:
            t *= 1.0 + self.jitter * self._rng.random()
        return t


# --------------------------------------------------------------------------
# Threaded control plane (decision cache for blocking stores)
# --------------------------------------------------------------------------
class _Flight:
    """One in-flight threaded ``log_once`` round being shared."""

    __slots__ = ("event", "result", "error")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.result: Optional[Vote] = None
        self.error: Optional[BaseException] = None


class ThreadControlPlane:
    """The blocking-store twin of the sim's decision-cache mixin.

    Owns ONE ``DecisionIndex`` (the same class the sim services use) and
    serializes access to it with a re-entrant lock; the wrapped store calls
    ``log_once(perform, ...)`` where ``perform()`` executes the real
    operation.  Semantics mirror the sim exactly:

      * cache hit   – the txn already holds a terminal record: return it
                      without running ``perform`` (no CAS, no quorum round).
      * singleflight – an identical (partition, txn, state) call is already
                      executing in another thread: block on its completion
                      and share its result (or its exception — a joiner of
                      a round that raised ``QuorumUnavailable`` must not
                      pretend it succeeded).
      * note/push   – terminal results feed the index; the first terminal
                      record fires registered ``watch_decision`` watchers
                      from the noting thread (there is no network leg to
                      charge in threaded deployments).

    Also observes per-lane (partition) write latency for the adaptive
    timeout policy — the same ``write_lat_ewma`` / ``write_lat_dev`` /
    ``lane_write_latency`` surface the sim services expose.
    """

    def __init__(self, cfg: Optional[DecisionCacheConfig] = None) -> None:
        self.cfg = cfg or DecisionCacheConfig()
        self.index = DecisionIndex(self.cfg)
        self._lock = threading.RLock()
        self._inflight: Dict[Tuple[str, str, str], _Flight] = {}
        self._lat = EwmaStat()
        self._lane_lat: Dict[str, EwmaStat] = {}

    # -- counters (mirror the sim mixin's surface) -------------------------
    @property
    def decision_cache_hits(self) -> int:
        return self.index.hits

    @property
    def singleflight_hits(self) -> int:
        return self.index.singleflight_hits

    @property
    def decisions_pushed(self) -> int:
        return self.index.pushes

    # -- write-latency observation -----------------------------------------
    @property
    def write_lat_ewma(self) -> Optional[float]:
        return self._lat.ewma

    @property
    def write_lat_dev(self) -> float:
        return self._lat.dev

    def note_write_latency(self, ms: float,
                           lane: Optional[str] = None) -> None:
        with self._lock:
            self._lat.note(ms)
            if lane is not None:
                st = self._lane_lat.get(lane)
                if st is None:
                    st = self._lane_lat[lane] = EwmaStat()
                st.note(ms)

    def lane_write_latency(self, lane: str
                           ) -> Optional[Tuple[float, float]]:
        st = self._lane_lat.get(lane)
        if st is None or st.ewma is None:
            return None
        return st.ewma, st.dev

    # -- watcher API (decision push) ---------------------------------------
    def watch_decision(self, txn: str, cb: Callable[[Vote], None],
                       node: Optional[str] = None) -> None:
        """Run ``cb(value)`` when the txn's first terminal record lands
        (immediately if it already has).  ``node`` is accepted for API
        parity with the sim services; threaded deployments have no
        modelled push leg to charge."""
        with self._lock:
            self.index.watch(txn, cb)

    def note(self, partition: str, txn: str,
             value: Optional[Vote]) -> None:
        """Feed a terminal value observed outside ``log_once`` (a plain
        ``log`` of a decision record, a read) into the index."""
        with self._lock:
            self.index.note(partition, txn, value)

    # -- the wrapped operation ---------------------------------------------
    def log_once(self, perform: Callable[[], Vote], partition: str,
                 txn: str, state: Vote, writer: str = "") -> Vote:
        key = (partition, txn, state.value)
        lead = False
        with self._lock:
            hit = self.index.lookup(txn)
            if hit is not None:
                # LogOnce "returns the existing value": the txn's log set
                # already holds a terminal record, so this attempt can only
                # read the decision — answer it without a CAS round.
                self.index.hits += 1
                return hit
            flight = self._inflight.get(key) if self.cfg.singleflight \
                else None
            if flight is not None:
                self.index.singleflight_hits += 1
            else:
                flight = _Flight()
                lead = True
                if self.cfg.singleflight:
                    self._inflight[key] = flight
        if not lead:
            # Joiner: share the leader's round (result OR exception).
            flight.event.wait()
            if flight.error is not None:
                raise flight.error
            return flight.result
        t0 = time.monotonic()
        try:
            result = flight.result = perform()
        except BaseException as e:
            flight.error = e
            raise
        finally:
            self.note_write_latency((time.monotonic() - t0) * 1e3,
                                    lane=partition)
            with self._lock:
                if self._inflight.get(key) is flight:
                    del self._inflight[key]
            flight.event.set()
        self.note(partition, txn, result)
        return result


# --------------------------------------------------------------------------
# Leadership-lease upkeep for long-lived committers
# --------------------------------------------------------------------------
class LeaseKeeper:
    """Automatic acquisition/renewal of a store leadership lease.

    Long-lived committers (the checkpoint loop, wall-clock bench workers)
    used to manage ``acquire_lease`` by hand — or not at all, paying the
    full prepare+accept on every post-failover LogOnce.  A ``LeaseKeeper``
    wraps the policy once:

      * ``ensure()`` returns a lease valid for at least
        ``renew_margin × duration_s`` more seconds, acquiring or renewing
        (an epoch bump) as needed — and returns ``None`` when the store has
        no lease API, another holder's lease is still valid (stealing a
        live peer's epoch would thrash), or acquisition fails because a
        quorum is unreachable.  ``None`` means: use the full-prepare slow
        path; it NEVER raises out of a renewal attempt.
      * safety is the store's (ballot order on the replicas); the keeper
        only decides when to spend an acquisition round.
      * degradation is NOT silent: every ``ensure()`` that answers "slow
        path" on a lease-capable store bumps ``degradations``, and the
        fast↔slow transitions emit one log line each — so a bench (or an
        operator) can assert the fast path actually re-engaged after a
        failover or membership reconfiguration instead of quietly paying
        full prepare+accept forever.
    """

    def __init__(self, store, holder: str, duration_s: float = 5.0,
                 renew_margin: float = 0.25) -> None:
        self.store = store
        self.holder = holder
        self.duration_s = duration_s
        self.renew_margin = renew_margin
        self.supported = hasattr(store, "acquire_lease") \
            and hasattr(store, "current_lease")
        self.acquisitions = 0
        self.renewals = 0
        self.failures = 0
        self.degradations = 0          # ensure() calls answered "slow path"
        self.reengagements = 0         # slow→fast transitions
        self._degraded = False

    def _slow(self, why: str):
        """Record (and, on the transition, log) a slow-path answer."""
        self.degradations += 1
        if not self._degraded:
            self._degraded = True
            log.warning("LeaseKeeper[%s]: degraded to full-prepare "
                        "slow path (%s)", self.holder, why)
        return None

    def _fast(self, lease):
        if self._degraded:
            self._degraded = False
            self.reengagements += 1
            log.info("LeaseKeeper[%s]: lease fast path re-engaged "
                     "(epoch %d)", self.holder, lease.epoch)
        return lease

    @property
    def degraded(self) -> bool:
        """True while the last ``ensure()`` answered "slow path"."""
        return self._degraded

    def ensure(self):
        """-> valid ``StoreLease`` held by ``holder``, or None (slow path)."""
        if not self.supported:
            return None
        lease = self.store.current_lease()
        now = time.monotonic()
        if lease is not None:
            if lease.holder == self.holder:
                if lease.expires_at - now > self.renew_margin * \
                        self.duration_s:
                    return self._fast(lease)
            else:
                # A live peer holds the lease: dueling epoch bumps would
                # invalidate each other's fast path every round.  Let the
                # holder serve; we take the (safe) full-prepare path.
                return self._slow(f"peer {lease.holder!r} holds the lease")
        try:
            lease = self.store.acquire_lease(self.holder,
                                             duration_s=self.duration_s)
        except QuorumUnavailable as e:
            # Degrade, don't error: the committer falls back to the full
            # proposer, which is correct (just slower) lease or no lease.
            self.failures += 1
            return self._slow(f"acquisition failed: {e}")
        if self.acquisitions:
            self.renewals += 1
        self.acquisitions += 1
        return self._fast(lease)
