"""Commit-protocol registry: protocol names → strategy classes.

The transaction layer never branches on protocol names; it resolves the
configured name here and hands the class the shared Transport / TxnContext /
storage wiring.  Adding a Table-3 row is therefore:

    @register("my-variant")
    class MyVariant(CornusProtocol):
        ...override the relevant role hooks...

and ``BenchConfig(protocol="my-variant")`` works everywhere.
"""
from __future__ import annotations

from typing import Callable, Dict, List

_REGISTRY: Dict[str, type] = {}


def register(name: str) -> Callable[[type], type]:
    """Class decorator registering a CommitProtocol under ``name``."""

    def deco(cls: type) -> type:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_protocol(name: str) -> type:
    """Resolve a protocol name to its strategy class (KeyError if unknown)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown commit protocol {name!r}; registered: "
            f"{registered_protocols()}") from None


def registered_protocols() -> List[str]:
    return sorted(_REGISTRY)
