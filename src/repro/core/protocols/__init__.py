"""Pluggable commit-protocol API.

Layers:
  transport  – Transport (messaging / liveness / slots) + ProtocolConfig
  context    – TxnContext (per-txn bookkeeping, outcomes, executor hooks)
  base       – CommitProtocol strategy interface (roles + hooks)
  registry   – register("name") / get_protocol(name)

Protocol strategies (one per Table-3 family member):
  cornus, 2pc, cl, cornus-opt1, paxos-commit
"""
from .transport import ProtocolConfig, Transport
from .context import TxnContext
from .base import CommitProtocol
from .registry import get_protocol, register, registered_protocols

# Importing the implementations populates the registry.
from .cornus import CornusProtocol
from .twopc import TwoPCProtocol
from .coordinator_log import CoordinatorLogProtocol
from .cornus_opt1 import CornusOpt1Protocol
from .paxos_commit import PaxosCommitProtocol

__all__ = [
    "ProtocolConfig", "Transport", "TxnContext", "CommitProtocol",
    "get_protocol", "register", "registered_protocols",
    "CornusProtocol", "TwoPCProtocol", "CoordinatorLogProtocol",
    "CornusOpt1Protocol", "PaxosCommitProtocol",
]
