"""Per-transaction bookkeeping shared across protocol roles.

One ``TxnContext`` per cluster: every node's local view of every transaction
(status / decision), recorded ``TxnOutcome``s, the blocked-marker map used by
2PC's cooperative termination, and the executor hooks (lock release on
finish, ELR on precommit).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from ..sim import Sim
from ..state import Decision, TxnOutcome


class TxnContext:
    def __init__(self, sim: Sim):
        self.sim = sim
        # (node, txn) -> {"status": none|voted|decided, "decision": Decision}
        self.local: Dict[Tuple[str, str], Dict] = {}
        self.outcomes: Dict[Tuple[str, str], TxnOutcome] = {}
        self.blocked: Dict[Tuple[str, str], bool] = {}
        # Termination accounting: runs started, runs absorbed by the
        # per-(node, txn) singleflight, and the in-flight table itself.
        self.terminations = 0
        self.dedup_hits = 0
        self.term_inflight: Dict[Tuple[str, str], object] = {}
        # Every spec the cluster ever ran, by txn id — what a restarting
        # node scans to find its in-doubt transactions (Table 1/2 recovery
        # needs the participant list, which in a real system would be read
        # from the coordinator's durable log).
        self.specs: Dict[str, "object"] = {}
        # Hooks for the transaction executor (lock release timing, ELR).
        self.on_precommit: Optional[Callable[[str, str, float], None]] = None
        self.on_finish: Optional[
            Callable[[str, str, Decision, float], None]] = None

    def local_state(self, node: str, txn: str) -> Dict:
        return self.local.setdefault((node, txn), {"status": "none",
                                                   "decision": None})

    def decide(self, node: str, txn: str, decision: Decision) -> None:
        """First decision wins (Lemma 1: decisions are irreversible)."""
        st = self.local_state(node, txn)
        if st["decision"] is None:
            st["status"], st["decision"] = "decided", decision
            if self.on_finish:
                self.on_finish(node, txn, decision, self.sim.now)

    def record(self, out: TxnOutcome) -> None:
        self.outcomes[(out.txn_id, out.node)] = out

    def precommit(self, node: str, txn: str) -> None:
        if self.on_precommit:
            self.on_precommit(node, txn, self.sim.now)
