"""Compute-layer transport: messaging, liveness, and per-txn message slots.

Extracted from the old ``Cluster`` god-class so protocol strategies share one
substrate: asynchronous one-way messages with geo-aware delays, per-node
fail/recover schedules, and (dst, txn, kind)-keyed rendezvous slots that a
storage service can also deliver into directly (vote forwarding, Table 3's
``cornus-opt1`` / ``paxos-commit`` rows).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..sim import Event, Sim
from ..storage import COMPUTE_RTT_MS, RegionTopology


@dataclass
class ProtocolConfig:
    protocol: str = "cornus"            # any name in protocols.registry
    rtt_ms: float = COMPUTE_RTT_MS      # compute <-> compute round trip
    vote_timeout_ms: float = 25.0       # coordinator waiting for votes
    decision_timeout_ms: float = 25.0   # participant waiting for decision
    votereq_timeout_ms: float = 25.0    # participant waiting for VOTE-REQ
    termination_retry_ms: float = 25.0  # retry period for termination protocol
    # 2PC cooperative termination polls peers with this period while blocked.
    coop_retry_ms: float = 25.0
    # Early Lock Release / speculative precommit (§5.6): locks drop at
    # precommit instead of at decision. Consumed by the txn executor via the
    # on_precommit hook.
    elr: bool = False
    # Geo-distributed deployments (extended §6): per-link RTTs come from a
    # RegionTopology + node→region placement instead of the scalar rtt_ms.
    topology: Optional[RegionTopology] = None
    placement: Dict[str, str] = field(default_factory=dict)
    # --- termination-storm controls (compute side) -------------------------
    # Participants register storage decision watchers before their decision
    # wait, so a decided txn reaches them without waiting out a timeout.
    push_decisions: bool = False
    # Per-(node, txn) singleflight on the termination protocol: concurrent
    # entries (participant timeout + recovery + coordinator vote-timeout)
    # share ONE run's decision instead of racing redundant CAS rounds.
    termination_dedup: bool = False
    # Adaptive timeout policy (duck-typed: ``timeout_ms(kind, base) ->
    # float``).  None keeps the static per-kind fields above EXACTLY; a
    # policy may only observe (it must not consume shared rng or schedule
    # events), so runs whose static timeouts never fire are unchanged.
    timeout_policy: Optional[object] = None

    _TIMEOUT_FIELDS = {
        "vote": "vote_timeout_ms",
        "decision": "decision_timeout_ms",
        "votereq": "votereq_timeout_ms",
        "termination_retry": "termination_retry_ms",
        "coop_retry": "coop_retry_ms",
    }

    def timeout(self, kind: str, lane: Optional[str] = None) -> float:
        """Effective timeout for ``kind`` — the static field, or the
        attached policy's (EWMA-raised, jittered) value, evaluated NOW.
        Use for sleep-like delays (retry periods).

        ``lane`` names the storage lane (partition) whose write the caller
        is waiting on; a per-lane policy reads that lane's EWMA instead of
        the service-global one.  Passed as a third positional only when
        set, so 2-arg duck-typed policies keep working unchanged."""
        base = getattr(self, self._TIMEOUT_FIELDS[kind])
        if self.timeout_policy is None:
            return base
        if lane is None:
            return self.timeout_policy.timeout_ms(kind, base)
        return self.timeout_policy.timeout_ms(kind, base, lane)

    def timeout_ref(self, kind: str, lane: Optional[str] = None):
        """Timeout argument for ``Transport.wait``: the static float, or —
        with a policy attached — a zero-arg provider the wait re-evaluates
        at every deadline expiry.  A wait armed while the latency EWMA was
        still cold then *stretches* with the congestion the policy has
        since observed, instead of firing a spurious first-wave storm."""
        base = getattr(self, self._TIMEOUT_FIELDS[kind])
        if self.timeout_policy is None:
            return base
        if lane is None:
            return lambda: self.timeout_policy.timeout_ms(kind, base)
        return lambda: self.timeout_policy.timeout_ms(kind, base, lane)

    def link_rtt_ms(self, src: str, dst: str) -> float:
        """Round trip between two compute nodes under the active model."""
        if self.topology is None:
            return self.rtt_ms
        default = self.topology.regions[0]
        return self.topology.rtt_ms(self.placement.get(src, default),
                                    self.placement.get(dst, default))


class Transport:
    """N compute nodes inside one Sim: liveness schedules + messaging."""

    def __init__(self, sim: Sim, nodes: List[str], cfg: ProtocolConfig):
        self.sim = sim
        self.nodes = list(nodes)
        self.cfg = cfg
        self.fail_at: Dict[str, float] = {n: float("inf") for n in nodes}
        self.recover_at: Dict[str, float] = {n: float("inf") for n in nodes}
        self._slots: Dict[Tuple[str, str, str], Event] = {}
        self.deliveries = 0        # storage→compute slot deliveries (payloads)
        self.delivery_batches = 0  # message events carrying them
        # Chaos plane (core/chaos.Nemesis); None = no injection, and every
        # hook below is behind that check, so unattached runs are
        # bit-identical. ``duplicate_deliveries`` counts storage→compute
        # payloads suppressed by the idempotent delivery guard.
        self.chaos = None
        self.duplicate_deliveries = 0
        # Crash–restart incarnations: bumped by the cluster when a node
        # comes back from a crash.  A protocol round started under an older
        # incarnation is a ZOMBIE — its volatile state died with the crash
        # and only ``recover()`` speaks for the new process.
        self.incarnations: Dict[str, int] = {}

    # -- liveness -----------------------------------------------------------
    def alive(self, node: str) -> bool:
        t = self.sim.now
        return t < self.fail_at[node] or t >= self.recover_at[node]

    def incarnation(self, node: str) -> int:
        return self.incarnations.get(node, 0)

    def fail(self, node: str, at: float, recover_at: float = float("inf")):
        self.fail_at[node] = at
        self.recover_at[node] = recover_at

    # -- messaging ----------------------------------------------------------
    def slot(self, dst: str, txn: str, kind: str) -> Event:
        key = (dst, txn, kind)
        ev = self._slots.get(key)
        if ev is None:
            ev = self.sim.event()
            self._slots[key] = ev
        return ev

    def send(self, src: str, dst: str, txn: str, kind: str, value=None):
        """One-way message; delivered after rtt/2 if both ends are alive."""
        if not self.alive(src):
            return
        delay = 0.0 if src == dst else self.cfg.link_rtt_ms(src, dst) / 2.0
        slot = self.slot(dst, txn, kind)
        copies = [0.0]
        if self.chaos is not None and src != dst:
            # Self-messages never traverse a link; everything else can be
            # dropped / delayed / duplicated / reordered.  One deliver per
            # surviving copy — a duplicate hitting an already-triggered slot
            # is a no-op (Event.trigger is idempotent).
            copies = self.chaos.message_plan(src, dst)
            if copies is None:
                return

        def deliver():
            if not self.alive(dst):
                return
            if slot.triggered:
                # Idempotent: a chaos-duplicated copy of an already-landed
                # message is suppressed (and counted).  Trigger was always
                # idempotent; the counter makes the guard observable.
                if self.chaos is not None:
                    self.duplicate_deliveries += 1
                return
            slot.trigger(value)

        for extra in copies:
            self.sim._schedule(self.sim.now + delay + extra, deliver)

    def deliver(self, dst: str, txn: str, kind: str, value=None):
        """Immediate delivery into a slot (no extra network delay).

        Used by storage services that forward votes: the service already
        modelled the acceptor/leader → ``dst`` network leg, so the message
        lands NOW — unless ``dst`` is down, in which case it is dropped like
        any other message to a dead node.
        """
        if not self.alive(dst):
            return
        if self.chaos is not None:
            copies = self.chaos.message_plan("storage", dst)
            if copies is None:
                return
            if copies != [0.0]:
                for extra in copies:
                    self.sim._schedule(
                        self.sim.now + extra,
                        lambda: self._deliver_guarded(dst, txn, kind, value,
                                                      batch=True))
                return
        self._deliver_guarded(dst, txn, kind, value, batch=True)

    def deliver_many(self, dst: str,
                     items: List[Tuple[str, str, object]]) -> None:
        """Coalesced storage→coordinator delivery: one message event carrying
        many ``(txn, kind, value)`` payloads — what a storage-side group
        commit flush produces when several slots in one batch forward their
        votes to the same compute node.  Counts as ONE delivery batch."""
        if not items or not self.alive(dst):
            return
        if self.chaos is not None:
            copies = self.chaos.message_plan("storage", dst)
            if copies is None:
                return
            if copies != [0.0]:
                for extra in copies:
                    self.sim._schedule(
                        self.sim.now + extra,
                        lambda: self._deliver_batch(dst, list(items)))
                return
        self._deliver_batch(dst, items)

    def _deliver_guarded(self, dst: str, txn: str, kind: str, value,
                         batch: bool) -> bool:
        """Idempotent delivery guard: a duplicated storage→compute payload
        for an already-triggered ``(dst, txn, kind)`` slot is suppressed —
        counted, never re-fired — so chaos-duplicated forwards cannot
        corrupt waiter state or inflate the delivery counters."""
        if not self.alive(dst):
            return False
        slot = self.slot(dst, txn, kind)
        if slot.triggered:
            self.duplicate_deliveries += 1
            return False
        self.deliveries += 1
        if batch:
            self.delivery_batches += 1
        slot.trigger(value)
        return True

    def _deliver_batch(self, dst: str,
                       items: List[Tuple[str, str, object]]) -> None:
        fresh = 0
        for txn, kind, value in items:
            if self._deliver_guarded(dst, txn, kind, value, batch=False):
                fresh += 1
        if fresh:
            self.delivery_batches += 1

    def wait(self, dst: str, txn: str, kind: str, timeout_ms) -> Event:
        """Event yielding ('msg', value) or ('timeout', None).

        ``timeout_ms`` is a float, or a zero-arg callable (an adaptive
        timeout policy) that is re-evaluated whenever the current deadline
        expires: if the policy has since raised the timeout — e.g. its
        storage-latency EWMA warmed up under congestion — the wait re-arms
        for the difference instead of reporting a timeout.  A float
        behaves exactly as before (single deadline)."""
        slot = self.slot(dst, txn, kind)
        done = self.sim.event()
        fixed = not callable(timeout_ms)
        provider = (lambda: timeout_ms) if fixed else timeout_ms
        t0 = self.sim.now

        def arm(budget_ms: float) -> None:
            any_ev = self.sim.any_of([slot, self.sim.timeout(budget_ms)])

            def on(ev):
                if done.triggered:
                    return
                idx, val = ev.value
                if idx == 0:
                    done.trigger(("msg", val))
                    return
                remaining = (0.0 if fixed
                             else t0 + provider() - self.sim.now)
                if remaining > 1e-9:
                    arm(remaining)
                else:
                    done.trigger(("timeout", None))

            any_ev.subscribe(on)

        arm(provider())
        return done
