"""Cornus (paper Algorithm 1): LogOnce votes, no decision log, storage-based
non-blocking termination.

Key behavioural points (vs 2PC):
  * The coordinator never logs a decision; it replies to the caller the
    moment the collective vote is known           (latency win, Fig 5–7).
  * Timeout paths go to the storage-based termination protocol that
    CAS-forces ABORT into unresponsive participants' logs (non-blocking,
    Fig 8).
  * Presumed abort: ABORT logging is async and off the critical path.
"""
from __future__ import annotations

from typing import List

from ..state import Decision, TxnOutcome, TxnSpec, Vote
from .base import CommitProtocol
from .registry import register


@register("cornus")
class CornusProtocol(CommitProtocol):

    def log_vote(self, spec: TxnSpec, me: str):
        # LogOnce(VOTE-YES); forwarding subclasses (cornus-opt1 /
        # paxos-commit) have the storage push the decided value straight to
        # the coordinator.                                 [Alg1 L15]
        fwd = self._vote_forward(spec, me) if self.forwards_votes else {}
        resp = yield self.storage.log_once(me, spec.txn_id, Vote.VOTE_YES,
                                           writer=me, **fwd)
        return "ABORT" if resp == Vote.ABORT else "VOTE-YES"

    def on_vote_timeout(self, spec: TxnSpec, me: str, out: TxnOutcome):
        return (yield from self.run_termination(spec, me, out))

    def after_decision(self, spec: TxnSpec, me: str,
                       decision: Decision) -> None:
        if me in spec.participants:
            # Coordinator-as-participant logs the decision asynchronously.
            self.storage.log(me, spec.txn_id,
                             Vote.COMMIT if decision == Decision.COMMIT
                             else Vote.ABORT, writer=me)

    # ========================================================================
    # Cornus termination protocol                          [Alg1 L26-34]
    # ========================================================================
    def terminate(self, spec: TxnSpec, me: str, out: TxnOutcome):
        cfg = self.cfg
        txn = spec.txn_id
        out.ran_termination = True
        # §3.6: known-upfront read-only participants never log a vote, so
        # their empty slots carry NO information about the transaction —
        # CAS-forcing ABORT into one can "win" a slot whose owner already
        # replied VOTE-YES by message, aborting a transaction the
        # coordinator has committed.  They are excluded from termination
        # exactly as the paper excludes them from the decision phase.
        live = [p for p in spec.participants
                if not (p in spec.read_only and spec.read_only_known_upfront)]
        ep = self.epoch(me)
        while True:
            if not self.live(me, ep):
                return None
            targets = [p for p in live if p != me]
            # CAS ABORT into every other participant's log. [Alg1 L27-28]
            reqs = [self.storage.log_once(p, txn, Vote.ABORT, writer=me)
                    for p in targets]
            # Include own log state (me may have VOTE-YES there, or — if me
            # is a non-participant coordinator — nothing).
            if me in live:
                reqs.append(self.storage.log_once(me, txn, Vote.ABORT,
                                                  writer=me))
            if not reqs:
                # Every voting participant is read-only: nothing was ever
                # at stake and the global decision is trivially COMMIT.
                return Decision.COMMIT
            # No single lane gates this retry (the CAS fan-out spans every
            # participant's partition), so it reads the service-global EWMA.
            to = self.sim.timeout(cfg.timeout("termination_retry"))
            got = yield self.sim.any_of([self.sim.all_of(reqs), to])
            idx, val = got
            if idx == 1:
                continue                                   # [Alg1 L33] retry
            states: List[Vote] = val
            if any(s == Vote.ABORT for s in states):       # [Alg1 L30]
                return Decision.ABORT
            if any(s == Vote.COMMIT for s in states):      # [Alg1 L31]
                return Decision.COMMIT
            # All responses are VOTE-YES.                  [Alg1 L32]
            return Decision.COMMIT
