"""paxos-commit (Table 3 row 6, 1.5 RTT): Gray & Lamport's Paxos Commit.

Each participant runs one Paxos instance for its vote with itself as the
proposer ("participant coordinates replication", coloc storage mode) and
the *acceptors* send their accept-acks straight to the transaction
coordinator, which learns each instance's outcome the moment a majority of
acks has reached it — vote-req (0.5) + accept (0.5) + forwarded acks (0.5)
= 1.5 RTT to the global decision.  Like Cornus, no decision record is on
the critical path, and the same storage-CAS termination protocol keeps the
protocol non-blocking.
"""
from __future__ import annotations

from .cornus import CornusProtocol
from .registry import register


@register("paxos-commit")
class PaxosCommitProtocol(CornusProtocol):

    forwards_votes = True
    preferred_storage_mode = "coloc"    # acceptors forward to the coordinator
