"""Conventional 2PC: forced prepare + decision logs, cooperative termination
that *blocks* when the coordinator is down and no peer knows the decision
(§2.1 — the failure mode Cornus exists to remove).
"""
from __future__ import annotations

from ..state import Decision, TxnOutcome, TxnSpec, Vote
from .base import CommitProtocol
from .registry import register


@register("2pc")
class TwoPCProtocol(CommitProtocol):

    readonly_prepare_skip = True
    vote_via_log_once = False         # prepare is a plain forced log
    eager_decision_record = True      # commit record forced before reply

    def log_vote(self, spec: TxnSpec, me: str):
        # 2PC prepare: plain forced log write.
        yield self.storage.log(me, spec.txn_id, Vote.VOTE_YES, writer=me)
        return "VOTE-YES"

    def on_vote_timeout(self, spec: TxnSpec, me: str, out: TxnOutcome):
        # Conventional 2PC: unilateral abort on vote timeout.
        yield from ()
        return Decision.ABORT

    def log_decision(self, spec: TxnSpec, me: str, decision: Decision):
        txn = spec.txn_id
        if decision == Decision.COMMIT:
            # 2PC: the commit record IS the ground truth — it must be
            # durable before replying to the caller (eager decision log).
            yield self.storage.log(me, txn, Vote.COMMIT, writer=me)
        else:
            # Presumed abort: the abort record need not be forced.
            self.storage.log(me, txn, Vote.ABORT, writer=me)

    # ========================================================================
    # 2PC cooperative termination (§2.1) — may block
    # ========================================================================
    def terminate(self, spec: TxnSpec, me: str, out: TxnOutcome):
        cfg, sim = self.cfg, self.sim
        txn = spec.txn_id
        attempt = 0
        ep = self.epoch(me)
        while True:
            if not self.live(me, ep):
                return None
            attempt += 1
            # §3.6: a known-upfront read-only participant concludes COMMIT
            # trivially the moment its reads finish — WITHOUT having seen
            # the decision — so its answer is no evidence of the global
            # outcome and must not be consulted.  (The coordinator's own
            # answer is always authoritative, read-only or not.)
            peers = [p for p in list(spec.participants) + [spec.coordinator]
                     if p != me
                     and not (p != spec.coordinator
                              and p in spec.read_only
                              and spec.read_only_known_upfront)]
            for p in peers:
                self.send(me, p, txn, f"dec-req:{me}:{attempt}", me)
                self._serve_decision_request(p, txn, me, attempt)
            waits = [self.wait(me, txn, f"dec-resp:{p}:{attempt}",
                               cfg.timeout_ref("coop_retry", lane=p))
                     for p in peers]
            results = yield self.sim.all_of(waits)
            for tag, val in results:
                if tag == "msg" and val in (Decision.COMMIT, Decision.ABORT):
                    return val
            # Nobody knows: blocked. Retry (models waiting for coordinator
            # recovery); give up only when the sim horizon ends us.
            self.ctx.blocked[(txn, me)] = True
            yield self.sim.timeout(cfg.timeout("coop_retry"))
            if sim.now > 1e7:
                return None

    def _serve_decision_request(self, server: str, txn: str, asker: str,
                                attempt: int):
        """Peer-side handler for cooperative termination (runs as a server
        thread, so it is modelled at delivery time rather than inside the
        peer's protocol process)."""
        delay = self.cfg.link_rtt_ms(asker, server) / 2.0

        def handle():
            if not self.alive(server):
                return
            st = self.ctx.local_state(server, txn)
            if st["decision"] is not None:
                resp = st["decision"]
            elif st["status"] == "none":
                # Never voted: unilaterally abort and answer ABORT.
                if self.participant_logs:
                    self.storage.log(server, txn, Vote.ABORT, writer=server)
                self.ctx.decide(server, txn, Decision.ABORT)
                resp = Decision.ABORT
            else:
                resp = "UNKNOWN"  # voted yes, uncertain — cannot help
            self.send(server, asker, txn, f"dec-resp:{server}:{attempt}", resp)

        self.sim._schedule(self.sim.now + delay, handle)

    # -- recovery -----------------------------------------------------------
    def recovery_resolve(self, spec: TxnSpec, me: str, out: TxnOutcome,
                         state):
        if state is None or me == spec.coordinator:
            # No vote logged: presumed abort.  A recovering COORDINATOR with
            # no decision record also aborts — its commit record is the
            # ground truth and it was never written, so nobody committed.
            yield from ()
            return Decision.ABORT
        # Participant that voted yes: uncertain — cooperative termination
        # (blocks while the coordinator stays down, §2.1).
        return (yield from self.run_termination(spec, me, out))
