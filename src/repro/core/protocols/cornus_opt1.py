"""cornus-opt1 (Table 3 row 3, 2.5 RTT): the Paxos leader forwards the vote.

Identical to Cornus except the participant's LogOnce(VOTE-YES) asks the
storage service to forward the slot's decided value *directly* to the
coordinator — saving the leader→participant→coordinator dog-leg (half an
inter-replica RTT on the prepare path).  The participant still receives its
own reply (it needs to learn whether a termination peer won the CAS), but
the coordinator no longer waits for it.
"""
from __future__ import annotations

from .cornus import CornusProtocol
from .registry import register


@register("cornus-opt1")
class CornusOpt1Protocol(CornusProtocol):

    forwards_votes = True
    preferred_storage_mode = "leader"   # the row assumes a forwarding leader
