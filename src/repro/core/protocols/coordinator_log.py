"""Coordinator-log (CL) optimization [Stamos & Cristian], §5.6.

Participants reply votes WITHOUT logging; the coordinator batches all
participants' redo logs + its decision into ONE storage write, then replies
to the caller.  Faster than 2PC (one batched write vs sequential
prepare-then-decision), slower than Cornus (the caller still waits for a
storage write), and it violates site autonomy (§5.6) — which is why
participants here never touch storage and must consult the *coordinator's*
log during recovery.
"""
from __future__ import annotations

from ..state import Decision, TxnOutcome, TxnSpec, Vote
from .registry import register
from .twopc import TwoPCProtocol


@register("cl")
class CoordinatorLogProtocol(TwoPCProtocol):

    participant_logs = False            # votes ride in the ack message

    def log_vote(self, spec: TxnSpec, me: str):
        # CL: reply the vote immediately — NO local logging.  The vote reply
        # carries this participant's redo records (bigger ack message, §5.6).
        yield from ()
        return "VOTE-YES"

    def log_decision(self, spec: TxnSpec, me: str, decision: Decision):
        # ONE batched write: every participant's redo log + the decision.
        yield self.storage.log_batch(
            me, spec.txn_id,
            Vote.COMMIT if decision == Decision.COMMIT else Vote.ABORT,
            n_records=len(spec.participants) + 1, writer=me)

    # -- recovery -----------------------------------------------------------
    def recovery_read_partition(self, spec: TxnSpec, me: str) -> str:
        # All durable state lives in the coordinator's batched record.
        return spec.coordinator

    def recovery_resolve(self, spec: TxnSpec, me: str, out: TxnOutcome,
                         state):
        if me == spec.coordinator:
            # The only logger never wrote its batch: presumed abort.
            yield self.storage.log(me, spec.txn_id, Vote.ABORT, writer=me)
            return Decision.ABORT
        # Participant: its own log is empty by design — ask peers
        # (cooperative termination against the coordinator's memory/log).
        return (yield from self.run_termination(spec, me, out))
