"""CommitProtocol: the strategy interface every Table-3 row implements.

The base class owns the Algorithm-1 *skeleton* — the message choreography
that is identical across 2PC, Cornus, CL, cornus-opt1 and paxos-commit —
and exposes the seams where the variants actually differ (Table 3: who logs
what, and who forwards votes):

  roles (spawned as sim processes by the Cluster facade):
    coordinator_round(spec)      – drive one commit as the coordinator
    participant_round(spec, me)  – one participant's side
    terminate(spec, me, out)     – timeout/termination path    [Alg1 L26-34]
    recover(spec, me)            – post-crash resolution (Table 1/2)

  strategy hooks (what subclasses override):
    log_vote(spec, me)           – persist a YES vote ("VOTE-YES"/"ABORT")
    on_vote_timeout(spec, me, out) – coordinator's vote-collection timeout
    log_decision(spec, me, d)    – coordinator's decision point
    after_decision(spec, me, d)  – post-reply logging (off critical path)
    recovery_resolve(...)        – how an in-doubt log state resolves

  capability flags:
    forwards_votes       – storage forwards votes to the coordinator, so
                           participants skip the explicit vote message
    participant_logs     – False for CL: participants never touch storage
    readonly_prepare_skip – §3.6 second case: may a read-only participant
                           discovered at prepare time skip logging?

Grey-highlighted lines of Algorithm 1 are marked ``# [Alg1 L<n>]`` so the
implementation can be audited against the paper.
"""
from __future__ import annotations

from typing import Optional

from ..state import Decision, TxnOutcome, TxnSpec, Vote
from .context import TxnContext
from .transport import ProtocolConfig, Transport


class VoteForward:
    """The ``on_forward`` callback handed to ``log_once``: delivers a slot's
    decided value into the coordinator's vote slot.  Besides being callable
    (one delivery = one message), it exposes the transport payload so a
    batched storage flush can coalesce several slots' forwards bound for
    the same coordinator into ONE ``Transport.deliver_many`` push."""

    __slots__ = ("transport", "dst", "txn", "kind")

    def __init__(self, transport: Transport, dst: str, txn: str, kind: str):
        self.transport = transport
        self.dst = dst
        self.txn = txn
        self.kind = kind

    def payload(self, v: Vote):
        return (self.txn, self.kind,
                "ABORT" if v == Vote.ABORT else "VOTE-YES")

    def __call__(self, v: Vote) -> None:
        self.transport.deliver(self.dst, *self.payload(v))


class CommitProtocol:
    """Shared commit choreography; subclasses fill in the logging strategy."""

    name: str = ""                      # set by @register
    forwards_votes: bool = False
    participant_logs: bool = True
    readonly_prepare_skip: bool = False
    # Storage deployment this protocol's Table-3 row assumes; the executor
    # uses it as the default ``storage_mode`` for replicated deployments.
    preferred_storage_mode: Optional[str] = None
    # Storage-write choreography descriptors (Table 3's "who logs what"),
    # consumed by backend-agnostic drivers — the threaded wall-clock
    # harness replays each row's forced writes against a real store from
    # these instead of re-implementing the sim strategies:
    #   vote_via_log_once     – participants persist votes with LogOnce
    #                           (Cornus family CAS) vs a plain forced log
    #   eager_decision_record – the coordinator forces a decision record
    #                           before replying (2PC's latency cost)
    vote_via_log_once: bool = True
    eager_decision_record: bool = False

    def __init__(self, transport: Transport, storage, ctx: TxnContext,
                 cfg: ProtocolConfig):
        self.transport = transport
        self.storage = storage
        self.ctx = ctx
        self.cfg = cfg

    # -- convenience --------------------------------------------------------
    @property
    def sim(self):
        return self.transport.sim

    def alive(self, node: str) -> bool:
        return self.transport.alive(node)

    def epoch(self, node: str) -> int:
        """Current crash–restart incarnation of ``node``."""
        return self.transport.incarnation(node)

    def live(self, node: str, epoch: int) -> bool:
        """Alive AND still the same incarnation.  A round that started
        before a crash must not keep acting after the node restarts: the
        real process (and its volatile state) died with the crash, and only
        ``recover()`` speaks for the restarted one.  Rounds capture their
        epoch at entry and guard resumption points with this instead of
        plain ``alive``."""
        return (self.transport.alive(node)
                and self.transport.incarnation(node) == epoch)

    def send(self, src, dst, txn, kind, value=None):
        self.transport.send(src, dst, txn, kind, value)

    def wait(self, dst, txn, kind, timeout_ms):
        return self.transport.wait(dst, txn, kind, timeout_ms)

    # ========================================================================
    # Coordinator role
    # ========================================================================
    def coordinator_round(self, spec: TxnSpec):
        cfg, sim, me = self.cfg, self.sim, spec.coordinator
        txn = spec.txn_id
        t0 = sim.now
        out = TxnOutcome(txn_id=txn, node=me, decision=Decision.UNDETERMINED)

        # §3.6 / §5.1.4: fully read-only txn known upfront — skip both phases
        # in EVERY protocol (locks released immediately by executor hook).
        if spec.all_read_only and spec.read_only_known_upfront:
            out.decision = Decision.COMMIT
            out.caller_latency_ms = sim.now - t0
            out.done_at_ms = sim.now
            self.ctx.decide(me, txn, Decision.COMMIT)
            for p in spec.participants:
                if p != me:
                    self.send(me, p, txn, "decision", Decision.COMMIT)
            self.ctx.record(out)
            return out

        # ---- phase 1: vote requests ---------------------------------------
        ep = self.epoch(me)
        if not self.alive(me):
            return out
        for p in spec.participants:                      # [Alg1 L2-3]
            if p != me:
                self.send(me, p, txn, "vote-req",
                          {"participants": list(spec.participants)})
        # The coordinator's own partition (if participating) votes locally;
        # the result lands in its own vote slot like any remote vote.
        if me in spec.participants:
            self.sim.process(self._local_vote(spec))

        # Collect votes.  Each wait names the storage lane (participant
        # partition) whose vote write gates it, so a per-lane adaptive
        # policy stretches ONLY the deadline of a congested partition.
        waits = [self.wait(me, txn, f"vote:{p}",          # [Alg1 L4-7]
                           cfg.timeout_ref("vote", lane=p))
                 for p in spec.participants]
        results = yield self.sim.all_of(waits)
        if not self.live(me, ep):
            return out
        prepare_done = sim.now
        out.prepare_ms = prepare_done - t0

        timed_out = any(tag == "timeout" for tag, _ in results)
        any_abort = any(tag == "msg" and val == "ABORT" for tag, val in results)

        if any_abort:                                     # [Alg1 L5]
            decision = Decision.ABORT
        elif not timed_out:                               # [Alg1 L6]
            decision = Decision.COMMIT
        else:                                             # [Alg1 L7]
            decision = yield from self.on_vote_timeout(spec, me, out)
        if decision is None or not self.live(me, ep):
            return out

        # ---- decision point (strategy: who logs it, and when) -------------
        yield from self.log_decision(spec, me, decision)
        if not self.live(me, ep):
            return out

        out.decision = decision                           # [Alg1 L8]
        out.caller_latency_ms = sim.now - t0
        out.commit_ms = sim.now - prepare_done
        self.ctx.decide(me, txn, decision)

        for p in spec.participants:                       # [Alg1 L9-10]
            if p != me:
                self.send(me, p, txn, "decision", decision)
        self.after_decision(spec, me, decision)
        out.done_at_ms = sim.now
        self.ctx.record(out)
        return out

    def _local_vote(self, spec: TxnSpec):
        """Coordinator's own partition voting (no network hop); the result
        is sent to the coordinator's vote slot with zero delay so the
        collection loop treats local and remote votes uniformly."""
        me, txn = spec.coordinator, spec.txn_id
        ep = self.epoch(me)
        st = self.ctx.local_state(me, txn)
        if me in spec.read_only and spec.read_only_known_upfront:
            st["status"] = "voted"
            self.send(me, me, txn, f"vote:{me}", "VOTE-YES")
            return
        if not spec.vote_of(me):
            if self.participant_logs:
                self.storage.log(me, txn, Vote.ABORT, writer=me)  # async
            self.ctx.decide(me, txn, Decision.ABORT)
            self.send(me, me, txn, f"vote:{me}", "ABORT")
            return
        vote = yield from self.log_vote(spec, me)
        if not self.live(me, ep):
            return
        if vote == "ABORT":
            # A peer already aborted on our behalf via termination.
            self.ctx.decide(me, txn, Decision.ABORT)
            self.send(me, me, txn, f"vote:{me}", "ABORT")
            return
        st["status"] = "voted"
        if self.cfg.elr:
            self.ctx.precommit(me, txn)
        if not self.forwards_votes:
            self.send(me, me, txn, f"vote:{me}", "VOTE-YES")

    # ========================================================================
    # Participant role                                     [Alg1 L11-25]
    # ========================================================================
    def participant_round(self, spec: TxnSpec, me: str):
        cfg, sim = self.cfg, self.sim
        txn = spec.txn_id
        if me == spec.coordinator:
            return  # voted via _local_vote
        t0 = sim.now
        ep = self.epoch(me)
        out = TxnOutcome(txn_id=txn, node=me, decision=Decision.UNDETERMINED)
        st = self.ctx.local_state(me, txn)

        if spec.all_read_only and spec.read_only_known_upfront:
            tag, val = yield self.wait(
                me, txn, "decision",
                cfg.timeout_ref("votereq", lane=spec.coordinator))
            self.ctx.decide(me, txn, Decision.COMMIT)
            out.decision = Decision.COMMIT
            out.done_at_ms = sim.now
            self.ctx.record(out)
            return out

        tag, msg = yield self.wait(                        # [Alg1 L12]
            me, txn, "vote-req",
            cfg.timeout_ref("votereq", lane=spec.coordinator))
        if not self.live(me, ep):
            return out
        if tag == "timeout":                               # [Alg1 L13]
            if self.participant_logs:
                yield self.storage.log(me, txn, Vote.ABORT, writer=me)
            return self._finish(spec, me, out, Decision.ABORT)

        votes_yes = spec.vote_of(me)
        read_only = me in spec.read_only

        if not votes_yes:
            # VOTE-NO: presumed abort — async log, reply.  [Alg1 L23-25]
            if self.participant_logs:
                self.storage.log(me, txn, Vote.ABORT, writer=me)
            self.send(me, spec.coordinator, txn, f"vote:{me}", "ABORT")
            return self._finish(spec, me, out, Decision.ABORT)

        if read_only and spec.read_only_known_upfront:     # [Alg1 L14]
            # Known-upfront read-only participant: skip prepare logging,
            # release locks, reply YES (§3.6 simple case, all protocols).
            st["status"] = "voted"
            self.send(me, spec.coordinator, txn, f"vote:{me}", "VOTE-YES")
            return self._finish(spec, me, out, Decision.COMMIT)

        if read_only and self.readonly_prepare_skip:
            # §3.6 second case, 2PC side: a read-only participant discovered
            # at prepare time skips logging entirely and can release locks
            # after replying.  (Cornus must NOT take this path: a missing
            # VOTE-YES in its log reads as abortable by the termination
            # protocol — it falls through to log_vote below.)
            st["status"] = "voted"
            self.send(me, spec.coordinator, txn, f"vote:{me}", "VOTE-YES")
            self._watch_decision(spec, me)
            tag, decision = yield self.wait(
                me, txn, "decision",
                cfg.timeout_ref("decision", lane=spec.coordinator))
            if not self.live(me, ep):
                return out
            d = decision if tag == "msg" else Decision.ABORT
            return self._finish(spec, me, out, d)

        # Persist the YES vote (strategy seam: LogOnce for the Cornus
        # family — possibly with storage-side forwarding — plain forced
        # log for 2PC, nothing for CL).                    [Alg1 L15]
        vote = yield from self.log_vote(spec, me)
        if not self.live(me, ep):
            return out
        if vote == "ABORT":                                # [Alg1 L16-17]
            # A peer already aborted on our behalf via termination.
            self.send(me, spec.coordinator, txn, f"vote:{me}", "ABORT")
            return self._finish(spec, me, out, Decision.ABORT)

        st["status"] = "voted"
        out.prepare_ms = sim.now - t0
        if self.cfg.elr:
            self.ctx.precommit(me, txn)
        if not self.forwards_votes:                        # [Alg1 L18-19]
            self.send(me, spec.coordinator, txn, f"vote:{me}", "VOTE-YES")

        # Wait for the decision.  The decision's gating write (2PC's
        # eager commit record) lands on the coordinator's partition, so
        # that is the lane whose congestion should stretch this wait.
        self._watch_decision(spec, me)                     # [Alg1 L20-21]
        tag, decision = yield self.wait(
            me, txn, "decision",
            cfg.timeout_ref("decision", lane=spec.coordinator))
        if not self.live(me, ep):
            return out
        if tag == "timeout":
            out.ran_termination = True
            tstart = sim.now
            decision = yield from self.run_termination(spec, me, out)
            out.termination_ms = sim.now - tstart
            if not self.live(me, ep):
                return out
        if decision is None:
            # Blocked until the sim horizon (2PC family), or died.
            out.decision = Decision.UNDETERMINED
            self.ctx.record(out)
            return out
        # Log the decision locally.                        [Alg1 L22]
        if self.participant_logs:
            yield self.storage.log(me, txn,
                                   Vote.COMMIT if decision == Decision.COMMIT
                                   else Vote.ABORT, writer=me)
        return self._finish(spec, me, out, decision)

    def _finish(self, spec: TxnSpec, me: str, out: TxnOutcome,
                decision: Decision) -> TxnOutcome:
        self.ctx.decide(me, spec.txn_id, decision)
        out.decision = decision
        out.done_at_ms = self.sim.now
        self.ctx.record(out)
        return out

    # ========================================================================
    # Strategy hooks
    # ========================================================================
    def log_vote(self, spec: TxnSpec, me: str):
        """Persist ``me``'s YES vote; return "VOTE-YES" or "ABORT" (the
        latter when a termination peer won the race for the log slot)."""
        raise NotImplementedError
        yield  # generator protocol

    def on_vote_timeout(self, spec: TxnSpec, me: str, out: TxnOutcome):
        """Coordinator timed out collecting votes; return the decision
        (None = blocked/dead)."""
        raise NotImplementedError
        yield

    def log_decision(self, spec: TxnSpec, me: str, decision: Decision):
        """Coordinator's decision point, BEFORE replying to the caller.
        Cornus-family: nothing (the latency win)."""
        yield from ()

    def after_decision(self, spec: TxnSpec, me: str,
                       decision: Decision) -> None:
        """Off-critical-path logging after the caller got its reply."""

    def terminate(self, spec: TxnSpec, me: str, out: TxnOutcome):
        """Resolve an in-doubt transaction after a timeout; return the
        decision or None (blocked/dead)."""
        raise NotImplementedError
        yield

    def run_termination(self, spec: TxnSpec, me: str, out: TxnOutcome):
        """``terminate`` behind a per-(node, txn) singleflight.

        With ``cfg.termination_dedup`` a node's concurrent termination
        entries (decision-timeout participant, vote-timeout coordinator,
        recovery) join the run already in flight and share its decision
        instead of racing redundant CAS rounds.  A joiner that receives
        None (the runner died mid-termination) retries as the leader —
        dedup never turns a live node's bounded termination into a
        blocked one.  Always the entry point; ``terminate`` stays the
        per-protocol mechanism."""
        key = (me, spec.txn_id)
        joined = False
        while self.cfg.termination_dedup:
            inflight = self.ctx.term_inflight.get(key)
            if inflight is None:
                break
            if not joined:
                # One logical join per caller, however many dead runners
                # it outlives — keeps dedup_hits an honest effectiveness
                # counter.
                joined = True
                self.ctx.dedup_hits += 1
            out.ran_termination = True
            decision = yield inflight
            if decision is not None or not self.alive(me):
                return decision
        self.ctx.terminations += 1
        if not self.cfg.termination_dedup:
            return (yield from self.terminate(spec, me, out))
        ev = self.ctx.term_inflight[key] = self.sim.event()
        decision = None
        try:
            decision = yield from self.terminate(spec, me, out)
        finally:
            if self.ctx.term_inflight.get(key) is ev:
                del self.ctx.term_inflight[key]
            ev.trigger(decision)
        return decision

    def _watch_decision(self, spec: TxnSpec, me: str) -> None:
        """Register a storage decision watcher feeding ``me``'s decision
        slot (``cfg.push_decisions``): the service pushes the txn's first
        terminal record the moment it lands, so a participant whose
        coordinator is slow or dead learns the decision without timing out
        into the termination protocol."""
        if not self.cfg.push_decisions:
            return
        watch = getattr(self.storage, "watch_decision", None)
        if watch is None:
            return
        txn = spec.txn_id

        def push(value: Vote) -> None:
            # The storage already charged its front-end→me push leg.
            d = (Decision.ABORT if value == Vote.ABORT else Decision.COMMIT)
            self.transport.deliver(me, txn, "decision", d)

        watch(txn, push, node=me)

    # -- vote forwarding (cornus-opt1 / paxos-commit) -----------------------
    def _vote_forward(self, spec: TxnSpec, me: str) -> dict:
        """log_once kwargs that make the storage service forward the slot's
        decided value straight to the coordinator's vote slot (Table 3:
        'Paxos leader forwards vote' / 'acceptors forward to coordinator')."""
        return dict(forward_to=spec.coordinator,
                    on_forward=VoteForward(self.transport, spec.coordinator,
                                           spec.txn_id, f"vote:{me}"))

    # ========================================================================
    # Recovery (Table 1 / Table 2 "During Recovery" column)
    # ========================================================================
    def recovery_read_partition(self, spec: TxnSpec, me: str) -> str:
        """Which partition's log a recovering node consults (CL: the
        coordinator's — participants have no log of their own)."""
        return me

    def recover(self, spec: TxnSpec, me: str):
        """Recovered node resolving one in-flight transaction."""
        txn = spec.txn_id
        out = TxnOutcome(txn_id=txn, node=me, decision=Decision.UNDETERMINED)
        part = self.recovery_read_partition(spec, me)
        state = yield self.storage.read_state(part, txn, writer=me)
        if state in (Vote.COMMIT, Vote.ABORT):
            out.decision = Decision(state.value)
        else:
            d = yield from self.recovery_resolve(spec, me, out, state)
            out.decision = d if d else Decision.UNDETERMINED
            if d and self.participant_logs:
                yield self.storage.log(
                    me, txn, Vote.COMMIT if d == Decision.COMMIT
                    else Vote.ABORT, writer=me)
        if out.decision != Decision.UNDETERMINED:
            self.ctx.decide(me, txn, out.decision)
        out.done_at_ms = self.sim.now
        self.ctx.outcomes[(txn, me + ":recovery")] = out
        return out

    def recovery_resolve(self, spec: TxnSpec, me: str, out: TxnOutcome,
                         state: Optional[Vote]):
        """In-doubt log state (None or VOTE-YES) after a crash.  Default
        (Cornus family): the storage-based termination protocol resolves in
        bounded time whether or not anyone else is alive."""
        return (yield from self.run_termination(spec, me, out))
