"""Durable-state lifecycle: checksummed records, truncation watermarks, scrub.

Cornus delegates *all* durability to the storage layer: every vote and
decision is a LogOnce record, and historically those records lived forever
and were trusted blindly.  This module supplies the three primitives the
lifecycle layer is built from:

  * **CRC32 record framing** — `encode_record` / `decode_record` wrap a
    state record (``vote\nwriter\n``) in a ``crc1`` header carrying the
    body length and CRC32.  Readers distinguish a *torn tail* (body shorter
    than the declared length — an unacknowledged write that died mid-flight,
    safe to treat as absent) from *bit-rot* (full-length body whose CRC
    mismatches — a previously acknowledged record that must NOT be treated
    as absent, only repaired from redundancy).  Both surface as a typed
    `CorruptRecord` instead of garbage bytes.

  * **`LifecycleConfig`** — the default-off switch block threaded through
    `StoreConfig`/`BenchConfig`.  With ``lifecycle=None`` every store
    behaves bit-identically to the pre-lifecycle code.

  * **`GcEntry` truncation journal** — every slot the GC watermark
    truncates leaves a journal entry recording the value it held and the
    durable terminal decision that justified truncating it.  The history
    checker consumes this journal to enforce AC-GC: truncation must
    preserve recoverability (never truncate a slot whose transaction has
    no durable terminal decision, and never journal a decision the nodes
    did not actually reach).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field, asdict
from typing import Optional, Tuple, Union

RECORD_MAGIC = b"crc1 "

__all__ = [
    "CorruptRecord", "GcEntry", "LifecycleConfig",
    "encode_record", "decode_record", "RECORD_MAGIC",
]


@dataclass(frozen=True)
class CorruptRecord:
    """Typed result of reading a record that failed its checksum.

    ``torn=True`` means the body is shorter than the declared length: the
    write was never acknowledged, so the record is safe to treat as absent
    (LogOnce may claim the slot).  ``torn=False`` means full-length bit-rot
    of a previously acknowledged record: it must never be treated as absent
    — only repaired from a replica or a sibling slot of the same txn.
    """
    partition: str = ""
    txn: str = ""
    torn: bool = False
    detail: str = ""
    # Flows harmlessly through code that treats records as Vote-like.
    value = "CORRUPT"

    def is_decision(self) -> bool:
        return False


def encode_record(state_value: str, writer: str) -> bytes:
    """Frame ``state\\nwriter\\n`` with a crc1 header (length + CRC32)."""
    body = f"{state_value}\n{writer}\n".encode()
    head = RECORD_MAGIC + b"%08x %08x\n" % (zlib.crc32(body), len(body))
    return head + body


def decode_record(blob: bytes, partition: str = "",
                  txn: str = "") -> Union[Tuple[str, str], CorruptRecord]:
    """Decode a crc1-framed record; returns ``(state_value, writer)``.

    Returns a `CorruptRecord` (never raises) on framing damage:
    ``torn=True`` for empty blobs / short headers / short bodies,
    ``torn=False`` for full-length bodies whose CRC32 mismatches.
    Legacy (unframed) records are passed through by the caller — this
    function only handles blobs carrying the magic.
    """
    if not blob.startswith(RECORD_MAGIC):
        return CorruptRecord(partition, txn, torn=True, detail="missing frame header")
    head, sep, body = blob[len(RECORD_MAGIC):].partition(b"\n")
    if not sep:
        return CorruptRecord(partition, txn, torn=True, detail="truncated header")
    try:
        crc_hex, len_hex = head.split()
        want_crc, want_len = int(crc_hex, 16), int(len_hex, 16)
    except ValueError:
        return CorruptRecord(partition, txn, torn=True, detail="unparsable header")
    if len(body) < want_len:
        return CorruptRecord(
            partition, txn, torn=True,
            detail=f"torn tail: {len(body)}/{want_len} bytes")
    body = body[:want_len]
    if zlib.crc32(body) != want_crc:
        return CorruptRecord(
            partition, txn, torn=False,
            detail=f"crc mismatch: {zlib.crc32(body):08x} != {want_crc:08x}")
    try:
        state_value, writer = body.decode().splitlines()[:2]
    except (UnicodeDecodeError, ValueError):
        return CorruptRecord(partition, txn, torn=False, detail="undecodable body")
    return state_value, writer


@dataclass
class LifecycleConfig:
    """Default-off switches for the durable-state lifecycle.

    ``checksums`` arms CRC32 record framing (torn-tail / bit-rot detection).
    ``gc`` arms the per-partition low-watermark truncation pass.
    ``scrub`` arms the anti-entropy scrubber on replicated stores.
    Intervals are sim-ms cadences for the background passes (0 = manual
    passes only).  ``quarantine_threshold`` is the per-volume corrupt-record
    count at which the volume is quarantined and refreshed wholesale.
    """
    checksums: bool = True
    gc: bool = False
    scrub: bool = False
    gc_interval_ms: float = 25.0
    scrub_interval_ms: float = 40.0
    quarantine_threshold: int = 3

    @classmethod
    def coerce(cls, value) -> Optional["LifecycleConfig"]:
        """Accept None / dict (repro-bundle JSON) / LifecycleConfig."""
        if value is None or isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls(**value)
        raise TypeError(f"cannot coerce {type(value).__name__} to LifecycleConfig")

    def to_dict(self) -> dict:
        return asdict(self)


@dataclass(frozen=True)
class GcEntry:
    """Truncation-journal entry: one slot removed by the GC watermark.

    ``value`` is the state the slot held when truncated; ``decision`` is
    the durable terminal decision that settled the txn and justified the
    truncation; ``settled`` records whether the watermark rule was actually
    satisfied (the checker flags AC-GC on any entry where it was not).
    """
    partition: str
    txn: str
    value: Optional[str]
    decision: Optional[str]
    settled: bool
    at: float = 0.0
