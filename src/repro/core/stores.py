"""Unified store registry: one ``StoreConfig`` for both backends.

The simulated services (``SimStorage`` / ``ReplicatedSimStorage``) and the
threaded stores (``MemoryStore`` / ``FileStore`` / ``ReplicatedStore``) grew
divergent constructor signatures; every bench and test picked a backend by
importing a class and hand-threading its kwargs.  This module mirrors
``protocols.registry``: backends register under a NAME, ``StoreConfig``
carries the union of knobs (each backend reads the subset it understands,
exactly the kwargs it always took), and ``build_store`` constructs the
store — so ``BenchConfig`` selects storage backends the way it selects
protocols.

Registered backends:

  memory          – ``MemoryStore``              (threaded, single node)
  file            – ``FileStore``                (threaded, needs ``root``)
  replicated      – ``ReplicatedStore``          (threaded, quorum Paxos)
  sim             – ``SimStorage``               (needs ``sim=``)
  replicated-sim  – ``ReplicatedSimStorage``     (needs ``sim=``)

Threaded backends optionally wrap in a ``BatchingStore`` group-commit
decorator (``batching=True``); simulated backends batch via ``BatchConfig``
as before.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Mapping, Optional, Sequence

from .control import DecisionCacheConfig
from .lifecycle import LifecycleConfig
from .storage import (AZURE_REDIS, BatchConfig, BatchingStore,
                      DelayedMemoryStore, DelayedReplicatedStore, FileStore,
                      LatencyModel, MemoryStore, RegionTopology,
                      ReplicatedSimStorage, ReplicatedStore, SimStorage)


@dataclass
class StoreConfig:
    """Union of every backend's knobs; unknown-to-a-backend fields are
    simply unread (the same contract ``BenchConfig`` has with protocols)."""

    backend: str = "memory"            # any name in the registry
    seed: int = 0
    # Control plane (decision cache / singleflight / push) — consumed by
    # every backend through the shared core in ``control``.
    decisions: Optional[DecisionCacheConfig] = None
    # Replicated backends (threaded and sim).
    replication: int = 3
    max_rounds: int = 256              # threaded proposer retry bound
    # Initial member ids (defaults to range(replication)); the live set can
    # then change via add_replica/remove_replica/set_replication.
    membership: Optional[Sequence[int]] = None
    # file backend.
    root: Optional[str] = None
    # Simulated services.
    model: Optional[LatencyModel] = None
    batch: Optional[BatchConfig] = None
    topology: Optional[RegionTopology] = None
    replica_regions: Optional[Sequence[str]] = None
    placement: Optional[Mapping[str, str]] = None
    mode: str = "leader"               # leader | coloc
    op_timeout_ms: Optional[float] = None
    lease_ms: float = 200.0
    # Threaded group-commit decorator (sim backends batch via ``batch``).
    batching: bool = False
    window_s: float = 0.0
    max_batch: int = 64
    # Injected per-op service time for wall-clock harnesses (memory /
    # replicated backends only): the sleep sits inside the op, under the
    # control plane, so cache hits and singleflight joiners skip it.
    # 0 (the default) constructs the plain store — bit-identical.
    service_delay_ms: float = 0.0
    # Threaded-store chaos decorator (core.chaos.ChaosStore): per-op
    # injected delay/jitter and drop→retry with exponential backoff.
    # Both 0 (the default) skips the wrapper entirely — bit-identical.
    chaos_drop_p: float = 0.0
    chaos_delay_ms: float = 0.0
    chaos_jitter_ms: float = 0.0
    # Durable-state lifecycle (checksummed records, GC watermark, scrub).
    # None (the default) keeps every backend bit-identical; accepts a
    # LifecycleConfig or a plain dict (repro-bundle JSON).
    lifecycle: Optional[object] = None


_REGISTRY: Dict[str, Callable] = {}
_SIMULATED = {"sim", "replicated-sim"}


def register_store(name: str):
    """Class/function decorator: register a builder under ``name``.

    A builder is ``fn(cfg: StoreConfig, sim) -> store``; ``sim`` is None
    for threaded backends."""
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_store(name: str) -> Callable:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown store backend {name!r} "
                       f"(registered: {known})") from None


def registered_stores() -> List[str]:
    return sorted(_REGISTRY)


def is_simulated(name: str) -> bool:
    """True if the backend runs inside the discrete-event sim (its builder
    requires ``sim=`` and its ops return sim Events)."""
    get_store(name)                    # validate, same error surface
    return name in _SIMULATED


def build_store(cfg: StoreConfig, sim=None):
    """Construct the configured backend (and, for threaded backends with
    ``batching=True``, wrap it in the group-commit decorator)."""
    builder = get_store(cfg.backend)
    simulated = is_simulated(cfg.backend)
    if simulated and sim is None:
        raise ValueError(f"backend {cfg.backend!r} needs sim= "
                         f"(it runs inside the discrete-event simulator)")
    store = builder(cfg, sim)
    if cfg.batching and not simulated:
        store = BatchingStore(store, window_s=cfg.window_s,
                              max_batch=cfg.max_batch)
    if not simulated and (cfg.chaos_drop_p > 0 or cfg.chaos_delay_ms > 0
                          or cfg.chaos_jitter_ms > 0):
        from .chaos import ChaosStore
        store = ChaosStore(store, seed=cfg.seed, drop_p=cfg.chaos_drop_p,
                           delay_ms=cfg.chaos_delay_ms,
                           jitter_ms=cfg.chaos_jitter_ms)
    return store


# --------------------------------------------------------------------------
# Builders — each constructs with EXACTLY the kwargs direct call sites
# always passed, so switching to the factory is bit-identical.
# --------------------------------------------------------------------------
@register_store("memory")
def _build_memory(cfg: StoreConfig, sim=None):
    lc = LifecycleConfig.coerce(cfg.lifecycle)
    if cfg.service_delay_ms > 0:
        return DelayedMemoryStore(cfg.service_delay_ms / 1e3,
                                  decisions=cfg.decisions, lifecycle=lc)
    return MemoryStore(decisions=cfg.decisions, lifecycle=lc)


@register_store("file")
def _build_file(cfg: StoreConfig, sim=None):
    if cfg.root is None:
        raise ValueError("file backend needs StoreConfig.root")
    return FileStore(cfg.root, decisions=cfg.decisions,
                     lifecycle=LifecycleConfig.coerce(cfg.lifecycle))


@register_store("replicated")
def _build_replicated(cfg: StoreConfig, sim=None):
    lc = LifecycleConfig.coerce(cfg.lifecycle)
    if cfg.service_delay_ms > 0:
        return DelayedReplicatedStore(cfg.service_delay_ms / 1e3,
                                      n_replicas=cfg.replication,
                                      seed=cfg.seed,
                                      max_rounds=cfg.max_rounds,
                                      decisions=cfg.decisions,
                                      membership=cfg.membership,
                                      lifecycle=lc)
    return ReplicatedStore(n_replicas=cfg.replication, seed=cfg.seed,
                           max_rounds=cfg.max_rounds,
                           decisions=cfg.decisions,
                           membership=cfg.membership,
                           lifecycle=lc)


@register_store("sim")
def _build_sim(cfg: StoreConfig, sim=None):
    return SimStorage(sim, cfg.model or AZURE_REDIS, seed=cfg.seed,
                      batch=cfg.batch, decisions=cfg.decisions,
                      lifecycle=LifecycleConfig.coerce(cfg.lifecycle))


@register_store("replicated-sim")
def _build_replicated_sim(cfg: StoreConfig, sim=None):
    return ReplicatedSimStorage(
        sim, cfg.model or AZURE_REDIS, n_replicas=cfg.replication,
        seed=cfg.seed, topology=cfg.topology,
        replica_regions=cfg.replica_regions,
        placement=cfg.placement, mode=cfg.mode,
        op_timeout_ms=cfg.op_timeout_ms, batch=cfg.batch,
        lease_ms=cfg.lease_ms, decisions=cfg.decisions,
        membership=cfg.membership,
        lifecycle=LifecycleConfig.coerce(cfg.lifecycle))
