"""Chaos plane: seeded fault schedules, the Nemesis that injects them, and
compute-side graceful degradation (retry policy + per-partition breaker).

Gray & Lamport's adversary for atomic commit is not "a node stops being
called": it loses, duplicates, delays and reorders messages, partitions the
network (symmetrically or one-way), skews clocks, tears replicated writes,
and crash-restarts processes that then recover from their durable log.  This
module makes that adversary a first-class, *reproducible* object:

  * ``FaultSchedule`` — a declarative, JSON-round-trippable description of
    every fault to inject (link chaos, partitions with timed heals, clock
    skew on lease deadlines, torn partial-scatter writes, crash–restarts),
    plus ``FaultSchedule.generate`` for seeded random schedules.
  * ``Nemesis`` — the runtime: attached to a ``Transport`` and a simulated
    storage service it answers their chaos hooks from a DEDICATED rng, so a
    detached nemesis (the default ``chaos is None`` everywhere) leaves every
    existing run bit-identical.
  * ``GuardedStorage`` — compute-side degradation wrapping storage ops: a
    per-attempt deadline with idempotent re-issue (LogOnce retries are safe
    by construction) under a jittered-exponential ``RetryPolicy``, and a
    per-partition ``CircuitBreaker`` that stops hammering an unreachable
    partition (trips / half-open probes surfaced as counters).
  * ``ChaosStore`` — the threaded-store decorator: per-op delay and
    drop→retry against real stores (``MemoryStore`` etc.), same taxonomy.
  * ``write_repro_bundle`` / ``load_repro_bundle`` — serialize the exact
    schedule + run config of a failing chaos run so
    ``python -m benchmarks.chaos --replay <file>`` reproduces it.

Endpoint naming: compute nodes use their transport names (``n0``...), the
storage front end is ``"storage"``, replica endpoints are ``"r0"``...
``"*"`` matches anything.  Partition sides are explicit endpoint lists.
"""
from __future__ import annotations

import json
import os
import random
import time
import threading
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["LinkChaos", "NetPartition", "ClockSkew", "TornWrite",
           "CrashRestart", "BitFlip", "TornTail", "Truncation",
           "FaultSchedule", "Nemesis", "RetryPolicy",
           "CircuitBreaker", "GuardedStorage", "ChaosStore",
           "write_repro_bundle", "load_repro_bundle", "STORAGE", "replica"]

STORAGE = "storage"            # the storage front end's endpoint name


def replica(i: int) -> str:
    """Endpoint name of replica ``i`` (for link faults / partitions)."""
    return f"r{i}"


def _match(pattern: str, name: str) -> bool:
    return pattern == "*" or name == "*" or pattern == name


# ---------------------------------------------------------------------------
# Fault vocabulary (all JSON-serializable dataclasses)
# ---------------------------------------------------------------------------
@dataclass
class LinkChaos:
    """Per-link message chaos active on [at, until)."""

    src: str = "*"
    dst: str = "*"
    at: float = 0.0
    until: float = float("inf")
    drop_p: float = 0.0            # message silently lost
    dup_p: float = 0.0             # message delivered twice
    delay_ms: float = 0.0          # fixed extra delay
    jitter_ms: float = 0.0         # + uniform extra delay
    reorder_p: float = 0.0         # extra reorder jitter on this message
    reorder_ms: float = 3.0        # magnitude of the reorder jitter

    def active(self, t: float) -> bool:
        return self.at <= t < self.until

    def matches(self, src: str, dst: str) -> bool:
        return _match(self.src, src) and _match(self.dst, dst)


@dataclass
class NetPartition:
    """Cut every link between ``side_a`` and ``side_b`` on [at, heal_at);
    ``symmetric=False`` cuts only the a→b direction (asymmetric partition,
    the classic one-way-visibility failure)."""

    at: float
    heal_at: float
    side_a: Tuple[str, ...]
    side_b: Tuple[str, ...]
    symmetric: bool = True

    def active(self, t: float) -> bool:
        return self.at <= t < self.heal_at

    def cuts(self, src: str, dst: str) -> bool:
        a, b = self.side_a, self.side_b
        if src in a and dst in b:
            return True
        return self.symmetric and src in b and dst in a


@dataclass
class ClockSkew:
    """The storage service's clock reads ``skew_ms`` ahead of sim time on
    [at, until) — applied to lease-deadline validity, so positive skew
    expires leases early (spurious acquisitions) and negative skew makes a
    holder trust a lease longer than it should (ballots must still keep it
    safe)."""

    at: float
    until: float
    skew_ms: float

    def active(self, t: float) -> bool:
        return self.at <= t < self.until


@dataclass
class TornWrite:
    """With probability ``p``, a replica scatter on [at, until) reaches only
    the first ``keep`` of its targets — a torn (partial) replicated write,
    the under-replication the quorum/ballot machinery must absorb."""

    at: float
    until: float
    p: float
    keep: int = 1

    def active(self, t: float) -> bool:
        return self.at <= t < self.until


@dataclass
class CrashRestart:
    """Compute node ``node`` crashes at ``at`` and restarts at
    ``restart_at`` with its durable log intact; on restart it runs the
    registered protocol's ``recover()`` for every in-doubt transaction."""

    node: str
    at: float
    restart_at: float


@dataclass
class BitFlip:
    """Durable-state bit-rot: at ``at``, flip ``count`` bytes in the bodies
    of randomly chosen *repairable* records (slots whose txn has another
    intact terminal copy — on a sibling slot, or on another replica at
    R>1).  The checksummed record format must detect the rot, surface a
    typed ``CorruptRecord``, and repair it; without checksums this fault
    would silently serve garbage."""

    at: float
    count: int = 1


@dataclass
class TornTail:
    """With probability ``p``, a non-decision single-store write on
    [at, until) both loses its response AND leaves a torn (truncated)
    durable frame — the classic crash-mid-write.  Safe for the reader to
    treat as absent precisely because the response was lost: the record
    was never acknowledged."""

    at: float
    until: float
    p: float

    def active(self, t: float) -> bool:
        return self.at <= t < self.until


@dataclass
class Truncation:
    """GC pulse train: run the store's watermark truncation pass at ``at``
    and then every ``every_ms`` until ``until`` (one-shot if
    ``every_ms == 0``).  Lets schedules interleave truncation with crashes
    and partitions, which is exactly what AC-GC certifies."""

    at: float
    every_ms: float = 0.0
    until: float = 0.0


_FAULT_KINDS = {"links": LinkChaos, "partitions": NetPartition,
                "skews": ClockSkew, "torn": TornWrite,
                "crashes": CrashRestart, "bitflips": BitFlip,
                "torn_tails": TornTail, "truncations": Truncation}


@dataclass
class FaultSchedule:
    """Everything a chaos run injects, keyed by one seed — the unit of
    reproducibility: (schedule, bench config) fully determines the run."""

    seed: int = 0
    links: List[LinkChaos] = field(default_factory=list)
    partitions: List[NetPartition] = field(default_factory=list)
    skews: List[ClockSkew] = field(default_factory=list)
    torn: List[TornWrite] = field(default_factory=list)
    crashes: List[CrashRestart] = field(default_factory=list)
    # Durable-state faults (default-empty keeps old bundles loading).
    bitflips: List[BitFlip] = field(default_factory=list)
    torn_tails: List[TornTail] = field(default_factory=list)
    truncations: List[Truncation] = field(default_factory=list)

    # -- serialization (the failure-repro bundle rides on this) ------------
    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSchedule":
        kw = {"seed": d.get("seed", 0)}
        for key, typ in _FAULT_KINDS.items():
            items = []
            for entry in d.get(key, []):
                if key == "partitions":
                    entry = dict(entry, side_a=tuple(entry["side_a"]),
                                 side_b=tuple(entry["side_b"]))
                items.append(typ(**entry))
            kw[key] = items
        return cls(**kw)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "FaultSchedule":
        return cls.from_dict(json.loads(s))

    # -- seeded random schedules (the chaos sweep's generator) -------------
    @classmethod
    def generate(cls, seed: int, nodes: Sequence[str], horizon_ms: float,
                 n_replicas: int = 0, mix: str = "full") -> "FaultSchedule":
        """Deterministic schedule for ``seed``: same inputs, same faults.

        ``mix`` picks the fault families: ``messages`` (drop/dup/delay/
        reorder), ``partition`` (timed symmetric+asymmetric cuts),
        ``crash`` (coordinator/participant crash–restarts), ``torn``
        (partial scatters + replica-link chaos), ``skew`` (lease clock
        skew), ``rot`` (durable-state decay: bit-flips, torn write tails,
        GC truncation pulses, plus one crash–restart so recovery replays
        the decayed log), or ``full`` (all of the classic families,
        lighter individual rates — ``rot`` stays opt-in so pre-lifecycle
        schedules keep their exact rng draw sequences)."""
        known = ("messages", "partition", "crash", "torn", "skew", "full",
                 "rot")
        if mix not in known:
            raise ValueError(f"unknown fault mix {mix!r} "
                             f"(one of: {', '.join(known)})")
        rng = random.Random(seed ^ 0xC4A05)
        sched = cls(seed=seed)
        nodes = list(nodes)
        full = mix == "full"
        scale = 0.5 if full else 1.0

        def window(frac_lo=0.05, frac_hi=0.6):
            start = rng.uniform(0.0, horizon_ms * frac_hi)
            length = rng.uniform(frac_lo, frac_hi) * horizon_ms
            return start, min(start + length, horizon_ms)

        if mix in ("messages", "full"):
            for _ in range(rng.randint(1, 3)):
                at, until = window()
                sched.links.append(LinkChaos(
                    src=rng.choice(nodes + ["*"]), dst="*",
                    at=at, until=until,
                    drop_p=rng.uniform(0.0, 0.25) * scale,
                    dup_p=rng.uniform(0.0, 0.3) * scale,
                    delay_ms=rng.uniform(0.0, 3.0),
                    jitter_ms=rng.uniform(0.0, 4.0),
                    reorder_p=rng.uniform(0.0, 0.4),
                    reorder_ms=rng.uniform(1.0, 6.0)))
            # Storage-facing chaos: lost requests/acks on the op path.
            at, until = window()
            sched.links.append(LinkChaos(
                src="*", dst=STORAGE, at=at, until=until,
                drop_p=rng.uniform(0.0, 0.15) * scale,
                delay_ms=rng.uniform(0.0, 2.0)))
        if mix in ("partition", "full"):
            for _ in range(rng.randint(1, 2)):
                at, until = window(0.05, 0.35)
                k = rng.randint(1, max(1, len(nodes) // 2))
                side = tuple(rng.sample(nodes, k))
                rest = tuple(n for n in nodes if n not in side)
                sched.partitions.append(NetPartition(
                    at=at, heal_at=until, side_a=side, side_b=rest,
                    symmetric=rng.random() < 0.6))
        if mix in ("crash", "full"):
            for _ in range(rng.randint(1, 2)):
                at = rng.uniform(0.05, 0.7) * horizon_ms
                down = rng.uniform(0.05, 0.25) * horizon_ms
                sched.crashes.append(CrashRestart(
                    node=rng.choice(nodes), at=at,
                    restart_at=min(at + down, horizon_ms * 0.95)))
        if n_replicas > 1 and mix in ("torn", "full"):
            at, until = window()
            sched.torn.append(TornWrite(
                at=at, until=until, p=rng.uniform(0.05, 0.3) * scale,
                keep=rng.randint(1, max(1, n_replicas - 1))))
            at, until = window()
            sched.links.append(LinkChaos(
                src=STORAGE, dst=replica(rng.randrange(n_replicas)),
                at=at, until=until,
                drop_p=rng.uniform(0.0, 0.3) * scale,
                delay_ms=rng.uniform(0.0, 2.0)))
        if n_replicas > 1 and mix in ("skew", "full"):
            at, until = window()
            sched.skews.append(ClockSkew(
                at=at, until=until,
                skew_ms=rng.choice([-1.0, 1.0]) * rng.uniform(50.0, 400.0)))
        if mix == "rot":
            # Durable-state decay.  ALL rng draws for this family happen
            # only inside this branch: pre-existing mixes' schedules stay
            # bit-identical.
            for _ in range(rng.randint(1, 3)):
                sched.bitflips.append(BitFlip(
                    at=rng.uniform(0.1, 0.8) * horizon_ms,
                    count=rng.randint(1, 2)))
            at, until = window(0.1, 0.5)
            sched.torn_tails.append(TornTail(
                at=at, until=until, p=rng.uniform(0.1, 0.35)))
            # Torn tails ride the lose-response path: arm a storage-link
            # loss window overlapping the torn window so responses are
            # actually lost there.
            sched.links.append(LinkChaos(
                src="*", dst=STORAGE, at=at, until=until,
                drop_p=rng.uniform(0.05, 0.2),
                delay_ms=rng.uniform(0.0, 1.0)))
            sched.truncations.append(Truncation(
                at=rng.uniform(0.05, 0.2) * horizon_ms,
                every_ms=rng.uniform(20.0, 45.0),
                until=horizon_ms))
            at = rng.uniform(0.2, 0.7) * horizon_ms
            down = rng.uniform(0.05, 0.2) * horizon_ms
            sched.crashes.append(CrashRestart(
                node=rng.choice(nodes), at=at,
                restart_at=min(at + down, horizon_ms * 0.95)))
        return sched


# ---------------------------------------------------------------------------
# Nemesis: the runtime that answers the chaos hooks
# ---------------------------------------------------------------------------
class Nemesis:
    """Injects one ``FaultSchedule`` into a live sim.

    All randomness comes from a dedicated rng derived from the schedule
    seed, never from the transport's or storage's shared streams; every
    hook is behind a ``chaos is None`` check at the call site, so an
    unattached run schedules no events and consumes no rng — bit-identical
    to a build without this module.
    """

    def __init__(self, schedule: FaultSchedule, sim, seed: Optional[int] = None):
        self.schedule = schedule
        self.sim = sim
        self.rng = random.Random((schedule.seed if seed is None else seed)
                                 ^ 0x2EBE15)
        # Fault-attribution counters (harvested into BenchResult).
        self.msgs_dropped = 0
        self.msgs_duplicated = 0
        self.msgs_delayed = 0
        self.msgs_reordered = 0
        self.partitions_healed = 0
        self.torn_writes = 0
        self.bit_flips = 0
        self.torn_tails = 0
        self.gc_pulses = 0

    # -- wiring -------------------------------------------------------------
    def attach(self, transport=None, storage=None, cluster=None) -> "Nemesis":
        """Point the chaos hooks of a transport / simulated storage at this
        nemesis, schedule partition-heal accounting, and arm the schedule's
        crash–restarts on the cluster."""
        if transport is not None:
            transport.chaos = self
        if storage is not None:
            inner = getattr(storage, "inner", storage)
            inner.chaos = self
        for p in self.schedule.partitions:
            self.sim._schedule(p.heal_at, self._healed)
        if cluster is not None:
            for c in self.schedule.crashes:
                cluster.schedule_crash_restart(c.node, c.at, c.restart_at)
        if storage is not None:
            inner = getattr(storage, "inner", storage)
            # Durable-state faults target the lifecycle hooks; a store
            # without them (lifecycle off / threaded) simply ignores them.
            if self.schedule.bitflips and hasattr(inner, "bitflip"):
                for bf in self.schedule.bitflips:
                    self.sim._schedule(
                        bf.at, lambda bf=bf: self._flip(inner, bf.count))
            if self.schedule.truncations and hasattr(inner, "gc_pass"):
                for tr in self.schedule.truncations:
                    self.sim._schedule(
                        tr.at, lambda tr=tr: self._gc_pulse(inner, tr))
        return self

    def _flip(self, storage, count: int) -> None:
        for _ in range(count):
            if storage.bitflip(self.rng):
                self.bit_flips += 1

    def _gc_pulse(self, storage, tr: Truncation) -> None:
        self.gc_pulses += 1
        storage.gc_pass(self.sim.now)
        nxt = self.sim.now + tr.every_ms
        if tr.every_ms > 0.0 and nxt < tr.until:
            self.sim._schedule(nxt, lambda: self._gc_pulse(storage, tr))

    def _healed(self) -> None:
        self.partitions_healed += 1

    # -- link chaos (Transport.send / deliver / deliver_many) ---------------
    def _cut(self, src: str, dst: str, t: float) -> bool:
        return any(p.active(t) and p.cuts(src, dst)
                   for p in self.schedule.partitions)

    def message_plan(self, src: str, dst: str) -> Optional[List[float]]:
        """Fate of one src→dst message NOW: ``None`` = dropped, else the
        list of extra-delay offsets to deliver copies at (``[0.0]`` is an
        undisturbed message; two entries = a duplicate)."""
        t = self.sim.now
        if self._cut(src, dst, t):
            self.msgs_dropped += 1
            return None
        delays = [0.0]
        for lc in self.schedule.links:
            if not (lc.active(t) and lc.matches(src, dst)):
                continue
            if lc.drop_p and self.rng.random() < lc.drop_p:
                self.msgs_dropped += 1
                return None
            extra = lc.delay_ms
            if lc.jitter_ms:
                extra += self.rng.random() * lc.jitter_ms
            if lc.reorder_p and self.rng.random() < lc.reorder_p:
                extra += self.rng.random() * lc.reorder_ms
                self.msgs_reordered += 1
            if extra > 0.0:
                self.msgs_delayed += 1
                delays = [d + extra for d in delays]
            if lc.dup_p and self.rng.random() < lc.dup_p:
                self.msgs_duplicated += 1
                delays.append(delays[0]
                              + self.rng.random() * max(lc.jitter_ms, 1.0))
        return delays

    # -- storage chaos (SimStorage._op / ReplicatedSimStorage._scatter) -----
    def storage_op_fate(self, lane: Optional[str]) -> Tuple[str, float]:
        """("ok"|"lose-request"|"lose-response", extra_delay_ms) for one
        single-store op on ``lane``'s compute↔storage link.  A lost request
        never applies; a lost response applies but never answers — the case
        only idempotent retry (LogOnce) recovers from."""
        plan = self.message_plan(lane or "*", STORAGE)
        if plan is None:
            return (("lose-request" if self.rng.random() < 0.5
                     else "lose-response"), 0.0)
        return ("ok", plan[0])

    def replica_leg(self, i: int) -> Optional[float]:
        """Fate of one front-end↔replica-``i`` leg: ``None`` = lost, else
        extra delay in ms."""
        plan = self.message_plan(STORAGE, replica(i))
        return None if plan is None else plan[0]

    def torn_targets(self, targets: List[int]) -> List[int]:
        """Maybe tear one scatter: only a prefix of the replica targets
        receives the write (the proposer believes it reached everyone)."""
        t = self.sim.now
        for tw in self.schedule.torn:
            if tw.active(t) and self.rng.random() < tw.p:
                self.torn_writes += 1
                return targets[:max(1, min(tw.keep, len(targets)))]
        return targets

    def torn_tail(self) -> bool:
        """Should the current lost-response single-store write ALSO leave a
        torn durable frame?  Consulted by ``SimStorage._op`` only on the
        lose-response path of a non-decision write, so the torn record was
        by construction never acknowledged."""
        t = self.sim.now
        for tt in self.schedule.torn_tails:
            if tt.active(t) and self.rng.random() < tt.p:
                self.torn_tails += 1
                return True
        return False

    def skew_ms(self) -> float:
        """Clock skew the storage service applies to lease deadlines NOW."""
        t = self.sim.now
        return sum(s.skew_ms for s in self.schedule.skews if s.active(t))


# ---------------------------------------------------------------------------
# Compute-side graceful degradation: retry policy + circuit breaker
# ---------------------------------------------------------------------------
@dataclass
class RetryPolicy:
    """Jittered exponential backoff between storage-op re-issues."""

    base_ms: float = 4.0
    factor: float = 2.0
    max_ms: float = 64.0

    def backoff_ms(self, attempt: int, rng: random.Random) -> float:
        raw = min(self.base_ms * (self.factor ** max(0, attempt - 1)),
                  self.max_ms)
        return raw * (0.5 + rng.random())


class CircuitBreaker:
    """Per-partition three-state breaker over storage-op outcomes.

    CLOSED: ops flow.  ``threshold`` consecutive failures trip it OPEN for
    ``cooldown_ms`` (admission waits instead of hammering the partition).
    After the cooldown it HALF-OPENs: one probe op is admitted; success
    closes the breaker, failure re-trips it.  Counters (``trips``,
    ``half_opens``) surface the degradation.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"

    def __init__(self, threshold: int = 3, cooldown_ms: float = 40.0):
        self.threshold = threshold
        self.cooldown_ms = cooldown_ms
        self._state: Dict[str, str] = {}
        self._fails: Dict[str, int] = {}
        self._opened_at: Dict[str, float] = {}
        self.trips = 0
        self.half_opens = 0

    def state(self, p: str) -> str:
        return self._state.get(p, self.CLOSED)

    def admission_delay_ms(self, p: str, now: float) -> float:
        """0 = admit now (CLOSED, or HALF-OPEN probe slot); >0 = wait this
        long before asking again (breaker OPEN)."""
        st = self.state(p)
        if st == self.OPEN:
            remaining = self._opened_at[p] + self.cooldown_ms - now
            if remaining > 1e-9:
                return remaining
            self._state[p] = self.HALF_OPEN
            self.half_opens += 1
        return 0.0

    def note_success(self, p: str) -> None:
        self._fails[p] = 0
        self._state[p] = self.CLOSED

    def note_failure(self, p: str, now: float) -> None:
        if self.state(p) == self.HALF_OPEN:      # failed probe: re-trip
            self._trip(p, now)
            return
        self._fails[p] = self._fails.get(p, 0) + 1
        if self._fails[p] >= self.threshold and self.state(p) == self.CLOSED:
            self._trip(p, now)

    def _trip(self, p: str, now: float) -> None:
        self._state[p] = self.OPEN
        self._opened_at[p] = now
        self._fails[p] = 0
        self.trips += 1


class GuardedStorage:
    """Sim-storage decorator: per-attempt deadlines, idempotent re-issue
    under ``RetryPolicy``, per-partition ``CircuitBreaker`` admission.

    A chaos-dropped storage request (or dropped response) leaves the op's
    Event forever untriggered; the guard re-issues after the deadline —
    safe because LogOnce is idempotent by definition (first write wins,
    re-issues read the winner), ``log`` re-writes the same record, and
    reads are pure.  The breaker turns a persistently unreachable
    partition into bounded, jittered waiting instead of a retry storm.
    Everything delegates, so the guard is a drop-in for any sim store.
    """

    def __init__(self, inner, sim, seed: int = 0,
                 deadline_ms: float = 50.0,
                 retry: Optional[RetryPolicy] = None,
                 breaker: Optional[CircuitBreaker] = None):
        self.inner = inner
        self.sim = sim
        self.rng = random.Random(seed ^ 0x6A4D)
        self.deadline_ms = deadline_ms
        self.retry = retry or RetryPolicy()
        self.breaker = breaker or CircuitBreaker()
        self.retries = 0

    def __getattr__(self, name):
        return getattr(self.inner, name)

    # -- wrapped ops --------------------------------------------------------
    def log_once(self, partition, txn, state, writer="", **kw):
        return self._guard(partition, lambda: self.inner.log_once(
            partition, txn, state, writer, **kw))

    def log(self, partition, txn, state, writer=""):
        return self._guard(partition, lambda: self.inner.log(
            partition, txn, state, writer))

    def read_state(self, partition, txn, writer=""):
        return self._guard(partition, lambda: self.inner.read_state(
            partition, txn, writer))

    def log_batch(self, partition, txn, state, n_records, writer=""):
        return self._guard(partition, lambda: self.inner.log_batch(
            partition, txn, state, n_records, writer))

    def _guard(self, partition: str, issue):
        done = self.sim.event()
        attempt = {"n": 0}

        def admit():
            if done.triggered:
                return
            wait = self.breaker.admission_delay_ms(partition, self.sim.now)
            if wait > 0.0:
                self.sim._schedule(
                    self.sim.now + wait * (1.0 + 0.25 * self.rng.random()),
                    admit)
                return
            fire()

        def fire():
            attempt["n"] += 1
            ev = issue()
            race = self.sim.any_of([ev, self.sim.timeout(self.deadline_ms)])

            def on(e):
                if done.triggered:
                    return
                idx, val = e.value
                if idx == 0:
                    self.breaker.note_success(partition)
                    done.trigger(val)
                    return
                self.breaker.note_failure(partition, self.sim.now)
                self.retries += 1
                backoff = self.retry.backoff_ms(attempt["n"], self.rng)
                self.sim._schedule(self.sim.now + backoff, admit)

            race.subscribe(on)

        admit()
        return done


# ---------------------------------------------------------------------------
# Threaded-store chaos decorator (delay/drop against real stores)
# ---------------------------------------------------------------------------
class ChaosStore:
    """Wraps a threaded store (``MemoryStore`` / ``FileStore`` /
    ``ReplicatedStore``): each op pays an injected delay and, with
    ``drop_p``, a lost-request that the built-in retry re-issues after a
    jittered exponential backoff (idempotent, like the sim guard).  The
    wall-clock analogue of the Nemesis message plan."""

    def __init__(self, inner, seed: int = 0, drop_p: float = 0.0,
                 delay_ms: float = 0.0, jitter_ms: float = 0.0,
                 max_retries: int = 8,
                 retry: Optional[RetryPolicy] = None):
        self.inner = inner
        self.drop_p = drop_p
        self.delay_ms = delay_ms
        self.jitter_ms = jitter_ms
        self.max_retries = max_retries
        self.retry = retry or RetryPolicy(base_ms=1.0, max_ms=16.0)
        self._rng = random.Random(seed ^ 0x7D20)
        self._lock = threading.Lock()
        self.ops_delayed = 0
        self.ops_dropped = 0
        self.retries = 0

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def _draw(self) -> Tuple[float, float, float]:
        with self._lock:
            return (self._rng.random(), self._rng.random(),
                    self._rng.random())

    def _chaos_call(self, fn):
        attempt = 0
        while True:
            r_drop, r_jit, r_back = self._draw()
            delay = self.delay_ms + r_jit * self.jitter_ms
            if delay > 0.0:
                with self._lock:
                    self.ops_delayed += 1
                time.sleep(delay / 1e3)
            if r_drop < self.drop_p and attempt < self.max_retries:
                attempt += 1
                with self._lock:
                    self.ops_dropped += 1
                    self.retries += 1
                raw = min(self.retry.base_ms
                          * (self.retry.factor ** (attempt - 1)),
                          self.retry.max_ms)
                time.sleep(raw * (0.5 + r_back) / 1e3)
                continue
            return fn()

    def log_once(self, partition, txn, state, writer="", **kw):
        return self._chaos_call(lambda: self.inner.log_once(
            partition, txn, state, writer, **kw))

    def log(self, partition, txn, state, writer=""):
        return self._chaos_call(lambda: self.inner.log(
            partition, txn, state, writer))

    def read_state(self, partition, txn):
        return self._chaos_call(lambda: self.inner.read_state(partition, txn))


# ---------------------------------------------------------------------------
# Failure-repro bundles
# ---------------------------------------------------------------------------
def write_repro_bundle(schedule: FaultSchedule, run_config: dict,
                       violations: Sequence[str], out_dir: Optional[str] = None,
                       name: Optional[str] = None) -> str:
    """Serialize a failing chaos run (exact schedule + bench knobs +
    checker output) to JSON; returns the path.  ``benchmarks.chaos
    --replay <path>`` re-runs it bit-for-bit.  Directory from ``out_dir``,
    the ``CHAOS_REPRO_DIR`` env var, or ``./chaos-failures``."""
    out_dir = out_dir or os.environ.get("CHAOS_REPRO_DIR", "chaos-failures")
    os.makedirs(out_dir, exist_ok=True)
    name = name or f"chaos-seed{schedule.seed}-" \
                   f"{run_config.get('protocol', 'unknown')}.json"
    path = os.path.join(out_dir, name)
    payload = {"schema": 1,
               "schedule": schedule.to_dict(),
               "config": dict(run_config),
               "violations": list(violations)}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_repro_bundle(path: str) -> Tuple[FaultSchedule, dict]:
    with open(path) as f:
        payload = json.load(f)
    return (FaultSchedule.from_dict(payload["schedule"]),
            dict(payload["config"]))
