"""Cornus and conventional 2PC, faithful to the paper's Algorithm 1.

Both protocols run as processes on the discrete-event kernel (`core.sim`)
against a `SimStorage` (CAS-at-apply-time semantics).  Grey-highlighted lines
of Algorithm 1 are marked ``# [Alg1 L<n>]`` so the implementation can be
audited against the paper.

Key behavioural differences implemented:
  * Cornus coordinator never logs a decision; it replies to the caller the
    moment the collective vote is known           (latency win, Fig 5–7).
  * Cornus timeout paths go to the storage-based termination protocol that
    CAS-forces ABORT into unresponsive participants' logs (non-blocking,
    Fig 8); 2PC uses the cooperative termination protocol and *blocks* when
    the coordinator is down and no peer knows the decision.
  * Presumed abort: ABORT logging is async and off the critical path.
  * Read-only optimizations per §3.6 / §5.1.4.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from .sim import Sim
from .state import Decision, TxnOutcome, TxnSpec, Vote
from .storage import COMPUTE_RTT_MS, RegionTopology, SimStorage


@dataclass
class ProtocolConfig:
    protocol: str = "cornus"            # "cornus" | "2pc"
    rtt_ms: float = COMPUTE_RTT_MS      # compute <-> compute round trip
    vote_timeout_ms: float = 25.0       # coordinator waiting for votes
    decision_timeout_ms: float = 25.0   # participant waiting for decision
    votereq_timeout_ms: float = 25.0    # participant waiting for VOTE-REQ
    termination_retry_ms: float = 25.0  # retry period for termination protocol
    # 2PC cooperative termination polls peers with this period while blocked.
    coop_retry_ms: float = 25.0
    # Early Lock Release / speculative precommit (§5.6): locks drop at
    # precommit instead of at decision. Consumed by the txn executor via the
    # on_precommit hook.
    elr: bool = False
    # Geo-distributed deployments (extended §6): per-link RTTs come from a
    # RegionTopology + node→region placement instead of the scalar rtt_ms.
    topology: Optional[RegionTopology] = None
    placement: Dict[str, str] = field(default_factory=dict)

    def link_rtt_ms(self, src: str, dst: str) -> float:
        """Round trip between two compute nodes under the active model."""
        if self.topology is None:
            return self.rtt_ms
        default = self.topology.regions[0]
        return self.topology.rtt_ms(self.placement.get(src, default),
                                    self.placement.get(dst, default))


class Cluster:
    """N compute nodes + one disaggregated storage service, inside one Sim.

    Each node owns one data partition named after itself (paper §5.1.1:
    "each compute node runs a resource manager and has exclusive access to
    one partition").
    """

    def __init__(self, sim: Sim, storage: SimStorage, nodes: List[str],
                 cfg: ProtocolConfig):
        self.sim = sim
        self.storage = storage
        self.nodes = list(nodes)
        self.cfg = cfg
        self.fail_at: Dict[str, float] = {n: float("inf") for n in nodes}
        self.recover_at: Dict[str, float] = {n: float("inf") for n in nodes}
        self._slots: Dict[Tuple[str, str, str], "object"] = {}
        # (node, txn) -> {"status": none|voted|decided, "decision": Decision}
        self.local: Dict[Tuple[str, str], Dict] = {}
        self.outcomes: Dict[Tuple[str, str], TxnOutcome] = {}
        # Hooks for the transaction executor (lock release timing, ELR).
        self.on_precommit: Optional[Callable[[str, str, float], None]] = None
        self.on_finish: Optional[Callable[[str, str, Decision, float], None]] = None
        self.blocked: Dict[Tuple[str, str], bool] = {}

    # -- liveness -----------------------------------------------------------
    def alive(self, node: str) -> bool:
        t = self.sim.now
        return t < self.fail_at[node] or t >= self.recover_at[node]

    def fail(self, node: str, at: float, recover_at: float = float("inf")):
        self.fail_at[node] = at
        self.recover_at[node] = recover_at

    # -- messaging ----------------------------------------------------------
    def _slot(self, dst: str, txn: str, kind: str):
        key = (dst, txn, kind)
        ev = self._slots.get(key)
        if ev is None:
            ev = self.sim.event()
            self._slots[key] = ev
        return ev

    def send(self, src: str, dst: str, txn: str, kind: str, value=None):
        """One-way message; delivered after rtt/2 if both ends are alive."""
        if not self.alive(src):
            return
        delay = 0.0 if src == dst else self.cfg.link_rtt_ms(src, dst) / 2.0
        slot = self._slot(dst, txn, kind)

        def deliver():
            if self.alive(dst):
                slot.trigger(value)

        self.sim._schedule(self.sim.now + delay, deliver)

    def wait(self, dst: str, txn: str, kind: str, timeout_ms: float):
        """Event yielding ('msg', value) or ('timeout', None)."""
        slot = self._slot(dst, txn, kind)
        to = self.sim.timeout(timeout_ms)
        any_ev = self.sim.any_of([slot, to])
        done = self.sim.event()

        def on(ev):
            idx, val = ev.value
            done.trigger(("msg", val) if idx == 0 else ("timeout", None))

        any_ev.subscribe(on)
        return done

    # -- local bookkeeping ---------------------------------------------------
    def _local(self, node: str, txn: str) -> Dict:
        return self.local.setdefault((node, txn), {"status": "none",
                                                   "decision": None})

    def _decide(self, node: str, txn: str, decision: Decision):
        st = self._local(node, txn)
        if st["decision"] is None:
            st["status"], st["decision"] = "decided", decision
            if self.on_finish:
                self.on_finish(node, txn, decision, self.sim.now)

    def _record(self, out: TxnOutcome):
        self.outcomes[(out.txn_id, out.node)] = out

    # ========================================================================
    # Transaction entry point
    # ========================================================================
    def run_txn(self, spec: TxnSpec):
        """Spawn coordinator + participant processes for one transaction.

        Returns the coordinator's done-Event (value: TxnOutcome).
        """
        for p in spec.participants:
            if p != spec.coordinator:
                self.sim.process(self._participant(spec, p))
        return self.sim.process(self._coordinator(spec))

    # ========================================================================
    # Coordinator
    # ========================================================================
    def _coordinator(self, spec: TxnSpec):
        cfg, sim, me = self.cfg, self.sim, spec.coordinator
        txn = spec.txn_id
        t0 = sim.now
        out = TxnOutcome(txn_id=txn, node=me, decision=Decision.UNDETERMINED)

        # §3.6 / §5.1.4: fully read-only txn known upfront — skip both phases
        # in BOTH protocols (locks released immediately by executor hook).
        if spec.all_read_only and spec.read_only_known_upfront:
            out.decision = Decision.COMMIT
            out.caller_latency_ms = sim.now - t0
            out.done_at_ms = sim.now
            self._decide(me, txn, Decision.COMMIT)
            for p in spec.participants:
                self.send(me, p, txn, "decision", Decision.COMMIT)
            self._record(out)
            return out

    # ---- phase 1: vote requests -------------------------------------------
        if not self.alive(me):
            return out
        for p in spec.participants:                      # [Alg1 L2-3]
            if p != me:
                self.send(me, p, txn, "vote-req",
                          {"participants": list(spec.participants)})
        # The coordinator's own partition (if participating) votes locally.
        my_vote_ev = None
        if me in spec.participants:
            my_vote_ev = self.sim.process(
                self._participant_vote_local(spec, me))

        # Collect votes.                                  [Alg1 L4-7]
        pending = [p for p in spec.participants if p != me]
        waits = [self.wait(me, txn, f"vote:{p}", cfg.vote_timeout_ms)
                 for p in pending]
        if my_vote_ev is not None:
            waits.append(self._wrap_local_vote(my_vote_ev, cfg.vote_timeout_ms))
        results = yield self.sim.all_of(waits)
        if not self.alive(me):
            return out
        prepare_done = sim.now
        out.prepare_ms = prepare_done - t0

        timed_out = any(tag == "timeout" for tag, _ in results)
        any_abort = any(tag == "msg" and val == "ABORT" for tag, val in results)

        if any_abort:                                     # [Alg1 L5]
            decision = Decision.ABORT
        elif not timed_out:                               # [Alg1 L6]
            decision = Decision.COMMIT
        else:                                             # [Alg1 L7]
            if cfg.protocol == "cornus":
                decision = yield from self._termination(spec, me, out)
            else:
                # Conventional 2PC: unilateral abort on vote timeout.
                decision = Decision.ABORT
        if not self.alive(me):
            return out

        # ---- decision point -------------------------------------------------
        if cfg.protocol == "2pc":
            if decision == Decision.COMMIT:
                # 2PC: the commit record IS the ground truth — it must be
                # durable before replying to the caller (eager decision log).
                yield self.storage.log(me, txn, Vote.COMMIT, writer=me)
            else:
                # Presumed abort: the abort record need not be forced.
                self.storage.log(me, txn, Vote.ABORT, writer=me)
            if not self.alive(me):
                return out
        # Cornus: no decision log — reply immediately.     [Alg1 L8]
        out.decision = decision
        out.caller_latency_ms = sim.now - t0
        out.commit_ms = sim.now - prepare_done
        self._decide(me, txn, decision)

        for p in spec.participants:                       # [Alg1 L9-10]
            if p != me:
                self.send(me, p, txn, "decision", decision)
        if me in spec.participants and cfg.protocol == "cornus":
            # Coordinator-as-participant logs the decision asynchronously.
            self.storage.log(me, txn,
                             Vote.COMMIT if decision == Decision.COMMIT
                             else Vote.ABORT, writer=me)
        out.done_at_ms = sim.now
        self._record(out)
        return out

    def _wrap_local_vote(self, proc, timeout_ms: float):
        """Adapt a local-vote process result to the ('msg', vote) shape."""
        to = self.sim.timeout(timeout_ms)
        any_ev = self.sim.any_of([proc, to])
        done = self.sim.event()

        def on(ev):
            idx, val = ev.value
            done.trigger(("msg", val) if idx == 0 else ("timeout", None))

        any_ev.subscribe(on)
        return done

    def _participant_vote_local(self, spec: TxnSpec, me: str):
        """Coordinator's own partition voting (no network hop)."""
        txn = spec.txn_id
        st = self._local(me, txn)
        if me in spec.read_only and spec.read_only_known_upfront:
            st["status"] = "voted"
            return "VOTE-YES"
        if not spec.vote_of(me):
            self.storage.log(me, txn, Vote.ABORT, writer=me)  # async
            self._decide(me, txn, Decision.ABORT)
            return "ABORT"
        if self.cfg.protocol == "cornus":
            resp = yield self.storage.log_once(me, txn, Vote.VOTE_YES, writer=me)
            if resp == Vote.ABORT:
                self._decide(me, txn, Decision.ABORT)
                return "ABORT"
        else:
            yield self.storage.log(me, txn, Vote.VOTE_YES, writer=me)
        st["status"] = "voted"
        if self.on_precommit and self.cfg.elr:
            self.on_precommit(me, txn, self.sim.now)
        return "VOTE-YES"

    # ========================================================================
    # Participant                                          [Alg1 L11-25]
    # ========================================================================
    def _participant(self, spec: TxnSpec, me: str):
        cfg, sim = self.cfg, self.sim
        txn = spec.txn_id
        if me == spec.coordinator:
            return  # voted via _participant_vote_local
        t0 = sim.now
        out = TxnOutcome(txn_id=txn, node=me, decision=Decision.UNDETERMINED)
        st = self._local(me, txn)

        if spec.all_read_only and spec.read_only_known_upfront:
            tag, val = yield self.wait(me, txn, "decision", cfg.votereq_timeout_ms)
            self._decide(me, txn, Decision.COMMIT)
            out.decision = Decision.COMMIT
            out.done_at_ms = sim.now
            self._record(out)
            return out

        tag, msg = yield self.wait(me, txn, "vote-req",    # [Alg1 L12]
                                   cfg.votereq_timeout_ms)
        if not self.alive(me):
            return out
        if tag == "timeout":                               # [Alg1 L13]
            yield self.storage.log(me, txn, Vote.ABORT, writer=me)
            self._decide(me, txn, Decision.ABORT)
            out.decision = Decision.ABORT
            out.done_at_ms = sim.now
            self._record(out)
            return out

        votes_yes = spec.vote_of(me)
        read_only = me in spec.read_only

        if votes_yes:                                      # [Alg1 L14]
            if read_only and spec.read_only_known_upfront:
                # Known-upfront read-only participant: skip prepare logging,
                # release locks, reply YES (§3.6 simple case, both protocols).
                st["status"] = "voted"
                self.send(me, spec.coordinator, txn, f"vote:{me}", "VOTE-YES")
                self._decide(me, txn, Decision.COMMIT)
                out.decision = Decision.COMMIT
                out.done_at_ms = sim.now
                self._record(out)
                return out

            if read_only and cfg.protocol == "2pc":
                # §3.6 second case, 2PC side: a read-only participant
                # discovered at prepare time skips logging entirely and can
                # release locks after replying.  (Cornus must NOT take this
                # path: a missing VOTE-YES in its log reads as abortable by
                # the termination protocol — it falls through to LogOnce.)
                st["status"] = "voted"
                self.send(me, spec.coordinator, txn, f"vote:{me}", "VOTE-YES")
                tag, decision = yield self.wait(me, txn, "decision",
                                                cfg.decision_timeout_ms)
                d = decision if tag == "msg" else Decision.ABORT
                self._decide(me, txn, d)
                out.decision = d
                out.done_at_ms = sim.now
                self._record(out)
                return out

            if cfg.protocol == "cornus":
                # LogOnce(VOTE-YES)                        [Alg1 L15]
                resp = yield self.storage.log_once(me, txn, Vote.VOTE_YES,
                                                   writer=me)
                if not self.alive(me):
                    return out
                if resp == Vote.ABORT:                     # [Alg1 L16-17]
                    # A peer already aborted on our behalf via termination.
                    self.send(me, spec.coordinator, txn, f"vote:{me}", "ABORT")
                    self._decide(me, txn, Decision.ABORT)
                    out.decision = Decision.ABORT
                    out.done_at_ms = sim.now
                    self._record(out)
                    return out
            else:
                # 2PC prepare: plain forced log write.
                yield self.storage.log(me, txn, Vote.VOTE_YES, writer=me)
                if not self.alive(me):
                    return out

            st["status"] = "voted"
            out.prepare_ms = sim.now - t0
            if self.on_precommit and cfg.elr:
                self.on_precommit(me, txn, sim.now)
            self.send(me, spec.coordinator, txn, f"vote:{me}", "VOTE-YES")
            # Wait for the decision.                       [Alg1 L20-21]
            tag, decision = yield self.wait(me, txn, "decision",
                                            cfg.decision_timeout_ms)
            if not self.alive(me):
                return out
            if tag == "timeout":
                out.ran_termination = True
                tstart = sim.now
                if cfg.protocol == "cornus":
                    decision = yield from self._termination(spec, me, out)
                else:
                    decision = yield from self._coop_termination(spec, me, out)
                out.termination_ms = sim.now - tstart
            if decision is None:
                # 2PC blocked until sim horizon.
                out.decision = Decision.UNDETERMINED
                self._record(out)
                return out
            # Log the decision locally.                    [Alg1 L22]
            yield self.storage.log(me, txn,
                                   Vote.COMMIT if decision == Decision.COMMIT
                                   else Vote.ABORT, writer=me)
            self._decide(me, txn, decision)
            out.decision = decision
        else:
            # VOTE-NO: presumed abort — async log, reply.  [Alg1 L23-25]
            self.storage.log(me, txn, Vote.ABORT, writer=me)
            self.send(me, spec.coordinator, txn, f"vote:{me}", "ABORT")
            self._decide(me, txn, Decision.ABORT)
            out.decision = Decision.ABORT

        out.done_at_ms = sim.now
        self._record(out)
        return out

    # ========================================================================
    # Cornus termination protocol                          [Alg1 L26-34]
    # ========================================================================
    def _termination(self, spec: TxnSpec, me: str, out: TxnOutcome):
        cfg, sim = self.cfg, self.sim
        txn = spec.txn_id
        out.ran_termination = True
        while True:
            if not self.alive(me):
                return None
            targets = [p for p in spec.participants if p != me]
            # CAS ABORT into every other participant's log. [Alg1 L27-28]
            reqs = [self.storage.log_once(p, txn, Vote.ABORT, writer=me)
                    for p in targets]
            # Include own log state (me may have VOTE-YES there, or — if me
            # is a non-participant coordinator — nothing).
            if me in spec.participants:
                reqs.append(self.storage.log_once(me, txn, Vote.ABORT,
                                                  writer=me))
            to = self.sim.timeout(cfg.termination_retry_ms)
            got = yield self.sim.any_of([self.sim.all_of(reqs), to])
            idx, val = got
            if idx == 1:
                continue                                   # [Alg1 L33] retry
            states: List[Vote] = val
            if any(s == Vote.ABORT for s in states):       # [Alg1 L30]
                return Decision.ABORT
            if any(s == Vote.COMMIT for s in states):      # [Alg1 L31]
                return Decision.COMMIT
            # All responses are VOTE-YES.                  [Alg1 L32]
            return Decision.COMMIT

    # ========================================================================
    # 2PC cooperative termination (§2.1) — may block
    # ========================================================================
    def _coop_termination(self, spec: TxnSpec, me: str, out: TxnOutcome):
        cfg, sim = self.cfg, self.sim
        txn = spec.txn_id
        attempt = 0
        while True:
            if not self.alive(me):
                return None
            attempt += 1
            peers = [p for p in list(spec.participants) + [spec.coordinator]
                     if p != me]
            for p in peers:
                self.send(me, p, txn, f"dec-req:{me}:{attempt}", me)
                self._serve_decision_request(p, txn, me, attempt)
            waits = [self.wait(me, txn, f"dec-resp:{p}:{attempt}",
                               cfg.coop_retry_ms) for p in peers]
            results = yield self.sim.all_of(waits)
            for tag, val in results:
                if tag == "msg" and val in (Decision.COMMIT, Decision.ABORT):
                    return val
            # Nobody knows: blocked. Retry (models waiting for coordinator
            # recovery); give up only when the sim horizon ends us.
            self.blocked[(txn, me)] = True
            yield self.sim.timeout(cfg.coop_retry_ms)
            if sim.now > 1e7:
                return None

    def _serve_decision_request(self, server: str, txn: str, asker: str,
                                attempt: int):
        """Peer-side handler for cooperative termination (runs as a server
        thread, so it is modelled at delivery time rather than inside the
        peer's protocol process)."""
        delay = self.cfg.link_rtt_ms(asker, server) / 2.0

        def handle():
            if not self.alive(server):
                return
            st = self._local(server, txn)
            if st["decision"] is not None:
                resp = st["decision"]
            elif st["status"] == "none":
                # Never voted: unilaterally abort and answer ABORT.
                self.storage.log(server, txn, Vote.ABORT, writer=server)
                self._decide(server, txn, Decision.ABORT)
                resp = Decision.ABORT
            else:
                resp = "UNKNOWN"  # voted yes, uncertain — cannot help
            self.send(server, asker, txn, f"dec-resp:{server}:{attempt}", resp)

        self.sim._schedule(self.sim.now + delay, handle)

    # ========================================================================
    # Recovery (Table 1 / Table 2 "During Recovery" column)
    # ========================================================================
    def recover_txn(self, spec: TxnSpec, me: str):
        """Recovered node resolving one in-flight transaction."""

        def proc():
            txn = spec.txn_id
            state = yield self.storage.read_state(me, txn, writer=me)
            out = TxnOutcome(txn_id=txn, node=me,
                             decision=Decision.UNDETERMINED)
            if state in (Vote.COMMIT, Vote.ABORT):
                out.decision = Decision(state.value)
            elif state is None or state == Vote.VOTE_YES:
                if state is None and self.cfg.protocol == "2pc":
                    # 2PC recovery without a vote: presumed abort.
                    yield self.storage.log(me, txn, Vote.ABORT, writer=me)
                    out.decision = Decision.ABORT
                else:
                    if self.cfg.protocol == "cornus":
                        d = yield from self._termination(spec, me, out)
                    else:
                        d = yield from self._coop_termination(spec, me, out)
                    out.decision = d if d else Decision.UNDETERMINED
                    if d:
                        yield self.storage.log(
                            me, txn, Vote.COMMIT if d == Decision.COMMIT
                            else Vote.ABORT, writer=me)
            if out.decision != Decision.UNDETERMINED:
                self._decide(me, txn, out.decision)
            out.done_at_ms = self.sim.now
            self.outcomes[(txn, me + ":recovery")] = out
            return out

        return self.sim.process(proc())
