"""Cluster: thin facade over the pluggable commit-protocol API.

Historically this module WAS the protocol implementation — a 525-line class
fusing messaging, liveness, timeouts and the Cornus/2PC logic.  That now
lives in ``repro.core.protocols`` as three separable pieces:

  * ``Transport``      – send/wait/liveness/slots between compute nodes
  * ``TxnContext``     – per-txn bookkeeping, outcomes, executor hooks
  * ``CommitProtocol`` – the strategy interface (coordinator_round /
    participant_round / terminate / recover), selected by name from the
    protocol registry (``register`` / ``get_protocol``)

``Cluster`` wires the three together and keeps the original surface, so
existing call sites — tests, benchmarks, examples — work unchanged:

    cluster = Cluster(sim, storage, nodes, ProtocolConfig(protocol="cornus"))
    done = cluster.run_txn(spec)        # ... cluster.outcomes, .local, ...

New variants plug in without touching this file; see
``repro/core/protocols/cornus_opt1.py`` for a complete ~25-line example.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .sim import Sim
from .state import Decision, TxnSpec
from .protocols import (CommitProtocol, ProtocolConfig, Transport, TxnContext,
                        get_protocol)

__all__ = ["Cluster", "ProtocolConfig"]


class Cluster:
    """N compute nodes + one disaggregated storage service, inside one Sim.

    Each node owns one data partition named after itself (paper §5.1.1:
    "each compute node runs a resource manager and has exclusive access to
    one partition").  The commit protocol is resolved from the registry by
    ``cfg.protocol`` (or the explicit ``protocol=`` override).
    """

    def __init__(self, sim: Sim, storage, nodes: List[str],
                 cfg: ProtocolConfig, protocol: Optional[str] = None):
        self.sim = sim
        self.storage = storage
        self.nodes = list(nodes)
        self.cfg = cfg
        self.transport = Transport(sim, self.nodes, cfg)
        self.ctx = TxnContext(sim)
        cls = get_protocol(protocol or cfg.protocol)
        self.protocol: CommitProtocol = cls(self.transport, storage,
                                            self.ctx, cfg)
        # Crash–restart accounting (chaos plane): restarts performed, and
        # recover() runs the restarts triggered for in-doubt txns.
        self.crash_restarts = 0
        self.recoveries_run = 0
        # (node, t_crash_restart, t_done, slots_scanned) per durable
        # restart scan — the recovery-time bound the GC bench gates.
        self.recovery_spans: List[Tuple[str, float, float, int]] = []

    # -- liveness (delegated to the transport) ------------------------------
    @property
    def fail_at(self) -> Dict[str, float]:
        return self.transport.fail_at

    @property
    def recover_at(self) -> Dict[str, float]:
        return self.transport.recover_at

    def alive(self, node: str) -> bool:
        return self.transport.alive(node)

    def fail(self, node: str, at: float, recover_at: float = float("inf")):
        self.transport.fail(node, at, recover_at)

    # -- messaging ----------------------------------------------------------
    def send(self, src: str, dst: str, txn: str, kind: str, value=None):
        self.transport.send(src, dst, txn, kind, value)

    def wait(self, dst: str, txn: str, kind: str, timeout_ms: float):
        return self.transport.wait(dst, txn, kind, timeout_ms)

    # -- per-txn bookkeeping (delegated to the context) ---------------------
    @property
    def local(self) -> Dict[Tuple[str, str], Dict]:
        return self.ctx.local

    @property
    def outcomes(self) -> Dict[Tuple[str, str], "object"]:
        return self.ctx.outcomes

    @property
    def blocked(self) -> Dict[Tuple[str, str], bool]:
        return self.ctx.blocked

    @property
    def on_precommit(self):
        return self.ctx.on_precommit

    @on_precommit.setter
    def on_precommit(self, fn) -> None:
        self.ctx.on_precommit = fn

    @property
    def on_finish(self):
        return self.ctx.on_finish

    @on_finish.setter
    def on_finish(self, fn) -> None:
        self.ctx.on_finish = fn

    # -- protocol entry points ----------------------------------------------
    def run_txn(self, spec: TxnSpec):
        """Spawn coordinator + participant processes for one transaction.

        Returns the coordinator's done-Event (value: TxnOutcome).
        """
        self.ctx.specs[spec.txn_id] = spec
        for p in spec.participants:
            if p != spec.coordinator:
                self.sim.process(self.protocol.participant_round(spec, p))
        return self.sim.process(self.protocol.coordinator_round(spec))

    def recover_txn(self, spec: TxnSpec, me: str):
        """Recovered node resolving one in-flight transaction (Table 1/2
        "During Recovery"); outcome recorded under (txn, me + ":recovery")."""
        return self.sim.process(self.protocol.recover(spec, me))

    # -- crash–restart (chaos plane) ----------------------------------------
    def schedule_crash_restart(self, node: str, at: float,
                               restart_at: float) -> None:
        """Crash ``node`` at ``at`` and bring it BACK at ``restart_at`` with
        its durable log intact: in-flight protocol rounds die via the
        existing ``alive()`` checks, and on restart the node scans every
        txn it participated in and runs the registered protocol's
        ``recover()`` (Table 1/2 in-doubt resolution) for each one still
        unresolved — against whatever live traffic is running."""
        self.fail(node, at, restart_at)
        self.sim._schedule(restart_at, lambda: self._restart(node))

    def _restart(self, node: str) -> None:
        self.crash_restarts += 1
        # New incarnation: protocol rounds started before the crash detect
        # the bump (CommitProtocol.live) and stop acting — the real process
        # they modelled died with the crash.
        tr = self.transport
        tr.incarnations[node] = tr.incarnation(node) + 1
        if getattr(self.storage, "lifecycle", None) is not None:
            # Lifecycle armed: recovery is bounded by the durable log, not
            # the full in-memory spec table — scan only the node's retained
            # (post-watermark) slots.  This is what makes recovery time
            # flat in history length once GC runs.
            self.sim.process(self._durable_restart(node))
            return
        for txn_id, spec in list(self.ctx.specs.items()):
            if node not in spec.participants and node != spec.coordinator:
                continue
            st = self.ctx.local.get((node, txn_id))
            if st is not None and st.get("decision") is not None:
                continue                       # decided before the crash
            prev = self.ctx.outcomes.get((txn_id, node + ":recovery"))
            if prev is not None and prev.decision != Decision.UNDETERMINED:
                continue                       # already resolved by recovery
            self.recoveries_run += 1
            self.sim.process(self.protocol.recover(spec, node))

    def _durable_restart(self, node: str):
        """Generator process: probe the node's retained durable slots (its
        own partition's post-watermark suffix), then run ``recover()`` for
        the ones still unresolved.  Probe reads go out in parallel batches
        so the scan's wall time reflects storage round trips, not
        serialized latency; truncated slots never appear (the watermark
        already settled them), which is the entire recovery bound.

        A node with no durable record of a txn (e.g. a CL participant —
        ``participant_logs=False``) has nothing in doubt: presumed abort
        covers it exactly as a real restart from an empty log would.
        """
        t0 = self.sim.now
        keys = list(self.storage.partition_log(node))
        scanned = 0
        in_doubt: List[str] = []
        B = 32
        for lo in range(0, len(keys), B):
            chunk = keys[lo:lo + B]
            evs = [self.storage.read_state(p, t, writer=node)
                   for (p, t) in chunk]
            for (p, txn_id), ev in zip(chunk, evs):
                st = yield ev
                scanned += 1
                if st is not None and getattr(st, "is_decision",
                                              lambda: False)():
                    continue                   # settled on disk
                in_doubt.append(txn_id)
        for txn_id in in_doubt:
            spec = self.ctx.specs.get(txn_id)
            if spec is None:
                continue
            st = self.ctx.local.get((node, txn_id))
            if st is not None and st.get("decision") is not None:
                continue                       # decided before the crash
            prev = self.ctx.outcomes.get((txn_id, node + ":recovery"))
            if prev is not None and prev.decision != Decision.UNDETERMINED:
                continue                       # already resolved by recovery
            self.recoveries_run += 1
            yield self.sim.process(self.protocol.recover(spec, node))
        self.recovery_spans.append((node, t0, self.sim.now, scanned))
