"""Disaggregated storage layer: Log() / LogOnce() over pluggable stores.

The paper's only storage-layer requirement is *log-once* semantics built on a
compare-and-swap primitive (§3.2, §4).  Three stores implement it here:

  * ``MemoryStore``  – lock-protected dict; used by the discrete-event sim and
    by threaded integration tests (stands in for Azure Redis / Blob).
  * ``FileStore``    – directory-backed; ``open(O_CREAT|O_EXCL)`` is the CAS
    (create-if-absent ≙ Azure Blob "If-None-Match:*" conditional PUT).  Used
    by the training framework's Cornus checkpoint commit.
  * ``LatencyModel`` – deterministic latency sampler with the paper's measured
    service times (§5.1.2), used only in simulation.

Every store exposes the same three operations on the *transaction-state* log:

  log_once(partition, txn, state) -> resulting state   (CAS; first write wins)
  log(partition, txn, state)      -> resulting state   (blind append; 2PC path)
  read_state(partition, txn)      -> state | None

User-data logging (the execution-phase writes that 2PC piggybacks on prepare)
is modelled as an opaque byte-count via ``log_data`` — access-control
separation between data and txn-state (§4) is what the ``acl`` flag models.
"""
from __future__ import annotations

import os
import random
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from .state import Vote


# --------------------------------------------------------------------------
# Latency models (paper §5.1.2 measurements, in milliseconds)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class LatencyModel:
    """Service-time model for one storage deployment."""

    name: str
    conditional_write_ms: float   # LogOnce() mean
    plain_write_ms: float         # Log() mean
    read_ms: float                # state read mean
    jitter: float = 0.05          # lognormal-ish multiplicative spread
    # Separate-ACL deployments (Azure Blob §4.2) need TWO sequential requests
    # for LogOnce-with-data: data PUT then conditional state PUT.
    separate_acl: bool = False
    # Service-time growth per extra record in a batched write (coordinator-log
    # variant §5.6 ships ALL participants' redo data in one request).
    batch_size_factor: float = 0.15

    def sample(self, rng: random.Random, mean_ms: float) -> float:
        # Deterministic multiplicative jitter; heavy-ish right tail like the
        # paper's P99 plots (Fig 5/6) without a full trace model.
        u = rng.random()
        tail = 1.0 + (3.0 * rng.random() if u > 0.97 else 0.0)
        return mean_ms * (1.0 + self.jitter * (2.0 * rng.random() - 1.0)) * tail


AZURE_REDIS = LatencyModel("redis", conditional_write_ms=1.96,
                           plain_write_ms=1.84, read_ms=0.9)
AZURE_BLOB = LatencyModel("blob", conditional_write_ms=10.40,
                          plain_write_ms=10.29, read_ms=5.0)
# §5.1.4: separate ACLs for txn-state vs user data raise LogOnce from
# 10.40ms to 18.43ms (two sequential requests).
AZURE_BLOB_SEPARATE_ACL = LatencyModel(
    "blob-acl", conditional_write_ms=18.43, plain_write_ms=10.29,
    read_ms=5.0, separate_acl=True)
# §5.6 coordinator-log experiment measured ~443ms writes ("such high latency
# of writing to Redis" — a heavily loaded/cross-region instance).
SLOW_REDIS = LatencyModel("slow-redis", conditional_write_ms=443.0,
                          plain_write_ms=443.0, read_ms=221.0)

COMPUTE_RTT_MS = 0.5  # measured compute↔compute round trip (§5.1.2)


# --------------------------------------------------------------------------
# Stores
# --------------------------------------------------------------------------
class MemoryStore:
    """Thread-safe CAS store holding per-partition transaction-state logs."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        # (partition, txn) -> (state, writer)
        self._state: Dict[Tuple[str, str], Tuple[Vote, str]] = {}
        self._data_bytes: Dict[str, int] = {}
        self.cas_attempts = 0
        self.cas_losses = 0

    def log_once(self, partition: str, txn: str, state: Vote,
                 writer: str = "") -> Vote:
        with self._lock:
            self.cas_attempts += 1
            key = (partition, txn)
            if key in self._state:
                self.cas_losses += 1
                return self._state[key][0]
            self._state[key] = (state, writer)
            return state

    def log(self, partition: str, txn: str, state: Vote,
            writer: str = "") -> Vote:
        with self._lock:
            # Blind append: last record wins, but a decision record never
            # regresses to a vote (append-only log read returns the newest
            # *decision* if present — matches 2PC recovery reads).
            key = (partition, txn)
            cur = self._state.get(key)
            if cur is not None and cur[0].is_decision() and not state.is_decision():
                return cur[0]
            self._state[key] = (state, writer)
            return state

    def read_state(self, partition: str, txn: str) -> Optional[Vote]:
        with self._lock:
            cur = self._state.get((partition, txn))
            return cur[0] if cur else None

    def writer_of(self, partition: str, txn: str) -> Optional[str]:
        with self._lock:
            cur = self._state.get((partition, txn))
            return cur[1] if cur else None

    def log_data(self, partition: str, nbytes: int) -> None:
        with self._lock:
            self._data_bytes[partition] = self._data_bytes.get(partition, 0) + nbytes

    def snapshot(self) -> Dict[Tuple[str, str], Vote]:
        with self._lock:
            return {k: v[0] for k, v in self._state.items()}


class FileStore:
    """Directory-backed store: O_CREAT|O_EXCL create-if-absent is the CAS.

    Layout:  <root>/state/<partition>/<txn>            (one small state file)
             <root>/data/<partition>/<name>            (bulk shard payloads)

    This is the deployment target for the checkpoint committer: the directory
    stands in for a blob container; partitions are per-host prefixes and the
    ACL separation of §4 maps to the state/ vs data/ prefixes.
    """

    def __init__(self, root: str) -> None:
        self.root = root
        os.makedirs(os.path.join(root, "state"), exist_ok=True)
        os.makedirs(os.path.join(root, "data"), exist_ok=True)

    def _state_path(self, partition: str, txn: str) -> str:
        d = os.path.join(self.root, "state", partition)
        os.makedirs(d, exist_ok=True)
        return os.path.join(d, txn)

    def log_once(self, partition: str, txn: str, state: Vote,
                 writer: str = "") -> Vote:
        path = self._state_path(partition, txn)
        payload = f"{state.value}\n{writer}\n".encode()
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            return self._read(path)
        try:
            os.write(fd, payload)
            os.fsync(fd)
        finally:
            os.close(fd)
        return state

    def log(self, partition: str, txn: str, state: Vote,
            writer: str = "") -> Vote:
        path = self._state_path(partition, txn)
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(f"{state.value}\n{writer}\n".encode())
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic overwrite
        return state

    def _read(self, path: str) -> Vote:
        with open(path, "rb") as f:
            return Vote(f.read().decode().splitlines()[0])

    def read_state(self, partition: str, txn: str) -> Optional[Vote]:
        path = self._state_path(partition, txn)
        try:
            return self._read(path)
        except FileNotFoundError:
            return None

    # Bulk payloads (checkpoint shards) ------------------------------------
    def data_path(self, partition: str, name: str) -> str:
        d = os.path.join(self.root, "data", partition)
        os.makedirs(d, exist_ok=True)
        return os.path.join(d, name)

    def put_data(self, partition: str, name: str, payload: bytes) -> str:
        path = self.data_path(partition, name)
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        return path

    def get_data(self, partition: str, name: str) -> bytes:
        with open(self.data_path(partition, name), "rb") as f:
            return f.read()


# --------------------------------------------------------------------------
# Simulated storage service: MemoryStore semantics + LatencyModel timing
# --------------------------------------------------------------------------
class SimStorage:
    """Storage service as seen from inside the discrete-event simulator.

    A request issued at t has its CAS *applied* at t + service/2 (the moment
    the storage processes it) and its response delivered at t + service.
    Interleaving of concurrent LogOnce calls is therefore decided by apply
    times — exactly the data race the paper's termination protocol wins or
    loses by, and what the hypothesis tests perturb.
    """

    def __init__(self, sim, model: LatencyModel, seed: int = 0) -> None:
        self.sim = sim
        self.model = model
        self.store = MemoryStore()
        self.rng = random.Random(seed)
        self.requests = 0

    # Each returns a sim Event yielding the op's result.
    def _op(self, service_ms: float, apply_fn):
        self.requests += 1
        done = self.sim.event()
        result = {}

        def apply():
            result["value"] = apply_fn()

        self.sim._schedule(self.sim.now + service_ms / 2.0, apply)
        self.sim._schedule(self.sim.now + service_ms,
                           lambda: done.trigger(result.get("value")))
        return done

    def log_once(self, partition: str, txn: str, state: Vote, writer: str = ""):
        ms = self.model.sample(self.rng, self.model.conditional_write_ms)
        return self._op(ms, lambda: self.store.log_once(partition, txn, state, writer))

    def log(self, partition: str, txn: str, state: Vote, writer: str = ""):
        ms = self.model.sample(self.rng, self.model.plain_write_ms)
        return self._op(ms, lambda: self.store.log(partition, txn, state, writer))

    def read_state(self, partition: str, txn: str):
        ms = self.model.sample(self.rng, self.model.read_ms)
        return self._op(ms, lambda: self.store.read_state(partition, txn))

    def log_batch(self, partition: str, txn: str, state: Vote, n_records: int,
                  writer: str = ""):
        """Coordinator-log variant (§5.6): n records batched in ONE write.

        One request (saves per-write round trips vs 2PC's sequential
        prepare-then-decision) but the payload carries every participant's
        redo records, so service time grows with the batch size.
        """
        mean = self.model.plain_write_ms * (
            1.0 + self.model.batch_size_factor * max(0, n_records - 1))
        ms = self.model.sample(self.rng, mean)
        return self._op(ms, lambda: self.store.log(partition, txn, state, writer))
