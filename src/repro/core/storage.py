"""Disaggregated storage layer: Log() / LogOnce() over pluggable stores.

The paper's only storage-layer requirement is *log-once* semantics built on a
compare-and-swap primitive (§3.2, §4).  Three stores implement it here:

  * ``MemoryStore``  – lock-protected dict; used by the discrete-event sim and
    by threaded integration tests (stands in for Azure Redis / Blob).
  * ``FileStore``    – directory-backed; ``open(O_CREAT|O_EXCL)`` is the CAS
    (create-if-absent ≙ Azure Blob "If-None-Match:*" conditional PUT).  Used
    by the training framework's Cornus checkpoint commit.
  * ``LatencyModel`` – deterministic latency sampler with the paper's measured
    service times (§5.1.2), used only in simulation.

Every store exposes the same three operations on the *transaction-state* log:

  log_once(partition, txn, state) -> resulting state   (CAS; first write wins)
  log(partition, txn, state)      -> resulting state   (blind append; 2PC path)
  read_state(partition, txn)      -> state | None

User-data logging (the execution-phase writes that 2PC piggybacks on prepare)
is modelled as an opaque byte-count via ``log_data`` — access-control
separation between data and txn-state (§4) is what the ``acl`` flag models.
"""
from __future__ import annotations

import itertools
import os
import random
import threading
import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from .control import (DecisionCacheConfig, DecisionIndex, EwmaStat,
                      QuorumUnavailable, ThreadControlPlane)
from .lifecycle import (CorruptRecord, GcEntry, LifecycleConfig,
                        RECORD_MAGIC, decode_record, encode_record)
from .state import Vote


# --------------------------------------------------------------------------
# Latency models (paper §5.1.2 measurements, in milliseconds)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class LatencyModel:
    """Service-time model for one storage deployment."""

    name: str
    conditional_write_ms: float   # LogOnce() mean
    plain_write_ms: float         # Log() mean
    read_ms: float                # state read mean
    jitter: float = 0.05          # lognormal-ish multiplicative spread
    # Separate-ACL deployments (Azure Blob §4.2) need TWO sequential requests
    # for LogOnce-with-data: data PUT then conditional state PUT.
    separate_acl: bool = False
    # Service-time growth per extra record in a batched write (coordinator-log
    # variant §5.6 ships ALL participants' redo data in one request).
    batch_size_factor: float = 0.15

    def sample(self, rng: random.Random, mean_ms: float) -> float:
        # Deterministic multiplicative jitter; heavy-ish right tail like the
        # paper's P99 plots (Fig 5/6) without a full trace model.
        u = rng.random()
        tail = 1.0 + (3.0 * rng.random() if u > 0.97 else 0.0)
        return mean_ms * (1.0 + self.jitter * (2.0 * rng.random() - 1.0)) * tail

    def batched_write_ms(self, n_records: int,
                         base_ms: Optional[float] = None) -> float:
        """Mean service time for ONE write carrying ``n_records`` records.

        The amortization model shared by the coordinator-log §5.6 batch
        write and the storage-ingress group-commit lanes: one base service
        time plus ``batch_size_factor`` payload growth per extra record.
        """
        base = self.plain_write_ms if base_ms is None else base_ms
        return base * (1.0 + self.batch_size_factor * max(0, n_records - 1))


AZURE_REDIS = LatencyModel("redis", conditional_write_ms=1.96,
                           plain_write_ms=1.84, read_ms=0.9)
AZURE_BLOB = LatencyModel("blob", conditional_write_ms=10.40,
                          plain_write_ms=10.29, read_ms=5.0)
# §5.1.4: separate ACLs for txn-state vs user data raise LogOnce from
# 10.40ms to 18.43ms (two sequential requests).
AZURE_BLOB_SEPARATE_ACL = LatencyModel(
    "blob-acl", conditional_write_ms=18.43, plain_write_ms=10.29,
    read_ms=5.0, separate_acl=True)
# §5.6 coordinator-log experiment measured ~443ms writes ("such high latency
# of writing to Redis" — a heavily loaded/cross-region instance).
SLOW_REDIS = LatencyModel("slow-redis", conditional_write_ms=443.0,
                          plain_write_ms=443.0, read_ms=221.0)

COMPUTE_RTT_MS = 0.5  # measured compute↔compute round trip (§5.1.2)


# --------------------------------------------------------------------------
# Region topology (extended version §6: geo-distributed deployments)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class RegionTopology:
    """Multi-region RTT matrix replacing the single scalar ``rtt_ms``.

    ``rtt_ms(a, b)`` is the full round trip between two regions: ``intra_ms``
    within a region, an explicit entry of ``links`` across regions (keyed by
    the sorted region pair), else ``default_cross_ms``.  Presets below model
    the three deployment shapes of the extended paper: intra-zone (the §5
    measurement setup), cross-zone, and cross-region (geo).
    """

    name: str
    regions: Tuple[str, ...]
    intra_ms: float = COMPUTE_RTT_MS
    links: Mapping[Tuple[str, str], float] = field(default_factory=dict)
    default_cross_ms: float = 2.0

    def rtt_ms(self, a: str, b: str) -> float:
        if a == b:
            return self.intra_ms
        key = (a, b) if a <= b else (b, a)
        return self.links.get(key, self.default_cross_ms)

    @property
    def max_rtt_ms(self) -> float:
        worst = max(self.intra_ms, self.default_cross_ms)
        return max([worst] + list(self.links.values()))

    @classmethod
    def uniform(cls, name: str, regions: Sequence[str],
                rtt_ms: float) -> "RegionTopology":
        """Every pair (including intra-region) costs the same RTT — used to
        validate the simulator against the analytic Table-3 RTT counts."""
        return cls(name, tuple(regions), intra_ms=rtt_ms,
                   default_cross_ms=rtt_ms)

    def place_round_robin(self, nodes: Sequence[str]) -> Dict[str, str]:
        return {n: self.regions[i % len(self.regions)]
                for i, n in enumerate(nodes)}


INTRA_ZONE = RegionTopology("intra-zone", ("zone-a",))
CROSS_ZONE = RegionTopology("cross-zone", ("zone-a", "zone-b", "zone-c"),
                            default_cross_ms=2.0)
# Public-cloud-shaped inter-region RTTs (coordinator home region first).
CROSS_REGION = RegionTopology(
    "cross-region", ("us-east", "us-west", "eu-west"),
    links={("us-east", "us-west"): 62.0,
           ("eu-west", "us-east"): 76.0,
           ("eu-west", "us-west"): 140.0},
    default_cross_ms=100.0)


# --------------------------------------------------------------------------
# Storage-ingress group commit (batching layer)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class BatchConfig:
    """Group-commit knobs for one storage service.

    The batching layer models the serial log device behind each partition:
    when active, a partition admits ONE write round trip at a time and
    requests that arrive meanwhile coalesce into the next batch, charged a
    single base service time plus ``LatencyModel.batch_size_factor`` payload
    growth (the same amortization the coordinator-log §5.6 variant uses for
    its batched record).

      window_ms  – batch formation window, counted from the first request
                   in the batch.  0 = flush as soon as the lane is idle
                   ("piggyback" group commit: only requests that arrived
                   while the previous flush was in flight coalesce).
                   "auto" = load-proportional window, like real log
                   daemons (PostgreSQL commit_delay / InnoDB group-commit
                   sync delay): a lane only delays a flush when arrivals
                   are frequent enough that waiting will coalesce more
                   records, and the delay is clamped to
                   [0, ``max_window_ms``]; an idle lane never waits.
      max_window_ms – clamp for the "auto" window.
      max_batch  – records per flush cap; a full batch flushes immediately.
                   1 = a plain serial queue (no coalescing).
      serial     – enable the per-partition serial lane even at window 0.

    The DEFAULT config is inactive: every request keeps its own concurrent
    round trip, bit-identical to the pre-batching simulator (fig10 /
    Table-3 numbers are validated against this passthrough).
    """

    window_ms: "float | str" = 0.0
    max_batch: int = 64
    serial: bool = False
    max_window_ms: float = 4.0

    def __post_init__(self) -> None:
        if isinstance(self.window_ms, str) and self.window_ms != "auto":
            raise ValueError(f"window_ms must be a float or 'auto', "
                             f"got {self.window_ms!r}")

    @property
    def auto(self) -> bool:
        return self.window_ms == "auto"

    @property
    def active(self) -> bool:
        return self.serial or self.auto or self.window_ms > 0.0

    @property
    def worst_case_window_ms(self) -> float:
        """Upper bound on formation delay — what timeouts must absorb."""
        return self.max_window_ms if self.auto else float(self.window_ms)


class _BatchOp:
    """One logical write queued at storage ingress."""

    __slots__ = ("kind", "partition", "txn", "state", "writer", "n_records",
                 "fwd", "done", "result", "key", "gen")

    def __init__(self, kind: str, partition: str, txn: str, state: Vote,
                 writer: str, n_records: int = 1, fwd=None):
        assert kind in ("log_once", "log")
        self.kind = kind
        self.partition = partition
        self.txn = txn
        self.state = state
        self.writer = writer
        self.n_records = n_records
        self.fwd = fwd                 # _Forward obligation (vote forwarding)
        self.done = None               # per-op completion Event
        self.result: Optional[Vote] = None
        self.key = (partition, txn)
        self.gen = 0                   # assigned at flush time for plain logs


class _Lane:
    __slots__ = ("pending", "busy", "timer", "ripe", "last_arrival",
                 "iat_ewma")

    def __init__(self) -> None:
        self.pending: List[_BatchOp] = []
        self.busy = False              # a flush round trip is in flight
        self.timer = None              # armed window timer
        self.ripe = False              # window elapsed while lane was busy
        self.last_arrival: Optional[float] = None   # adaptive-window EWMA
        self.iat_ewma: Optional[float] = None       # mean inter-arrival ms


class GroupCommitIngress:
    """Per-partition group-commit lanes in front of a simulated storage
    service.  ``submit(op)`` returns the op's completion Event; the owning
    service supplies ``flush_fn(partition, ops) -> Event`` which charges ONE
    round trip, applies every op in arrival order (first-writer-wins per
    slot is therefore preserved), triggers each ``op.done``, and triggers
    the returned Event when the round trip completes (freeing the lane).
    """

    def __init__(self, sim, cfg: BatchConfig, flush_fn) -> None:
        self.sim = sim
        self.cfg = cfg
        self.flush_fn = flush_fn
        self._lanes: Dict[str, _Lane] = {}
        self.flushes = 0
        self.ops_in = 0
        self.max_batch_seen = 0

    def submit(self, op: _BatchOp):
        op.done = self.sim.event()
        lane = self._lanes.setdefault(op.partition, _Lane())
        lane.pending.append(op)
        self.ops_in += 1
        if self.cfg.auto:
            now = self.sim.now
            if lane.last_arrival is not None:
                dt = now - lane.last_arrival
                if dt >= self.cfg.max_window_ms:
                    # The lane went idle: burst history must not make a
                    # lone straggler wait out a formation window.
                    lane.iat_ewma = None
                else:
                    lane.iat_ewma = (dt if lane.iat_ewma is None
                                     else 0.8 * lane.iat_ewma + 0.2 * dt)
            lane.last_arrival = now
        self._poke(lane)
        return op.done

    def _window_ms(self, lane: _Lane) -> float:
        """Formation window for this lane's next batch.

        Fixed configs return ``window_ms`` verbatim.  "auto" is
        load-proportional: an idle lane (mean inter-arrival above the
        clamp) never delays, and a busy lane waits just long enough to
        fill the remaining batch capacity at the observed arrival rate,
        clamped to [0, max_window_ms].
        """
        if not self.cfg.auto:
            return float(self.cfg.window_ms)
        iat = lane.iat_ewma
        if iat is None or iat >= self.cfg.max_window_ms:
            return 0.0
        room = max(0, self.cfg.max_batch - len(lane.pending))
        return min(self.cfg.max_window_ms, iat * room)

    def _poke(self, lane: _Lane) -> None:
        if lane.busy or not lane.pending:
            return
        window = self._window_ms(lane)
        if window > 0 and len(lane.pending) < self.cfg.max_batch:
            if lane.timer is None:
                lane.timer = self.sim.timer(window,
                                            lambda: self._fire(lane))
            return
        self._fire(lane)

    def _fire(self, lane: _Lane) -> None:
        if lane.timer is not None:
            lane.timer.cancel()
            lane.timer = None
        if lane.busy:
            lane.ripe = True           # flush the moment the lane frees up
            return
        if not lane.pending:
            return
        ops = lane.pending[:self.cfg.max_batch]
        lane.pending = lane.pending[self.cfg.max_batch:]
        lane.busy = True
        self.flushes += 1
        self.max_batch_seen = max(self.max_batch_seen, len(ops))
        self.flush_fn(ops[0].partition, ops).subscribe(
            lambda _ev, lane=lane: self._flushed(lane))

    def _flushed(self, lane: _Lane) -> None:
        lane.busy = False
        if not lane.pending:
            lane.ripe = False
            return
        if (lane.ripe or self._window_ms(lane) <= 0
                or len(lane.pending) >= self.cfg.max_batch):
            lane.ripe = False
            self._fire(lane)
        else:
            self._poke(lane)           # arm a fresh window for the next batch


# --------------------------------------------------------------------------
# Storage-side termination-storm controls: decision cache + singleflight
# --------------------------------------------------------------------------
# ``DecisionCacheConfig`` / ``DecisionIndex`` (and the adaptive-timeout /
# lease policies that read the stats recorded here) live in ``control`` —
# the backend-agnostic control plane shared by these simulated services and
# the threaded stores below.  Re-exported here for compatibility.


class _DecisionCacheMixin:
    """Shared decision-cache plumbing for the two simulated services.

    Subclass ``__init__`` sets ``self._dindex`` (or None) and
    ``self._cache_rng``; the mixin adds the counters, the watcher API and
    the write-latency EWMA that adaptive timeout policies read."""

    _dindex: Optional[DecisionIndex]
    # Observed write-latency stats (queueing included) — the signal an
    # adaptive protocol-timeout policy needs to sit above the real tail.
    write_lat_ewma: Optional[float]
    write_lat_dev: float

    def _init_decisions(self, decisions: Optional[DecisionCacheConfig],
                        seed: int) -> None:
        self.decisions = decisions or DecisionCacheConfig()
        self._dindex = (DecisionIndex(self.decisions)
                        if self.decisions.active else None)
        # Chaos plane + history recorder (core/chaos.Nemesis,
        # core/history.HistoryRecorder).  Both default OFF; every hook
        # checks for None before touching them and the recorder is
        # subscription-only, so unattached runs are bit-identical.
        self.chaos = None
        self.history = None
        # Dedicated rng for cache-hit reads: the MAIN service stream stays
        # identical whether or not hits occur, so enabling the cache can
        # never perturb the timing of uncached operations.
        self._cache_rng = random.Random(seed ^ 0x0DEC1DE)
        self.write_lat_ewma = None
        self.write_lat_dev = 0.0
        # Per-lane (partition) stats alongside the service-global pair:
        # pure bookkeeping (no rng, no events), consulted only by adaptive
        # timeout policies constructed with ``per_lane=True``.
        self._lane_lat: Dict[str, EwmaStat] = {}

    # -- counters ----------------------------------------------------------
    @property
    def decision_cache_hits(self) -> int:
        return self._dindex.hits if self._dindex else 0

    @property
    def singleflight_hits(self) -> int:
        return self._dindex.singleflight_hits if self._dindex else 0

    @property
    def decisions_pushed(self) -> int:
        return self._dindex.pushes if self._dindex else 0

    # -- watcher API (decision push) ---------------------------------------
    def watch_decision(self, txn: str, cb: Callable[[Vote], None],
                       node: Optional[str] = None) -> None:
        """Run ``cb(value)`` when the txn's first terminal record lands
        (immediately if it already has).  ``node`` is the watching compute
        node: the service charges the storage→node push leg before
        invoking ``cb`` (the same leg vote forwarding pays).  No-op unless
        push is enabled."""
        if self._dindex is not None:
            self._dindex.watch(txn, self._push_wrapper(cb, node))

    def _push_wrapper(self, cb: Callable[[Vote], None],
                      node: Optional[str]):
        """Storage→watcher push leg.  The single unreplicated service has
        no distinct position (mirrors its ``on_forward`` semantics), so it
        charges the fixed compute↔storage half-RTT; the replicated service
        overrides this with the front-end replica's topology leg."""
        if node is None:
            return cb

        def wrapped(value: Vote) -> None:
            self.sim._schedule(self.sim.now + COMPUTE_RTT_MS / 2.0,
                               lambda: cb(value))

        return wrapped

    def _note(self, partition: str, txn: str,
              value: Optional[Vote]) -> None:
        if self._dindex is not None:
            self._dindex.note(partition, txn, value)

    # -- write-latency observation (adaptive timeouts) ---------------------
    def _note_write_latency(self, ms: float,
                            lane: Optional[str] = None) -> None:
        if self.write_lat_ewma is None:
            self.write_lat_ewma = ms
            self.write_lat_dev = ms / 4.0
        else:
            self.write_lat_dev = (0.75 * self.write_lat_dev
                                  + 0.25 * abs(ms - self.write_lat_ewma))
            self.write_lat_ewma = 0.75 * self.write_lat_ewma + 0.25 * ms
        if lane is not None:
            st = self._lane_lat.get(lane)
            if st is None:
                st = self._lane_lat[lane] = EwmaStat()
            st.note(ms)

    def lane_write_latency(self, lane: str
                           ) -> Optional[Tuple[float, float]]:
        """(ewma, dev) of ``lane``'s observed write latency, or None if the
        lane has never completed a write."""
        st = self._lane_lat.get(lane)
        if st is None or st.ewma is None:
            return None
        return st.ewma, st.dev

    def _observed(self, ev, lane: Optional[str] = None):
        """Record the op's caller-observed latency (queueing included) when
        it completes.  Subscription only — no events, no rng."""
        t0 = self.sim.now
        ev.subscribe(lambda _e: self._note_write_latency(self.sim.now - t0,
                                                         lane))
        return ev

    def _recorded(self, ev, kind: str, partition: str, txn: str,
                  state=None, writer: str = ""):
        """Feed the op into the attached history recorder (checker
        evidence).  Subscription only — no events, no rng — and a no-op
        without a recorder."""
        if self.history is not None:
            self.history.record(ev, kind, partition, txn, state, writer)
        return ev


# --------------------------------------------------------------------------
# Stores
# --------------------------------------------------------------------------
class _ControlledStoreMixin:
    """Threaded-store side of the shared control plane.

    The simulated services above drive the decision index with sim Events;
    the blocking stores drive the SAME index through a
    ``ThreadControlPlane`` (real threads, one lock).  The mixin adds the
    identical observable surface — ``decision_cache_hits`` /
    ``singleflight_hits`` / ``decisions_pushed`` counters,
    ``watch_decision``, and the ``write_lat_ewma`` / ``lane_write_latency``
    stats adaptive timeout policies read — so protocol code and benches
    are backend-agnostic.  With no active ``DecisionCacheConfig`` (the
    default) the plane is absent and every operation is exactly the raw
    store op."""

    control: Optional[ThreadControlPlane]

    def _init_control(self,
                      decisions: Optional[DecisionCacheConfig]) -> None:
        self.control = (ThreadControlPlane(decisions)
                        if decisions is not None and decisions.active
                        else None)

    # -- counters (same names as the sim services) -------------------------
    @property
    def decision_cache_hits(self) -> int:
        return self.control.decision_cache_hits if self.control else 0

    @property
    def singleflight_hits(self) -> int:
        return self.control.singleflight_hits if self.control else 0

    @property
    def decisions_pushed(self) -> int:
        return self.control.decisions_pushed if self.control else 0

    @property
    def write_lat_ewma(self) -> Optional[float]:
        return self.control.write_lat_ewma if self.control else None

    @property
    def write_lat_dev(self) -> float:
        return self.control.write_lat_dev if self.control else 0.0

    def lane_write_latency(self, lane: str
                           ) -> Optional[Tuple[float, float]]:
        return self.control.lane_write_latency(lane) if self.control \
            else None

    def watch_decision(self, txn: str, cb: Callable[[Vote], None],
                       node: Optional[str] = None) -> None:
        if self.control is not None:
            self.control.watch_decision(txn, cb, node)

    # -- op wrappers -------------------------------------------------------
    def _controlled_log_once(self, perform: Callable[[], Vote],
                             partition: str, txn: str, state: Vote,
                             writer: str) -> Vote:
        if self.control is None:
            return perform()
        return self.control.log_once(perform, partition, txn, state, writer)

    def _note_control(self, partition: str, txn: str,
                      value: Optional[Vote]) -> None:
        """Feed decisions landing outside log_once (2PC's plain decision
        logs, recovery reads) into the index."""
        if self.control is not None:
            self.control.note(partition, txn, value)


class MemoryStore(_ControlledStoreMixin):
    """Thread-safe CAS store holding per-partition transaction-state logs.

    With a ``LifecycleConfig`` armed the store additionally keeps a
    CRC32-framed durable image per record (torn tails are treated as
    absent — the write was never acknowledged — and bit-rot is detected
    and repaired from a sibling slot of the same txn holding the terminal
    decision), a per-partition append order the GC low-watermark advances
    over, and a truncation journal (``gc_log``) the history checker audits
    (AC-GC).  ``lifecycle=None`` (the default) is bit-identical to the
    pre-lifecycle store.
    """

    def __init__(self,
                 decisions: Optional[DecisionCacheConfig] = None,
                 lifecycle: Optional[LifecycleConfig] = None) -> None:
        self._lock = threading.Lock()
        # (partition, txn) -> (state, writer)
        self._state: Dict[Tuple[str, str], Tuple[Vote, str]] = {}
        self._data_bytes: Dict[str, int] = {}
        self._payloads: Dict[Tuple[str, str], bytes] = {}
        self.cas_attempts = 0
        self.cas_losses = 0
        self.lifecycle = LifecycleConfig.coerce(lifecycle)
        # Durable image: key -> mutable CRC32-framed record bytes (the
        # chaos BitFlip/TornTail hooks mutate these; reads verify them).
        self._frames: Dict[Tuple[str, str], bytearray] = {}
        self._order: Dict[str, List[str]] = {}     # partition -> txns, append order
        self._order_seen: set = set()
        self.watermarks: Dict[str, int] = {}       # partition -> truncated prefix
        self.gc_log: List[GcEntry] = []
        self._gc_index: Dict[Tuple[str, str], GcEntry] = {}
        self.gc_truncations = 0
        self.torn_records = 0
        self.corrupt_records = 0
        self.scrub_repairs = 0
        self.quarantines = 0
        self._corrupt_streak = 0
        self._init_control(decisions)

    # -- lifecycle-aware record access (lock held) -------------------------
    def _put(self, key: Tuple[str, str], state: Vote, writer: str) -> None:
        self._state[key] = (state, writer)
        lc = self.lifecycle
        if lc is not None:
            if key not in self._order_seen:
                self._order_seen.add(key)
                self._order.setdefault(key[0], []).append(key[1])
            if lc.checksums:
                self._frames[key] = bytearray(
                    encode_record(state.value, writer))

    def _get(self, key: Tuple[str, str]):
        """-> (state, writer) | (CorruptRecord, "") | None, verifying the
        CRC frame when checksums are armed.  Torn frames (unacknowledged
        writes) are dropped as absent; bit-rot is repaired from a sibling
        slot of the same txn, or surfaced as a typed `CorruptRecord`."""
        cur = self._state.get(key)
        lc = self.lifecycle
        if cur is None:
            if lc is not None and lc.gc:
                # Truncated slot: the journal entry is the tombstone — it
                # carries the settled terminal decision, which is the only
                # answer a post-truncation reader can soundly be given.
                e = self._gc_index.get(key)
                if e is not None and e.decision is not None:
                    return (Vote(e.decision), "gc")
            return None
        if lc is None or not lc.checksums:
            return cur
        fr = self._frames.get(key)
        if fr is None:
            return cur
        rec = decode_record(bytes(fr), key[0], key[1])
        if isinstance(rec, CorruptRecord):
            if rec.torn:
                # Torn tail: the write died mid-flight and was never
                # acknowledged — absent-or-corrupt, safe to treat absent.
                self.torn_records += 1
                self._state.pop(key, None)
                self._frames.pop(key, None)
                return None
            self.corrupt_records += 1
            self._corrupt_streak += 1
            if self._corrupt_streak >= lc.quarantine_threshold:
                self.quarantines += 1
                self._corrupt_streak = 0
            repaired = self._sibling_repair(key)
            if repaired is not None:
                return repaired
            return (rec, "")     # typed CorruptRecord, never garbage bytes
        val, w = rec
        return (Vote(val), w)

    def _sibling_repair(self, key: Tuple[str, str]):
        """Bit-rot repair from intra-txn redundancy: another slot of the
        same txn holding a verified terminal decision, or the truncation
        journal's recorded decision.  Rewrites the frame in place."""
        partition, txn = key
        found: Optional[Vote] = None
        for (p2, t2), cur in self._state.items():
            if t2 != txn or (p2, t2) == key:
                continue
            fr = self._frames.get((p2, t2))
            if fr is not None and isinstance(
                    decode_record(bytes(fr)), CorruptRecord):
                continue       # the sibling is rotted too
            if isinstance(cur[0], Vote) and cur[0].is_decision():
                found = cur[0]
                break
        if found is None:
            for e in reversed(self.gc_log):
                if e.txn == txn and e.decision is not None:
                    found = Vote(e.decision)
                    break
        if found is None:
            return None
        self._put(key, found, "scrub")
        self.scrub_repairs += 1
        return (found, "scrub")

    def log_once(self, partition: str, txn: str, state: Vote,
                 writer: str = "") -> Vote:
        return self._controlled_log_once(
            lambda: self._log_once_direct(partition, txn, state, writer),
            partition, txn, state, writer)

    def _log_once_direct(self, partition: str, txn: str, state: Vote,
                         writer: str = "") -> Vote:
        with self._lock:
            self.cas_attempts += 1
            key = (partition, txn)
            cur = self._get(key)
            if cur is not None:
                if not isinstance(cur[0], CorruptRecord):
                    self.cas_losses += 1
                return cur[0]
            self._put(key, state, writer)
            return state

    def log(self, partition: str, txn: str, state: Vote,
            writer: str = "") -> Vote:
        with self._lock:
            # Blind append: last record wins, but a decision record never
            # regresses to a vote NOR flips to the other decision (a zombie
            # re-issue from a dead incarnation racing crash recovery must
            # not make the slot serve both terminal values — AC3).
            key = (partition, txn)
            cur = self._get(key)
            if (cur is not None and isinstance(cur[0], Vote)
                    and cur[0].is_decision() and state != cur[0]):
                result = cur[0]
            else:
                self._put(key, state, writer)
                result = state
        self._note_control(partition, txn, result)
        return result

    def read_state(self, partition: str, txn: str) -> Optional[Vote]:
        with self._lock:
            cur = self._get((partition, txn))
            return cur[0] if cur else None

    def writer_of(self, partition: str, txn: str) -> Optional[str]:
        with self._lock:
            cur = self._state.get((partition, txn))
            return cur[1] if cur else None

    # -- durable-state lifecycle -------------------------------------------
    def gc_pass(self, now: float = 0.0) -> int:
        """Advance each partition's low-watermark past SETTLED txns (some
        slot of the txn holds a terminal decision — durable here by
        presence, this store being its own single volume) and truncate the
        slots below it, journaling every removal.  The watermark only ever
        moves forward (monotonic CAS under the store lock) and never past
        the first unsettled txn, so an in-doubt transaction blocks GC of
        its partition rather than losing recoverability."""
        lc = self.lifecycle
        if lc is None or not lc.gc:
            return 0
        with self._lock:
            settled: Dict[str, Vote] = {}
            for (_p, t), cur in self._state.items():
                if isinstance(cur[0], Vote) and cur[0].is_decision():
                    settled.setdefault(t, cur[0])
            for e in self.gc_log:
                if e.decision is not None:
                    settled.setdefault(e.txn, Vote(e.decision))
            n = 0
            for partition, order in self._order.items():
                wm = self.watermarks.get(partition, 0)
                while wm < len(order):
                    txn = order[wm]
                    key = (partition, txn)
                    cur = self._state.get(key)
                    if cur is None:
                        wm += 1           # torn-dropped or already truncated
                        continue
                    dec = settled.get(txn)
                    if dec is None:
                        break             # unsettled txn: watermark stops
                    e = GcEntry(partition, txn,
                                getattr(cur[0], "value", None), dec.value,
                                True, at=now)
                    self.gc_log.append(e)
                    self._gc_index[key] = e
                    self._state.pop(key, None)
                    self._frames.pop(key, None)
                    wm += 1
                    n += 1
                if wm > self.watermarks.get(partition, 0):
                    self.watermarks[partition] = wm
            self.gc_truncations += n
            return n

    def scrub_pass(self) -> int:
        """Verify every retained frame (repairing rot, dropping torn
        tails); returns the number of repairs made."""
        lc = self.lifecycle
        if lc is None or not lc.checksums:
            return 0
        with self._lock:
            before = self.scrub_repairs
            for key in list(self._state.keys()):
                self._get(key)
            return self.scrub_repairs - before

    def bitflip(self, rng: random.Random) -> bool:
        """Chaos hook: flip one body byte of a REPAIRABLE durable record.
        Eligible slots belong to a txn with a second, intact terminal slot
        (rot with no redundant copy is unrecoverable by any protocol — the
        Nemesis models survivable media rot).  Header bytes are spared:
        this format cannot distinguish header rot from a torn create."""
        lc = self.lifecycle
        if lc is None or not lc.checksums:
            return False
        with self._lock:
            terminal: Dict[str, int] = {}
            for (_p, t), cur in self._state.items():
                if isinstance(cur[0], Vote) and cur[0].is_decision():
                    terminal[t] = terminal.get(t, 0) + 1
            cands = sorted(
                key for key in self._frames
                if key in self._state
                and terminal.get(key[1], 0)
                >= (2 if isinstance(self._state[key][0], Vote)
                    and self._state[key][0].is_decision() else 1))
            if not cands:
                return False
            key = cands[rng.randrange(len(cands))]
            fr = self._frames[key]
            body_start = bytes(fr).find(b"\n") + 1
            if body_start <= 0 or body_start >= len(fr):
                return False
            i = rng.randrange(body_start, len(fr))
            fr[i] ^= rng.randrange(1, 256)
            return True

    def tear_slot(self, key: Tuple[str, str]) -> bool:
        """Chaos hook: truncate the slot's frame mid-write (a torn tail).
        The next read detects the short body and treats the record as
        absent — sound only because the Nemesis pairs this with losing the
        write's response (the record was never acknowledged)."""
        lc = self.lifecycle
        if lc is None or not lc.checksums:
            return False
        with self._lock:
            fr = self._frames.get(key)
            if fr is None or len(fr) < 2:
                return False
            del fr[len(fr) - 2:]
            return True

    def partition_log(self, partition: str) -> List[Tuple[str, str]]:
        """Retained (post-watermark) slots of ``partition`` in append
        order — what a durable restart scan must replay.  With no
        lifecycle armed there is no order metadata; fall back to the
        state map, sorted for determinism."""
        with self._lock:
            order = self._order.get(partition)
            if order is not None:
                wm = self.watermarks.get(partition, 0)
                return [(partition, t) for t in order[wm:]
                        if (partition, t) in self._state]
            return sorted(k for k in self._state if k[0] == partition)

    def is_truncated(self, key: Tuple[str, str]) -> bool:
        return key in self._gc_index

    def watermark_lag(self) -> int:
        """Slots retained above the watermark, summed over partitions —
        how far truncation is behind the append frontier."""
        with self._lock:
            return sum(len(order) - self.watermarks.get(p, 0)
                       for p, order in self._order.items())

    def log_data(self, partition: str, nbytes: int) -> None:
        with self._lock:
            self._data_bytes[partition] = self._data_bytes.get(partition, 0) + nbytes

    # -- bulk payloads (same surface as FileStore's data/ prefix) ----------
    def put_data(self, partition: str, name: str, payload: bytes) -> None:
        with self._lock:
            self._payloads[(partition, name)] = bytes(payload)

    def get_data(self, partition: str, name: str) -> bytes:
        with self._lock:
            try:
                return self._payloads[(partition, name)]
            except KeyError:
                raise FileNotFoundError(f"no payload {partition}/{name}") \
                    from None

    def snapshot(self) -> Dict[Tuple[str, str], Vote]:
        with self._lock:
            return {k: v[0] for k, v in self._state.items()}


class FileStore(_ControlledStoreMixin):
    """Directory-backed store: O_CREAT|O_EXCL create-if-absent is the CAS.

    Layout:  <root>/state/<partition>/<txn>            (one small state file)
             <root>/data/<partition>/<name>            (bulk shard payloads)

    This is the deployment target for the checkpoint committer: the directory
    stands in for a blob container; partitions are per-host prefixes and the
    ACL separation of §4 maps to the state/ vs data/ prefixes.
    """

    def __init__(self, root: str,
                 decisions: Optional[DecisionCacheConfig] = None,
                 lifecycle: Optional[LifecycleConfig] = None) -> None:
        self.root = root
        os.makedirs(os.path.join(root, "state"), exist_ok=True)
        os.makedirs(os.path.join(root, "data"), exist_ok=True)
        self.lifecycle = LifecycleConfig.coerce(lifecycle)
        self.torn_records = 0
        self.corrupt_records = 0
        self.scrub_repairs = 0
        self.quarantines = 0
        self.gc_truncations = 0
        self._corrupt_streak = 0
        self._torn_lock = threading.Lock()
        self.watermarks: Dict[str, int] = {}
        self.gc_log: List[GcEntry] = []
        self._gc_index: Dict[Tuple[str, str], GcEntry] = {}
        # A crash between the tmp write and os.replace strands a
        # `.tmp.<pid>.<tid>` file; sweep them at open (they were never
        # visible at the final path, so unlinking loses nothing).
        self.orphans_swept = self._sweep_orphans()
        self._init_control(decisions)

    def _sweep_orphans(self) -> int:
        n = 0
        for sub in ("state", "data"):
            top = os.path.join(self.root, sub)
            for dirpath, _dirs, files in os.walk(top):
                for name in files:
                    if ".tmp." in name:
                        try:
                            os.unlink(os.path.join(dirpath, name))
                            n += 1
                        except FileNotFoundError:
                            pass
        return n

    def _state_path(self, partition: str, txn: str) -> str:
        d = os.path.join(self.root, "state", partition)
        os.makedirs(d, exist_ok=True)
        return os.path.join(d, txn)

    def _payload(self, state: Vote, writer: str) -> bytes:
        lc = self.lifecycle
        if lc is not None and lc.checksums:
            return encode_record(state.value, writer)
        return f"{state.value}\n{writer}\n".encode()

    def log_once(self, partition: str, txn: str, state: Vote,
                 writer: str = "") -> Vote:
        return self._controlled_log_once(
            lambda: self._log_once_direct(partition, txn, state, writer),
            partition, txn, state, writer)

    def _log_once_direct(self, partition: str, txn: str, state: Vote,
                         writer: str = ""):
        path = self._state_path(partition, txn)
        payload = self._payload(state, writer)
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            existing = self._read(path, partition, txn)
            if existing is None:
                # The file exists but holds a torn (never-acknowledged)
                # create.  Complete the CAS in place under a local lock;
                # cross-*process* races on a torn create are out of scope
                # here (a production port would re-run O_EXCL after an
                # unlink-if-unchanged).
                with self._torn_lock:
                    existing = self._read(path, partition, txn)
                    if existing is None:
                        self._replace(path, payload)
                        return state
            return existing
        try:
            os.write(fd, payload)
            os.fsync(fd)
        finally:
            os.close(fd)
        return state

    def _replace(self, path: str, payload: bytes) -> None:
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic overwrite

    def log(self, partition: str, txn: str, state: Vote,
            writer: str = "") -> Vote:
        path = self._state_path(partition, txn)
        cur = self.read_state(partition, txn)
        if isinstance(cur, Vote) and cur.is_decision() and state != cur:
            # Decisions never regress to a vote nor flip to the other
            # decision (AC3 at the disk).
            return cur
        self._replace(path, self._payload(state, writer))
        self._note_control(partition, txn, state)
        return state

    def _read(self, path: str, partition: str = "", txn: str = ""):
        """-> Vote | CorruptRecord | None (torn/absent).  Never raises on
        damaged bytes: a zero-length or truncated file left by a torn
        create reads as None (the write was never acknowledged), and
        bit-rot of a full-length record surfaces as a typed
        `CorruptRecord` instead of a garbage Vote."""
        with open(path, "rb") as f:
            blob = f.read()
        if blob.startswith(RECORD_MAGIC):
            rec = decode_record(blob, partition, txn)
            if isinstance(rec, CorruptRecord):
                if rec.torn:
                    self.torn_records += 1
                    return None
                self.corrupt_records += 1
                self._corrupt_streak += 1
                lc = self.lifecycle
                if (lc is not None
                        and self._corrupt_streak >= lc.quarantine_threshold):
                    self.quarantines += 1
                    self._corrupt_streak = 0
                return rec
            return Vote(rec[0])
        lines = blob.decode(errors="replace").splitlines()
        if not lines or not lines[0]:
            self.torn_records += 1      # zero-length / truncated legacy file
            return None
        try:
            return Vote(lines[0])
        except ValueError:
            self.corrupt_records += 1
            return CorruptRecord(partition, txn, torn=False,
                                 detail=f"unparsable state {lines[0]!r}")

    def read_state(self, partition: str, txn: str) -> Optional[Vote]:
        path = self._state_path(partition, txn)
        try:
            result = self._read(path, partition, txn)
        except FileNotFoundError:
            result = None
        if result is None and self._gc_index:
            e = self._gc_index.get((partition, txn))
            if e is not None and e.decision is not None:
                return Vote(e.decision)   # truncation tombstone
        return result

    # -- durable-state lifecycle -------------------------------------------
    def _state_files(self):
        """Yield (partition, txn, path) for every retained state file."""
        top = os.path.join(self.root, "state")
        for part in sorted(os.listdir(top)):
            pdir = os.path.join(top, part)
            if not os.path.isdir(pdir):
                continue
            for name in sorted(os.listdir(pdir)):
                if ".tmp." in name or name == ".watermark":
                    continue
                yield part, name, os.path.join(pdir, name)

    def scrub(self) -> List[str]:
        """Verify every state file; unlink torn tails (unacknowledged
        writes) and return the paths of rotted records needing repair
        from a replica of the volume."""
        rotted: List[str] = []
        for part, txn, path in self._state_files():
            try:
                result = self._read(path, part, txn)
            except FileNotFoundError:
                continue
            if result is None:
                try:
                    os.unlink(path)
                except FileNotFoundError:
                    pass
            elif isinstance(result, CorruptRecord):
                rotted.append(path)
        return rotted

    def gc_pass(self, now: float = 0.0) -> int:
        """Truncate state files of settled txns (some slot of the txn
        holds a terminal decision on this volume), journaling each
        removal.  Files carry no total append order, so truncation is
        settled-only rather than strict-prefix; the per-partition
        watermark counts truncated slots and is persisted beside them."""
        lc = self.lifecycle
        if lc is None or not lc.gc:
            return 0
        slots: Dict[Tuple[str, str], Tuple[str, Optional[Vote]]] = {}
        for part, txn, path in self._state_files():
            try:
                result = self._read(path, part, txn)
            except FileNotFoundError:
                continue
            slots[(part, txn)] = (
                path, result if isinstance(result, Vote) else None)
        settled: Dict[str, Vote] = {}
        for (_p, t), (_path, vote) in slots.items():
            if vote is not None and vote.is_decision():
                settled.setdefault(t, vote)
        for e in self.gc_log:
            if e.decision is not None:
                settled.setdefault(e.txn, Vote(e.decision))
        n = 0
        removed_by_part: Dict[str, int] = {}
        for (part, txn), (path, vote) in sorted(slots.items()):
            dec = settled.get(txn)
            if dec is None:
                continue
            e = GcEntry(part, txn, None if vote is None else vote.value,
                        dec.value, True, at=now)
            self.gc_log.append(e)
            self._gc_index[(part, txn)] = e
            try:
                os.unlink(path)
            except FileNotFoundError:
                pass
            removed_by_part[part] = removed_by_part.get(part, 0) + 1
            n += 1
        for part, removed in removed_by_part.items():
            wm = self.watermarks.get(part, 0) + removed
            self.watermarks[part] = wm
            wpath = os.path.join(self.root, "state", part, ".watermark")
            self._replace(wpath, f"{wm}\n".encode())
        self.gc_truncations += n
        return n

    def watermark_lag(self) -> int:
        return sum(1 for _ in self._state_files())

    # Bulk payloads (checkpoint shards) ------------------------------------
    def data_path(self, partition: str, name: str) -> str:
        d = os.path.join(self.root, "data", partition)
        os.makedirs(d, exist_ok=True)
        return os.path.join(d, name)

    def put_data(self, partition: str, name: str, payload: bytes) -> str:
        path = self.data_path(partition, name)
        tmp = path + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        return path

    def get_data(self, partition: str, name: str) -> bytes:
        with open(self.data_path(partition, name), "rb") as f:
            return f.read()


# --------------------------------------------------------------------------
# Simulated storage service: MemoryStore semantics + LatencyModel timing
# --------------------------------------------------------------------------
class SimStorage(_DecisionCacheMixin):
    """Storage service as seen from inside the discrete-event simulator.

    A request issued at t has its CAS *applied* at t + service/2 (the moment
    the storage processes it) and its response delivered at t + service.
    Interleaving of concurrent LogOnce calls is therefore decided by apply
    times — exactly the data race the paper's termination protocol wins or
    loses by, and what the hypothesis tests perturb.
    """

    def __init__(self, sim, model: LatencyModel, seed: int = 0,
                 batch: Optional[BatchConfig] = None,
                 decisions: Optional[DecisionCacheConfig] = None,
                 lifecycle: Optional[LifecycleConfig] = None) -> None:
        self.sim = sim
        self.model = model
        self.store = MemoryStore(lifecycle=lifecycle)
        self.lifecycle = self.store.lifecycle
        self.rng = random.Random(seed)
        self.requests = 0
        self.round_trips = 0
        self.batch = batch or BatchConfig()
        self._ingress = (GroupCommitIngress(sim, self.batch, self._flush)
                         if self.batch.active else None)
        self._init_decisions(decisions, seed)

    # Each returns a sim Event yielding the op's result.
    def _op(self, service_ms: float, apply_fn, lane: Optional[str] = None,
            torn_key: Optional[Tuple[str, str]] = None):
        self.requests += 1
        self.round_trips += 1
        done = self.sim.event()
        result = {}

        def apply():
            result["value"] = apply_fn()

        if self.chaos is not None:
            # Chaos on the compute↔storage op path: a lost REQUEST never
            # applies; a lost RESPONSE applies but never answers — the
            # caller's event stays untriggered either way (only a timeout
            # + idempotent re-issue recovers it, which is what the
            # GuardedStorage wrapper provides).
            fate, extra = self.chaos.storage_op_fate(lane)
            if fate == "lose-request":
                return done
            if fate == "lose-response":
                self.sim._schedule(self.sim.now + service_ms / 2.0, apply)
                if torn_key is not None and self.chaos.torn_tail():
                    # Torn tail: the write applied but died mid-persist —
                    # the durable frame is truncated AFTER the apply and
                    # the response is lost, so the record was never
                    # acknowledged and treat-as-absent on re-read is sound.
                    self.sim._schedule(
                        self.sim.now + service_ms * 0.75,
                        lambda: self.store.tear_slot(torn_key))
                return done
            service_ms += extra
        self.sim._schedule(self.sim.now + service_ms / 2.0, apply)
        self.sim._schedule(self.sim.now + service_ms,
                           lambda: done.trigger(result.get("value")))
        return done

    def _flush(self, partition: str, ops: List[_BatchOp]):
        """ONE storage round trip carrying every op in ``ops``: base service
        time of the most expensive op kind, grown by ``batch_size_factor``
        per extra record; all ops apply in arrival order at t + service/2
        (so first-writer-wins CAS races resolve exactly as if the ops had
        been issued back to back) and every caller's reply — plus any vote
        forwarding — lands with the single response at t + service."""
        self.requests += len(ops)
        self.round_trips += 1
        base = max(self.model.conditional_write_ms if op.kind == "log_once"
                   else self.model.plain_write_ms for op in ops)
        n = sum(op.n_records for op in ops)
        ms = self.model.sample(self.rng, self.model.batched_write_ms(n, base))
        done = self.sim.event()

        def apply():
            for op in ops:
                if op.kind == "log_once":
                    op.result = self.store.log_once(op.partition, op.txn,
                                                    op.state, op.writer)
                else:
                    op.result = self.store.log(op.partition, op.txn,
                                               op.state, op.writer)
                self._note(op.partition, op.txn, op.result)

        def respond():
            for op in ops:
                op.done.trigger(op.result)
                if op.fwd is not None:
                    op.fwd(op.result)
            done.trigger(len(ops))

        self.sim._schedule(self.sim.now + ms / 2.0, apply)
        self.sim._schedule(self.sim.now + ms, respond)
        return done

    def _flush_single(self, op: _BatchOp):
        op.done = self.sim.event()
        self._flush(op.partition, [op])
        return op.done

    def _cached_answer(self, value: Vote, on_forward=None):
        """Post-decision LogOnce answered from the decision index: ONE
        cheap read round trip — no CAS, no serial-lane occupancy.  Samples
        a dedicated rng so the main service stream is untouched."""
        self._dindex.hits += 1
        self.requests += 1
        self.round_trips += 1
        ms = self.model.sample(self._cache_rng, self.model.read_ms)
        done = self.sim.event()
        self.sim._schedule(self.sim.now + ms, lambda: done.trigger(value))
        if on_forward is not None:
            done.subscribe(lambda e: on_forward(e.value))
        return done

    def _applied(self, partition: str, txn: str, fn):
        """Wrap a store apply so terminal results feed the decision index."""
        if self._dindex is None:
            return fn

        def wrapped():
            v = fn()
            self._dindex.note(partition, txn, v)
            return v

        return wrapped

    def log_once(self, partition: str, txn: str, state: Vote, writer: str = "",
                 forward_to: Optional[str] = None, on_forward=None):
        sfkey = (partition, txn, state.value)
        if self._dindex is not None:
            hit = self._dindex.lookup(txn)
            if hit is not None:
                # LogOnce "returns the existing value": the txn's log set
                # already holds a terminal record, so this attempt can only
                # read the decision — answer it without a CAS round.
                return self._recorded(self._cached_answer(hit, on_forward),
                                      "log_once", partition, txn, state,
                                      writer)
            shared = self._dindex.join(sfkey)
            if shared is not None:
                # Identical round already in flight (a racing terminator):
                # share its result — the joiner's CAS could never have
                # mutated the slot.
                self._dindex.singleflight_hits += 1
                self.requests += 1
                if on_forward is not None:
                    shared.subscribe(lambda e: on_forward(e.value))
                return self._recorded(shared, "log_once", partition, txn,
                                      state, writer)
        if self._ingress is not None:
            ev = self._ingress.submit(
                _BatchOp("log_once", partition, txn, state, writer,
                         fwd=on_forward))
        else:
            ms = self.model.sample(self.rng, self.model.conditional_write_ms)
            # Torn-tail faults target non-decision writes only: a decision
            # that applied may already have fed the decision index, and a
            # later tear would leave the cache serving an un-durable value.
            ev = self._op(ms, self._applied(
                partition, txn,
                lambda: self.store.log_once(partition, txn, state, writer)),
                lane=partition,
                torn_key=((partition, txn)
                          if not state.is_decision() else None))
            if on_forward is not None:
                # Vote forwarding (Table 3 cornus-opt1 / paxos-commit): the
                # service pushes the slot's decided value to ``forward_to``
                # in parallel with the reply to the writer.  A single
                # unreplicated service has no distinct acceptor/leader
                # position, so the forwarded copy lands when the response
                # does.
                ev.subscribe(lambda e: on_forward(e.value))
        if self._dindex is not None:
            self._dindex.lead(sfkey, ev)
        return self._recorded(self._observed(ev, lane=partition),
                              "log_once", partition, txn, state, writer)

    def log(self, partition: str, txn: str, state: Vote, writer: str = ""):
        if self._ingress is not None:
            return self._recorded(self._observed(self._ingress.submit(
                _BatchOp("log", partition, txn, state, writer)),
                lane=partition), "log", partition, txn, state, writer)
        ms = self.model.sample(self.rng, self.model.plain_write_ms)
        return self._recorded(self._observed(self._op(ms, self._applied(
            partition, txn,
            lambda: self.store.log(partition, txn, state, writer)),
            lane=partition),
            lane=partition), "log", partition, txn, state, writer)

    def read_state(self, partition: str, txn: str, writer: str = ""):
        # `writer` (the calling node) is unused here but part of the storage
        # API: the replicated store derives the caller's region from it.
        # Reads bypass the group-commit lanes (they don't hit the serial
        # log device).
        ms = self.model.sample(self.rng, self.model.read_ms)
        return self._recorded(self._op(ms, self._applied(
            partition, txn, lambda: self.store.read_state(partition, txn)),
            lane=partition), "read", partition, txn, None, writer)

    def log_batch(self, partition: str, txn: str, state: Vote, n_records: int,
                  writer: str = ""):
        """Coordinator-log variant (§5.6): n records batched in ONE write.

        One request (saves per-write round trips vs 2PC's sequential
        prepare-then-decision) but the payload carries every participant's
        redo records, so service time grows with the batch size — the exact
        amortization the ingress group-commit lanes reuse, so this is now
        just a pre-formed single-op batch submitted to the same flush path.
        """
        op = _BatchOp("log", partition, txn, state, writer,
                      n_records=n_records)
        if self._ingress is not None:
            return self._recorded(
                self._observed(self._ingress.submit(op), lane=partition),
                "log_batch", partition, txn, state, writer)
        return self._recorded(
            self._observed(self._flush_single(op), lane=partition),
            "log_batch", partition, txn, state, writer)

    # -- durable-state lifecycle (delegates to the backing MemoryStore) ----
    def gc_pass(self, now: Optional[float] = None) -> int:
        return self.store.gc_pass(self.sim.now if now is None else now)

    def scrub_pass(self) -> int:
        return self.store.scrub_pass()

    def bitflip(self, rng: random.Random) -> bool:
        return self.store.bitflip(rng)

    def tear_slot(self, key: Tuple[str, str]) -> bool:
        return self.store.tear_slot(key)

    def partition_log(self, partition: str) -> List[Tuple[str, str]]:
        return self.store.partition_log(partition)

    def is_truncated(self, key: Tuple[str, str]) -> bool:
        return self.store.is_truncated(key)

    def watermark_lag(self) -> int:
        return self.store.watermark_lag()

    @property
    def gc_log(self) -> List[GcEntry]:
        return self.store.gc_log

    @property
    def watermarks(self) -> Dict[str, int]:
        return self.store.watermarks

    @property
    def gc_truncations(self) -> int:
        return self.store.gc_truncations

    @property
    def torn_records(self) -> int:
        return self.store.torn_records

    @property
    def corrupt_records(self) -> int:
        return self.store.corrupt_records

    @property
    def scrub_repairs(self) -> int:
        return self.store.scrub_repairs

    @property
    def quarantines(self) -> int:
        return self.store.quarantines

    # -- ground truth for the history checker ------------------------------
    def snapshot(self) -> Dict[Tuple[str, str], Vote]:
        return self.store.snapshot()

    def writer_of(self, partition: str, txn: str) -> Optional[str]:
        return self.store.writer_of(partition, txn)


# --------------------------------------------------------------------------
# Replicated storage: quorum LogOnce over R replica logs (extended §6)
# --------------------------------------------------------------------------
# The extended paper argues Cornus ports to replicated storage services where
# LogOnce becomes a quorum operation: "the first value accepted by a majority
# of replicas wins" (Paxos-Commit-style, Gray & Lamport).  We implement the
# slot register as single-decree Paxos per (partition, txn): ballots make the
# participant-vs-termination CAS race safe under any interleaving of replica
# failures, which plain first-write-wins replicas cannot guarantee (a 1-1
# split across a 2-of-3 quorum has no winner without a second round).
#
# Ballots are ``(epoch, round, proposer_id)`` tuples — Multi-Paxos style.
# The *epoch* is a leadership term: whoever holds the epoch's lease holds an
# implicit phase-1 promise at round 1 for ALL current and future slots of
# the partition, so every slot costs one accept round (the phase-1-free
# fast path).  Within an epoch, a per-slot proposer (a termination CAS, a
# fallback after a lost batch) prepares at round >= 2 and beats the
# leaseholder's round-1 ballot on that slot alone — first-writer-wins races
# resolve exactly as before.  A new leader acquires epoch e+1 with ONE bulk
# ``prepare_epoch`` round (promoting the per-partition epoch ballot on a
# quorum), which supersedes every epoch-e ballot.
#
# Epoch 1 is the *implicit* initial lease: the slot's partition owner when
# compute coordinates replication ("coloc", the paper's participant-
# coordinates-replication rows of Table 3), or the storage service's
# initial leader replica in leader mode.  Its holder skips phase 1 from the
# first op with no acquisition round — which is what keeps the no-failure
# timing bit-identical to the single-epoch implementation and reproduces
# Table 3's 2pc=5 / cornus=3 / 2pc-coloc=3 / cornus-coloc=2 RTT totals.
#
# Leases are time-bounded (sim clock / wall clock) but safety NEVER rests
# on lease timing: an expired or superseded leaseholder's round-1 accepts
# simply fail (the replicas promised a higher ballot) and the op falls back
# to the full prepare+accept proposer, preserving single-winner-per-slot.

Ballot = Tuple[int, int, int]
OWNER_BALLOT: Ballot = (1, 1, 0)

# ``QuorumUnavailable`` moved to ``control`` (the lease keeper catches it
# without importing this module); re-exported here unchanged.


class _Slot:
    """Per-(partition, txn) state on ONE replica."""

    __slots__ = ("promised", "acc_ballot", "acc_value", "decided",
                 "value", "gen", "writer", "corrupt")

    def __init__(self) -> None:
        self.promised: Ballot = OWNER_BALLOT   # implicit phase-1 for owner
        self.acc_ballot: Optional[Ballot] = None
        self.acc_value: Optional[Vote] = None
        self.decided = False
        self.value: Optional[Vote] = None      # visible log record
        self.gen = 0                           # owner-assigned LSN of `value`
        self.writer = ""
        # Bit-rot flag: the visible record failed its checksum.  Only the
        # VISIBLE value is hidden from readers; acceptor metadata
        # (promised/acc_value/decided) survives — corruption of the log
        # record must not let a conflicting accept past the decided-guard.
        self.corrupt = False


class ReplicaLog:
    """One storage replica: a Paxos acceptor plus a visible MemoryStore-like
    log.  The first value of a slot is fixed by consensus (log_once); later
    blind ``write``s overwrite it with sticky-decision semantics (the 2PC /
    decision-record path).  Thread-safe; liveness is tracked by the enclosing
    store, a failed replica simply stops being called (disk survives)."""

    def __init__(self, index: int) -> None:
        self.index = index
        self._lock = threading.Lock()
        self._slots: Dict[Tuple[str, str], _Slot] = {}
        self._data_bytes: Dict[str, int] = {}
        self._payloads: Dict[Tuple[str, str], bytes] = {}
        # Highest epoch ballot promised — covers every slot, current and
        # future, of every partition this replica hosts (the bulk phase-1
        # of Multi-Paxos leases).  Starts at OWNER_BALLOT: the implicit
        # epoch-1 lease of the natural owner.
        self.epoch_promised: Ballot = OWNER_BALLOT

    def _slot(self, key: Tuple[str, str]) -> _Slot:
        s = self._slots.get(key)
        if s is None:
            s = self._slots[key] = _Slot()
        return s

    # -- acceptor ----------------------------------------------------------
    def prepare(self, key, ballot: Ballot):
        """-> (ok, acc_ballot, acc_value, visible_value, gen, decided,
        promised) — ``promised`` is the effective promise (max of the
        slot's own ballot and the epoch ballot), so a rejected proposer
        learns the epoch to exceed instead of blindly bumping rounds."""
        with self._lock:
            s = self._slot(key)
            ok = ballot > max(s.promised, self.epoch_promised)
            if ok:
                s.promised = ballot
            vis = None if s.corrupt else s.value
            return (ok, s.acc_ballot, s.acc_value, vis, s.gen,
                    s.decided, max(s.promised, self.epoch_promised))

    def prepare_epoch(self, ballot: Ballot):
        """Bulk phase-1 for a leadership epoch: promote the epoch ballot
        covering all current and future slots in ONE request.

        -> (ok, promised, inflight) where ``inflight`` lists
        (key, acc_ballot, acc_value) for every undecided slot holding an
        accepted value — the Multi-Paxos recovery obligation the new
        leaseholder must complete (re-propose at its epoch ballot) before
        serving fresh values on those slots."""
        with self._lock:
            ok = ballot > self.epoch_promised
            if ok:
                self.epoch_promised = ballot
            inflight = [(key, s.acc_ballot, s.acc_value)
                        for key, s in self._slots.items()
                        if s.acc_value is not None and not s.decided]
            return (ok, self.epoch_promised, inflight)

    def accept(self, key, ballot: Ballot, value: Vote) -> bool:
        with self._lock:
            s = self._slot(key)
            if ballot < max(s.promised, self.epoch_promised):
                return False
            if s.acc_ballot == ballot and s.acc_value not in (None, value):
                return False   # same-ballot different-value: never diverge
            if s.decided:
                # Consensus already reached here: a different value can
                # only come from a round-1 accept that skipped this slot's
                # phase-1 history (a NEW epoch's leaseholder serving a
                # fresh caller value).  Reject it — the proposer falls
                # back, runs prepare, and adopts the chosen value.  The
                # learned value is authoritative (acc_value may briefly
                # hold a losing round-1 value until learn aligns it).
                chosen = s.value if s.value is not None else s.acc_value
                if chosen is not None and value != chosen:
                    return False
            s.promised = ballot
            s.acc_ballot, s.acc_value = ballot, value
            return True

    def learn(self, key, value: Vote, writer: str = "") -> None:
        """Decision reached at a quorum: pin the slot's first value."""
        with self._lock:
            s = self._slot(key)
            s.decided = True
            if s.gen == 0:
                s.value, s.gen, s.writer = value, 1, writer
            # Align the acceptor state with the chosen value: a competing
            # round-1 accept may have parked a LOSING value here at a
            # higher ballot (a post-failover leaseholder serving a raced
            # CAS on a replica that missed the decide); once the decision
            # is known, any future adoption must carry the chosen value.
            if s.acc_value is not None and s.acc_value != value:
                s.acc_value = value
            if s.corrupt:
                # Learning the chosen value rewrites the rotted record.
                s.value, s.gen = value, max(s.gen, 1)
                s.corrupt = False

    # -- visible log -------------------------------------------------------
    def write(self, key, value: Vote, gen: int, writer: str = "") -> Vote:
        """Blind overwrite at generation ``gen``; decisions never regress
        to a vote nor flip to the other decision (AC3 at the disk)."""
        with self._lock:
            s = self._slot(key)
            if (not s.corrupt and s.value is not None
                    and s.value.is_decision() and value != s.value):
                return s.value
            if gen > s.gen or s.corrupt:
                s.value, s.gen, s.writer = value, max(gen, s.gen), writer
                s.corrupt = False
            return s.value if s.value is not None else value

    def read(self, key):
        with self._lock:
            s = self._slots.get(key)
            if s is None or s.corrupt:
                return (None, 0, False)
            return (s.value, s.gen, s.decided)

    def repair(self, key, value: Vote, gen: int, decided: bool,
               writer: str = "") -> None:
        """Read-repair push: adopt a fresher-or-equal merged view."""
        with self._lock:
            s = self._slot(key)
            if decided:
                s.decided = True
            if (gen > s.gen or (s.value is None and value is not None)
                    or (s.corrupt and value is not None)):
                s.value, s.gen, s.writer = value, max(gen, 1), writer
                s.corrupt = False

    # -- durable-state lifecycle -------------------------------------------
    def truncate(self, key) -> bool:
        """GC: drop the slot entirely (its decision is journaled by the
        enclosing store's watermark pass before this is called)."""
        with self._lock:
            return self._slots.pop(key, None) is not None

    def corrupt_slot(self, key) -> bool:
        """Chaos hook: rot the slot's visible record (checksum failure on
        next read).  Acceptor metadata survives — see `_Slot.corrupt`."""
        with self._lock:
            s = self._slots.get(key)
            if s is None or s.value is None:
                return False
            s.corrupt = True
            return True

    def corrupt_keys(self):
        with self._lock:
            return [k for k, s in self._slots.items() if s.corrupt]

    def partition_digests(self) -> Dict[str, int]:
        """Per-partition CRC32 over the replica's visible slot contents —
        what the anti-entropy scrubber exchanges to find divergence
        cheaply.  A corrupt record digests as empty, so rot always shows
        up as a digest mismatch against an intact peer."""
        with self._lock:
            lines: Dict[str, List[str]] = {}
            for (p, t), s in sorted(self._slots.items()):
                v = "" if (s.corrupt or s.value is None) else s.value.value
                lines.setdefault(p, []).append(
                    f"{t}:{v}:{s.gen}:{int(s.decided)}:{int(s.corrupt)}")
            return {p: zlib.crc32("\n".join(ls).encode())
                    for p, ls in lines.items()}

    def log_data(self, partition: str, nbytes: int) -> None:
        with self._lock:
            self._data_bytes[partition] = \
                self._data_bytes.get(partition, 0) + nbytes

    # -- bulk payloads (checkpoint shards on this replica's volume) --------
    def put_data(self, partition: str, name: str, payload: bytes,
                 version: int = 1) -> None:
        with self._lock:
            key = (partition, name)
            cur = self._payloads.get(key)
            if cur is None or version >= cur[0]:
                self._payloads[key] = (version, bytes(payload))

    def get_data(self, partition: str, name: str
                 ) -> Optional[Tuple[int, bytes]]:
        """-> (version, payload) so quorum readers can pick the freshest
        copy (a recovered volume may hold a stale rewrite)."""
        with self._lock:
            return self._payloads.get((partition, name))

    def drop_data(self) -> None:
        """Model a lost volume: the replica's shard payloads are gone
        (state slots survive separately, like a lost data disk)."""
        with self._lock:
            self._payloads.clear()

    def keys(self):
        with self._lock:
            return list(self._slots.keys())

    def data_keys(self):
        with self._lock:
            return list(self._payloads.keys())


def merge_reads(reads: Sequence[Tuple[Optional[Vote], int, bool]]):
    """Merge per-replica (value, gen, decided) into one view.

    A decision record anywhere wins (decisions are unique and sticky);
    otherwise the freshest (max-gen) record; `decided` is OR-ed.
    """
    value, gen, decided = None, 0, False
    for v, g, d in reads:
        decided = decided or d
        if v is None:
            continue
        better = (value is None or g > gen
                  or (v.is_decision() and not value.is_decision()))
        if value is not None and value.is_decision() and not v.is_decision():
            better = False
        if better:
            value, gen = v, g
    return value, gen, decided


@dataclass
class StoreLease:
    """One leadership epoch over a ``ReplicatedStore``/``ReplicatedSimStorage``.

    Holding a valid lease grants the phase-1-free fast path (round-1
    accepts at ``ballot``) for EVERY slot; validity is advisory only —
    expiry or preemption by a higher epoch costs round trips, never
    safety, because replicas enforce the ballot order regardless."""

    epoch: int
    holder: str                  # writer id (threaded) / replica idx (sim)
    ballot: Ballot
    expires_at: float            # time.monotonic() (threaded) / sim.now

    def valid_at(self, now: float) -> bool:
        return now < self.expires_at


@dataclass(frozen=True)
class MembershipConfig:
    """One quorum-membership configuration of a replicated store.

    Membership is a first-class, versioned object (Marlin-style): a config
    change is an epoch bump whose bulk ``prepare_epoch`` carries the new
    replica set, installed with a CAS on ``config_id`` — two concurrent
    reconfigurations cannot both win.  ``replica_ids`` index into the
    store's replica table; retired ids are never reused, so a removed
    replica's volume can hold arbitrarily stale state without ever being
    consulted (or counted toward a quorum) again.
    """

    config_id: int
    replica_ids: Tuple[int, ...]

    def __post_init__(self) -> None:
        ids = tuple(sorted(set(self.replica_ids)))
        if not ids:
            raise ValueError("membership needs at least one replica")
        object.__setattr__(self, "replica_ids", ids)

    @property
    def n(self) -> int:
        return len(self.replica_ids)

    @property
    def quorum(self) -> int:
        return self.n // 2 + 1

    def quorum_of(self, ids) -> bool:
        """True when ``ids`` contains a majority of THIS config."""
        members = set(self.replica_ids)
        return sum(1 for i in ids if i in members) >= self.quorum


# Bulk state-transfer streaming model (sim): a joiner pulls its catch-up
# image as TRANSFER_STREAMS parallel chunk streams of TRANSFER_CHUNK
# records each — wall time is one RTT plus ceil(n / (chunk*streams))
# chunk-batched service times, NOT one log write per record.
TRANSFER_CHUNK = 256
TRANSFER_STREAMS = 8


class ReplicatedStore(_ControlledStoreMixin):
    """Majority-quorum store over R ``ReplicaLog``s (threaded deployments).

    Same three-operation surface as ``MemoryStore``; ``log_once`` runs the
    Paxos proposer synchronously against the alive replicas, ``log`` is a
    quorum overwrite with owner-assigned generations, ``read_state`` is a
    quorum read with lazy repair of stale replicas.  ``fail_replica`` /
    ``recover_replica`` model per-replica outages; state survives an outage
    (crash, not amnesia), recovered replicas catch up via read repair.

    ``acquire_lease(holder)`` promotes a fresh epoch ballot on a quorum in
    one bulk prepare round (wall-clock bounded); while the lease is valid,
    every ``log_once`` issued with ``writer == holder`` skips phase 1 even
    for slots the writer does not own.  ``put_data``/``get_data`` replicate
    bulk shard payloads to every alive replica volume, so the checkpoint
    committer survives the loss of any minority of volumes.

    Membership is elastic: ``reconfigure`` (and the ``add_replica`` /
    ``remove_replica`` / ``set_replication`` conveniences) installs a new
    ``MembershipConfig`` as an epoch bump — joiners first catch up via
    recovery-driven state transfer (bulk slot + versioned ``put_data``
    copy), then one bulk ``prepare_epoch`` carrying the new membership is
    promised by a majority of the old AND the new config (joint quorum),
    in-flight slots are completed under it, and the lease hands over to
    the prior holder so the fast path survives the change.
    """

    def __init__(self, n_replicas: int = 3, seed: int = 0,
                 max_rounds: int = 256,
                 decisions: Optional[DecisionCacheConfig] = None,
                 membership: Optional[Sequence[int]] = None,
                 lifecycle: Optional[LifecycleConfig] = None) -> None:
        assert n_replicas >= 1
        ids = (tuple(membership) if membership is not None
               else tuple(range(n_replicas)))
        self._membership = MembershipConfig(1, ids)
        table = max(self._membership.replica_ids) + 1
        self.replicas = [ReplicaLog(i) for i in range(table)]
        self._alive = [True] * table
        self._gens: Dict[Tuple[str, str], int] = {}
        self._glock = threading.Lock()
        self._pids = itertools.count(1)
        self._rng = random.Random(seed)
        self.max_rounds = max_rounds
        self.cas_attempts = 0
        self.cas_losses = 0
        self._lease: Optional[StoreLease] = None
        self.lease_acquisitions = 0
        self.fast_path_ops = 0
        self.fallback_ops = 0
        # Slots whose in-flight value could NOT be re-proposed at quorum
        # during lease acquisition: the fast path must avoid them (a
        # round-1 accept there could contradict a possibly-chosen value);
        # the full proposer adopts the accepted value correctly.
        self._pinned: set = set()
        # Reconfiguration bookkeeping: one change at a time (the lock), a
        # full config history, and counters the benches surface.
        self._reconfig_lock = threading.RLock()
        self.membership_history: List[MembershipConfig] = [self._membership]
        self.reconfigurations = 0
        self.state_transfers = 0
        # Durable-state lifecycle (GC watermark + anti-entropy scrub).
        self.lifecycle = LifecycleConfig.coerce(lifecycle)
        self._order: Dict[str, List[str]] = {}
        self._order_seen: set = set()
        self.watermarks: Dict[str, int] = {}
        self.gc_log: List[GcEntry] = []
        self._gc_index: Dict[Tuple[str, str], GcEntry] = {}
        self.gc_truncations = 0
        self.scrub_repairs = 0
        self.quarantines = 0
        self.corrupt_records = 0
        self._init_control(decisions)

    @property
    def membership(self) -> MembershipConfig:
        return self._membership

    @property
    def n(self) -> int:
        return self._membership.n

    @property
    def quorum(self) -> int:
        return self._membership.quorum

    # -- replica liveness --------------------------------------------------
    def fail_replica(self, i: int) -> None:
        self._alive[i] = False

    def recover_replica(self, i: int) -> None:
        self._alive[i] = True

    def alive_replicas(self) -> List[ReplicaLog]:
        m = self._membership
        return [self.replicas[i] for i in m.replica_ids if self._alive[i]]

    def alive_ids(self) -> List[int]:
        m = self._membership
        return [i for i in m.replica_ids if self._alive[i]]

    def member_replicas(self) -> List[ReplicaLog]:
        """Every member's replica log, down ones included (crash, not
        amnesia — the disk survives an outage)."""
        return [self.replicas[i] for i in self._membership.replica_ids]

    # -- quorum read -------------------------------------------------------
    def _read_merge(self, key):
        if self._gc_index:
            e = self._gc_index.get(key)
            if e is not None and e.decision is not None:
                # Truncated slot: the journal entry is the tombstone.  Any
                # replica still holding the slot (e.g. it was down during
                # the truncation pass) is lazily truncated here.
                for r in self.member_replicas():
                    r.truncate(key)
                return (Vote(e.decision), 1, True,
                        len(self.alive_replicas()))
        alive = self.alive_replicas()
        reads = [(r, r.read(key)) for r in alive]
        value, gen, decided = merge_reads([rd for _, rd in reads])
        if value is not None or decided:
            for r, (v, g, d) in reads:       # stale-replica read repair
                if g < gen or (decided and not d):
                    r.repair(key, value, gen, decided)
        return value, gen, decided, len(alive)

    # -- leadership leases (epoch ballots, wall-clock bounded) -------------
    def current_lease(self) -> Optional[StoreLease]:
        lease = self._lease
        if lease is not None and lease.valid_at(time.monotonic()):
            return lease
        return None

    def acquire_lease(self, holder: str,
                      duration_s: float = 5.0) -> StoreLease:
        """One bulk prepare round: promote a fresh epoch ballot on a quorum
        (covering all current and future slots) and complete any in-flight
        undecided slots at it — then ``holder`` serves every slot with
        round-1 accepts until the lease expires or is superseded."""
        with self._glock:
            epoch = self._lease.epoch if self._lease is not None else 1
        for attempt in range(self.max_rounds):
            alive = self.alive_replicas()
            if len(alive) < self.quorum:
                raise QuorumUnavailable("majority down during lease acquire")
            epoch += 1
            ballot: Ballot = (epoch, 1, next(self._pids))
            oks = 0
            inflight: Dict[Tuple[str, str], Tuple[Ballot, Vote]] = {}
            for r in alive:
                ok, promised, acc = r.prepare_epoch(ballot)
                if ok:
                    oks += 1
                    for key, ab, av in acc:
                        cur = inflight.get(key)
                        if cur is None or ab > cur[0]:
                            inflight[key] = (ab, av)
                else:
                    epoch = max(epoch, promised[0])
            if oks < self.quorum:
                time.sleep(self._rng.random() * 1e-4 * (attempt + 1))
                continue
            # Multi-Paxos recovery: re-propose in-flight values at the new
            # epoch ballot so later round-1 accepts can never contradict a
            # value the previous epoch may already have chosen.  A slot
            # whose re-propose misses quorum stays PINNED: the lease is
            # still useful for every other slot, but fast-path serving of
            # a pinned slot could overwrite the unrecovered value.
            for key, (_ab, av) in sorted(inflight.items()):
                acks = [r for r in self.alive_replicas()
                        if r.accept(key, ballot, av)]
                if len(acks) >= self.quorum:
                    for r in self.alive_replicas():
                        r.learn(key, av)
                    self._pinned.discard(key)
                else:
                    self._pinned.add(key)
            lease = StoreLease(epoch, holder, ballot,
                               time.monotonic() + duration_s)
            with self._glock:
                # Install-if-newer: a concurrent acquirer whose ballot
                # already superseded ours on the replicas must not be
                # overwritten by our stale (and unusable) lease.
                cur = self._lease
                installed = cur is None or ballot > cur.ballot
                if installed:
                    self._lease = lease
                else:
                    epoch = max(epoch, cur.epoch)
            if not installed:
                # Lost the install race: retry above the winner so the
                # caller really ends up holding the lease it asked for.
                time.sleep(self._rng.random() * 1e-4 * (attempt + 1))
                continue
            self.lease_acquisitions += 1
            return lease
        raise QuorumUnavailable(
            f"no lease after {self.max_rounds} rounds")

    # -- elastic membership (versioned, CAS-installed config changes) ------
    def _state_transfer(self, i: int, donors_ids: Sequence[int]) -> int:
        """Recovery-driven catch-up: bulk-copy the donors' merged slot
        state and their freshest payload versions onto replica ``i`` —
        a full image push with versioned cutover, not lazy read repair.
        Returns the number of records moved."""
        donors = [self.replicas[j] for j in donors_ids
                  if self._alive[j] and j != i]
        target = self.replicas[i]
        moved = 0
        keys = set()
        for d in donors:
            keys.update(d.keys())
        for k in keys:
            if k in self._gc_index:
                continue    # truncated: the journal entry is authoritative
            v, g, dec = merge_reads([d.read(k) for d in donors])
            if v is not None or dec:
                target.repair(k, v, g, dec)
                moved += 1
        if self._gc_index:
            # Anti-resurrection sweep: a rejoiner must not re-serve slots
            # the watermark already truncated cluster-wide.
            for k in target.keys():
                if k in self._gc_index:
                    target.truncate(k)
        pkeys = set()
        for d in donors:
            pkeys.update(d.data_keys())
        for (partition, name) in pkeys:
            best: Optional[Tuple[int, bytes]] = None
            for d in donors:
                got = d.get_data(partition, name)
                if got is not None and (best is None or got[0] > best[0]):
                    best = got
            if best is not None:
                # put_data keeps the max version, so a racing rewrite with
                # a higher version is never clobbered (versioned cutover).
                target.put_data(partition, name, best[1], version=best[0])
                moved += 1
        self.state_transfers += 1
        return moved

    def revive_replica(self, i: int) -> int:
        """Bring a crashed member back AND restore its volume through the
        same recovery-driven state transfer a joiner gets.  Plain
        ``recover_replica`` models a crash (disk intact, lazy read repair
        fills gaps); revive models a replacement volume that must not
        serve stale state before it caught up."""
        self._alive[i] = True
        return self._state_transfer(i, self._membership.replica_ids)

    def reconfigure(self, new_ids: Sequence[int], holder: str = "",
                    duration_s: float = 5.0) -> MembershipConfig:
        """Install a new membership as an epoch bump.

        Sequence: grow the replica table for joiners → state-transfer the
        old config's image onto each joiner → one bulk ``prepare_epoch``
        promised by a majority of the old AND new config (the epoch bump
        that carries the new membership) → complete in-flight undecided
        slots under it → CAS-install the ``MembershipConfig`` (config_id
        + 1) and hand the lease to ``holder`` (default: the prior valid
        leaseholder) so the fast path survives the change.

        Safety: any two old-config majorities intersect, so a proposer
        still running on a pre-bump ballot meets a promoted replica and
        falls back; retired replicas are no longer read, repaired, or
        counted toward any quorum, so their stale writes can never be
        chosen under the new config.
        """
        with self._reconfig_lock:
            old = self._membership
            new = MembershipConfig(old.config_id + 1, tuple(new_ids))
            if new.replica_ids == old.replica_ids:
                return old
            with self._glock:
                for i in new.replica_ids:
                    while len(self.replicas) <= i:
                        self.replicas.append(ReplicaLog(len(self.replicas)))
                        self._alive.append(True)
            joiners = [i for i in new.replica_ids
                       if i not in old.replica_ids]
            for i in joiners:
                self._state_transfer(i, old.replica_ids)
            if not holder:
                lease = self.current_lease()
                holder = lease.holder if lease is not None else "reconfig"
            lease = self._joint_epoch_bump(old, new, holder, duration_s)
            # Delta pass: slots decided between the image copy and the
            # bump reached only old members; close the gap before the
            # joiners start counting toward read quorums.
            for i in joiners:
                self._state_transfer(i, old.replica_ids)
            with self._glock:
                if self._membership.config_id != old.config_id:
                    # CAS failed: somebody else installed concurrently
                    # (cannot happen under _reconfig_lock; kept as the
                    # invariant the install is defined by).
                    raise QuorumUnavailable("membership CAS lost")
                self._membership = new
                self.membership_history.append(new)
                cur = self._lease
                if cur is None or lease.ballot > cur.ballot:
                    self._lease = lease     # lease handover across configs
            self.reconfigurations += 1
            return new

    def _joint_epoch_bump(self, old: MembershipConfig,
                          new: MembershipConfig, holder: str,
                          duration_s: float) -> StoreLease:
        """One bulk prepare over the union of both configs, requiring a
        majority of EACH; in-flight undecided slots are re-proposed at the
        new ballot in both quorums (the Multi-Paxos recovery obligation,
        joint so neither config can contradict the completion)."""
        union_ids = sorted(set(old.replica_ids) | set(new.replica_ids))
        with self._glock:
            epoch = self._lease.epoch if self._lease is not None else 1
        for attempt in range(self.max_rounds):
            alive = [i for i in union_ids if self._alive[i]]
            if not (old.quorum_of(alive) and new.quorum_of(alive)):
                raise QuorumUnavailable(
                    "joint quorum unreachable for reconfiguration")
            epoch += 1
            ballot: Ballot = (epoch, 1, next(self._pids))
            ok_ids: List[int] = []
            inflight: Dict[Tuple[str, str], Tuple[Ballot, Vote]] = {}
            for i in alive:
                ok, promised, acc = self.replicas[i].prepare_epoch(ballot)
                if ok:
                    ok_ids.append(i)
                    for key, ab, av in acc:
                        cur = inflight.get(key)
                        if cur is None or ab > cur[0]:
                            inflight[key] = (ab, av)
                else:
                    epoch = max(epoch, promised[0])
            if not (old.quorum_of(ok_ids) and new.quorum_of(ok_ids)):
                time.sleep(self._rng.random() * 1e-4 * (attempt + 1))
                continue
            for key, (_ab, av) in sorted(inflight.items()):
                acks = [i for i in union_ids
                        if self._alive[i]
                        and self.replicas[i].accept(key, ballot, av)]
                if old.quorum_of(acks) and new.quorum_of(acks):
                    for i in union_ids:
                        if self._alive[i]:
                            self.replicas[i].learn(key, av)
                    self._pinned.discard(key)
                else:
                    self._pinned.add(key)
            self.lease_acquisitions += 1
            return StoreLease(epoch, holder, ballot,
                              time.monotonic() + duration_s)
        raise QuorumUnavailable(
            f"no joint epoch bump after {self.max_rounds} rounds")

    def add_replica(self, holder: str = "") -> int:
        """Grow the quorum by one fresh replica (never a retired id);
        returns the new replica's index."""
        with self._reconfig_lock:
            new_id = len(self.replicas)
            self.reconfigure(self._membership.replica_ids + (new_id,),
                             holder=holder)
            return new_id

    def remove_replica(self, i: int, holder: str = "") -> MembershipConfig:
        """Retire member ``i``: its volume stays on disk but it leaves the
        replica set permanently (retired ids are never reused)."""
        with self._reconfig_lock:
            ids = tuple(j for j in self._membership.replica_ids if j != i)
            if len(ids) == self._membership.n:
                raise ValueError(f"replica {i} is not a member")
            return self.reconfigure(ids, holder=holder)

    def set_replication(self, n: int, holder: str = "") -> MembershipConfig:
        """Scale the replica set to ``n``: grows with fresh replicas,
        shrinks from the highest member ids (never the leader-colocated
        lowest member)."""
        assert n >= 1
        with self._reconfig_lock:
            ids = list(self._membership.replica_ids)
            if len(ids) > n:
                ids = ids[:n]
            nxt = len(self.replicas)
            while len(ids) < n:
                ids.append(nxt)
                nxt += 1
            return self.reconfigure(tuple(ids), holder=holder)

    # -- operations --------------------------------------------------------
    def log_once(self, partition: str, txn: str, state: Vote,
                 writer: str = "") -> Vote:
        # The control plane wraps the WHOLE quorum operation: a cache hit
        # answers without any replica round, a singleflight joiner shares
        # the leader's round (including a QuorumUnavailable, if it raised).
        result = self._controlled_log_once(
            lambda: self._log_once_quorum(partition, txn, state, writer),
            partition, txn, state, writer)
        return result

    def _track(self, key: Tuple[str, str]) -> None:
        """Record first-write append order per partition — what the GC
        low-watermark advances over."""
        if self.lifecycle is None:
            return
        with self._glock:
            if key not in self._order_seen:
                self._order_seen.add(key)
                self._order.setdefault(key[0], []).append(key[1])

    def _log_once_quorum(self, partition: str, txn: str, state: Vote,
                         writer: str = "") -> Vote:
        key = (partition, txn)
        self._track(key)
        self.cas_attempts += 1
        value, _, decided, n_alive = self._read_merge(key)
        if n_alive < self.quorum:
            raise QuorumUnavailable(f"{n_alive}/{self.n} replicas alive")
        if value is not None and (decided or value.is_decision()):
            if value != state:
                self.cas_losses += 1
            return value
        lease = self.current_lease()
        use_lease = lease is not None and lease.holder == writer
        fast_ballot = lease.ballot if use_lease else OWNER_BALLOT
        # The partition owner's implicit fast path only exists in the
        # epoch-1 world: once ANY lease was acquired, every replica's
        # epoch promise permanently exceeds OWNER_BALLOT and a round-1
        # attempt at it is a guaranteed-dead quorum round.
        owner = (use_lease or (writer == partition
                               and self._lease is None)) \
            and key not in self._pinned
        first = self._propose(key, state, owner=owner,
                              fast_ballot=fast_ballot)
        # A concurrent gc_pass may have truncated the slot mid-propose
        # (emptying the decided-guard our accept raced against): the
        # journaled decision is authoritative, never the raced result.
        e = self._gc_index.get(key) if self._gc_index else None
        if e is not None and e.decision is not None:
            first = Vote(e.decision)
        if first != state:
            self.cas_losses += 1
            return first
        # The decided first value may already have been overwritten by a
        # decision record (can't happen before we return in the protocols,
        # but a quorum read keeps the API honest).
        value, _, _, _ = self._read_merge(key)
        return value if value is not None else first

    def _propose(self, key, my_value: Vote, owner: bool,
                 fast_ballot: Ballot = OWNER_BALLOT) -> Vote:
        pid = None
        # Seed the fallback epoch from the store's newest lease too — a
        # non-leaseholder starting at epoch 1 after an acquisition would
        # burn a guaranteed-rejected prepare round just to learn it.
        lease = self._lease
        epoch = max(fast_ballot[0],
                    lease.epoch if lease is not None else 1)
        fell_back = False
        for attempt in range(self.max_rounds):
            alive = self.alive_replicas()
            if len(alive) < self.quorum:
                raise QuorumUnavailable("majority down during propose")
            adopted = my_value
            if owner and attempt == 0:
                ballot = fast_ballot           # implicit phase 1
                voters = alive
            else:
                if not fell_back:
                    fell_back = True
                    self.fallback_ops += 1
                if pid is None:
                    pid = next(self._pids)
                ballot = (epoch, attempt + 2, pid)
                voters, best, seen = [], None, None
                for r in alive:
                    ok, ab, av, vis, gen, decided, promised = \
                        r.prepare(key, ballot)
                    if vis is not None and decided:
                        self._pinned.discard(key)
                        for rr in self.alive_replicas():
                            rr.learn(key, vis)   # converge stragglers
                        return vis             # already chosen and visible
                    if ok:
                        voters.append(r)
                    elif promised[0] > epoch:
                        epoch = promised[0]    # jump stale epochs, not rounds
                    if av is not None and (best is None or ab > best[0]):
                        best = (ab, av)
                    if vis is not None and seen is None:
                        seen = vis
                if len(voters) < self.quorum:
                    time.sleep(self._rng.random() * 1e-4 * (attempt + 1))
                    continue
                adopted = best[1] if best else (seen or my_value)
            acks = sum(1 for r in voters if r.accept(key, ballot, adopted))
            if acks >= self.quorum:
                if owner and attempt == 0:
                    self.fast_path_ops += 1
                else:
                    self._pinned.discard(key)   # settled by a full round
                for r in self.alive_replicas():
                    r.learn(key, adopted)
                return adopted
            time.sleep(self._rng.random() * 1e-4 * (attempt + 1))
        raise QuorumUnavailable(f"no decision after {self.max_rounds} rounds")

    def log(self, partition: str, txn: str, state: Vote,
            writer: str = "") -> Vote:
        key = (partition, txn)
        self._track(key)
        cur, gen, decided, n_alive = self._read_merge(key)
        if n_alive < self.quorum:
            raise QuorumUnavailable(f"{n_alive}/{self.n} replicas alive")
        if cur is not None and cur.is_decision() and state != cur:
            # Decisions never regress to a vote nor flip to the other
            # decision (AC3 at the disk).
            return cur
        with self._glock:
            g = self._gens[key] = max(self._gens.get(key, 0), gen) + 1
        results = [r.write(key, state, g, writer)
                   for r in self.alive_replicas()]
        if len(results) < self.quorum:
            raise QuorumUnavailable("majority down during log")
        e = self._gc_index.get(key) if self._gc_index else None
        if e is not None and e.decision is not None:
            # Raced a concurrent truncation: the journal is authoritative.
            return Vote(e.decision)
        self._note_control(partition, txn, state)
        return state

    def read_state(self, partition: str, txn: str) -> Optional[Vote]:
        value, _, _, n_alive = self._read_merge((partition, txn))
        if n_alive < self.quorum:
            raise QuorumUnavailable(f"{n_alive}/{self.n} replicas alive")
        return value

    def log_data(self, partition: str, nbytes: int) -> None:
        for r in self.alive_replicas():
            r.log_data(partition, nbytes)

    # -- bulk payloads (checkpoint shards, replicated R ways) --------------
    def put_data(self, partition: str, name: str, payload: bytes) -> None:
        alive = self.alive_replicas()
        if len(alive) < self.quorum:
            raise QuorumUnavailable(
                f"{len(alive)}/{self.n} replicas alive for put_data")
        with self._glock:
            # Version each rewrite so readers can spot a stale copy on a
            # replica that was down during the rewrite (crash, not
            # amnesia: its old payload survives recovery).
            key = ("data", partition, name)
            ver = self._gens[key] = self._gens.get(key, 0) + 1
        for r in alive:
            r.put_data(partition, name, payload, version=ver)

    def get_data(self, partition: str, name: str) -> bytes:
        best: Optional[Tuple[int, bytes]] = None
        for r in self.alive_replicas():
            got = r.get_data(partition, name)
            if got is not None and (best is None or got[0] > best[0]):
                best = got
        if best is not None:
            return best[1]
        # Same error surface as FileStore.get_data on a missing shard.
        raise FileNotFoundError(f"no alive replica holds "
                                f"{partition}/{name}")

    def snapshot(self) -> Dict[Tuple[str, str], Vote]:
        """Merged view over every MEMBER replica's disk — ground truth for
        tests and recovery tooling.  Deliberately includes down members
        (crash, not amnesia): a quorum-committed record must show up even
        while the replicas that hold it are offline.  Retired (removed)
        replicas are excluded — their stale writes can never be chosen."""
        members = self.member_replicas()
        keys = set()
        for r in members:
            keys.update(r.keys())
        out = {}
        for k in keys:
            if k in self._gc_index:
                continue      # truncated slots live in the journal
            v, _, _ = merge_reads([r.read(k) for r in members])
            if v is not None:
                out[k] = v
        return out

    # -- durable-state lifecycle -------------------------------------------
    def gc_pass(self, now: float = 0.0) -> int:
        """Advance each partition's low-watermark past txns whose terminal
        decision is durable on a QUORUM of members (down members count
        their disks — crash, not amnesia) and truncate the slots below it,
        journaling each removal.  Strict prefix order per partition: an
        in-doubt txn blocks GC behind it."""
        lc = self.lifecycle
        if lc is None or not lc.gc:
            return 0
        with self._reconfig_lock:
            members = self.member_replicas()
            # Durability census: (key, vote) -> copies on member disks.  A
            # terminal value on >= quorum disks IS quorum-durable whether
            # it got there via Paxos learn (decided=True) or a generation
            # write (``log``-path decisions never set the consensus flag).
            counts: Dict[Tuple[Tuple[str, str], str], int] = {}
            seen_keys = set()
            for r in members:
                seen_keys.update(r.keys())
            for k in seen_keys:
                if k in self._gc_index:
                    # Resurrected garbage from an op that raced an earlier
                    # truncation: re-truncate, keep it out of the census.
                    for r in members:
                        r.truncate(k)
                    continue
                for r in members:
                    v, _g, _d = r.read(k)
                    if v is not None and v.is_decision():
                        ck = (k, v.value)
                        counts[ck] = counts.get(ck, 0) + 1
            settled: Dict[str, Vote] = {}
            for e in self.gc_log:
                if e.decision is not None:
                    settled.setdefault(e.txn, Vote(e.decision))
            for (k, val), n_copies in counts.items():
                if n_copies >= self.quorum:
                    settled.setdefault(k[1], Vote(val))
            n = 0
            with self._glock:
                order_items = [(p, list(ts)) for p, ts in self._order.items()]
            for partition, order in order_items:
                wm = self.watermarks.get(partition, 0)
                while wm < len(order):
                    txn = order[wm]
                    key = (partition, txn)
                    if key in self._gc_index:
                        wm += 1
                        continue
                    dec = settled.get(txn)
                    if dec is None:
                        break
                    v, _g, _d = merge_reads([r.read(key) for r in members])
                    e = GcEntry(partition, txn,
                                None if v is None else v.value,
                                dec.value, True, at=now)
                    self.gc_log.append(e)
                    self._gc_index[key] = e
                    for r in members:
                        r.truncate(key)
                    wm += 1
                    n += 1
                if wm > self.watermarks.get(partition, 0):
                    self.watermarks[partition] = wm
            self.gc_truncations += n
            return n

    def scrub_pass(self) -> int:
        """Anti-entropy: exchange per-partition slot digests among alive
        members, repair divergent/corrupt replicas through `repair`, and
        quarantine (full state transfer) any member whose corrupt-record
        count crosses the threshold.  Returns repairs made."""
        lc = self.lifecycle
        if lc is None or not lc.scrub:
            return 0
        with self._reconfig_lock:
            alive = [(i, self.replicas[i])
                     for i in self._membership.replica_ids if self._alive[i]]
            if len(alive) < 2:
                return 0
            digests = [r.partition_digests() for _i, r in alive]
            suspect_parts = set()
            all_parts = set()
            for dg in digests:
                all_parts.update(dg)
            for p in all_parts:
                vals = {dg.get(p) for dg in digests}
                if len(vals) > 1:
                    suspect_parts.add(p)
            corrupt_by = {i: set(r.corrupt_keys()) for i, r in alive}
            self.corrupt_records += sum(
                len(ks) for ks in corrupt_by.values())
            keys = set()
            for _i, r in alive:
                keys.update(k for k in r.keys() if k[0] in suspect_parts)
            for ks in corrupt_by.values():
                keys.update(ks)
            repaired = 0
            for k in sorted(keys):
                if k in self._gc_index:
                    for _i, r in alive:
                        r.truncate(k)
                    continue
                reads = [(r, r.read(k)) for _i, r in alive]
                v, g, d = merge_reads([rd for _r, rd in reads])
                if v is None and not d:
                    continue
                for r, (rv, rg, rd) in reads:
                    if rg < g or (d and not rd) or (v is not None
                                                    and rv is None):
                        r.repair(k, v, g, d)
                        repaired += 1
            self.scrub_repairs += repaired
            threshold = lc.quarantine_threshold
            for i, _r in alive:
                if len(corrupt_by[i]) >= threshold:
                    # Quarantine: refresh the whole volume from its peers.
                    self.quarantines += 1
                    self._state_transfer(i, self._membership.replica_ids)
            return repaired

    def partition_log(self, partition: str) -> List[Tuple[str, str]]:
        with self._glock:
            order = self._order.get(partition)
            if order is not None:
                wm = self.watermarks.get(partition, 0)
                retained = order[wm:]
                return [(partition, t) for t in retained
                        if (partition, t) not in self._gc_index]
        keys = set()
        for r in self.member_replicas():
            keys.update(k for k in r.keys() if k[0] == partition)
        return sorted(keys)

    def is_truncated(self, key: Tuple[str, str]) -> bool:
        return key in self._gc_index

    def watermark_lag(self) -> int:
        with self._glock:
            return sum(len(order) - self.watermarks.get(p, 0)
                       for p, order in self._order.items())


class DelayedMemoryStore(MemoryStore):
    """MemoryStore whose store-side ops cost ``delay_s`` of service time.

    The sleep sits INSIDE the op (under ``perform()`` for ``log_once``),
    so a decision-cache hit — which never runs the op — skips it, and a
    singleflight joiner shares one leader's delay instead of paying its
    own.  Wall-clock harnesses (``repro.txn.threaded``, ``repro.serve``)
    use this to make throughput a property of the protocol's forced-write
    count rather than of the host machine."""

    def __init__(self, delay_s: float,
                 decisions: Optional[DecisionCacheConfig] = None,
                 lifecycle: Optional[LifecycleConfig] = None) -> None:
        super().__init__(decisions=decisions, lifecycle=lifecycle)
        self._delay_s = delay_s

    def _log_once_direct(self, partition, txn, state, writer=""):
        time.sleep(self._delay_s)
        return super()._log_once_direct(partition, txn, state, writer)

    def log(self, partition, txn, state, writer=""):
        time.sleep(self._delay_s)
        return super().log(partition, txn, state, writer)


class DelayedReplicatedStore(ReplicatedStore):
    """ReplicatedStore with the same injected per-op service delay."""

    def __init__(self, delay_s: float, n_replicas: int = 3, seed: int = 0,
                 max_rounds: int = 256,
                 decisions: Optional[DecisionCacheConfig] = None,
                 membership: Optional[Sequence[int]] = None,
                 lifecycle: Optional[LifecycleConfig] = None) -> None:
        super().__init__(n_replicas=n_replicas, seed=seed,
                         max_rounds=max_rounds, decisions=decisions,
                         membership=membership, lifecycle=lifecycle)
        self._delay_s = delay_s

    def _log_once_quorum(self, partition, txn, state, writer=""):
        time.sleep(self._delay_s)
        return super()._log_once_quorum(partition, txn, state, writer)

    def log(self, partition, txn, state, writer=""):
        time.sleep(self._delay_s)
        return super().log(partition, txn, state, writer)


class _Forward:
    """One vote-forwarding obligation on a log_once call (Table 3's
    ``cornus-opt1`` / ``paxos-commit`` rows): deliver the slot's decided
    value to a third-party compute node (the transaction coordinator)
    exactly once, from wherever the decision was reached — the leader in
    leader mode, the quorum-th acceptor ack in coloc mode."""

    __slots__ = ("region", "_deliver", "fired", "scheduled")

    def __init__(self, region: str, deliver) -> None:
        self.region = region
        self._deliver = deliver
        self.fired = False
        self.scheduled = False

    def deliver_now(self, value: Vote) -> None:
        if not self.fired:
            self.fired = True
            self._deliver(value)

    def schedule(self, sim, delay_ms: float, value: Vote) -> None:
        self.scheduled = True
        sim._schedule(sim.now + delay_ms, lambda: self.deliver_now(value))

    @staticmethod
    def deliver_group(pairs) -> None:
        """Deliver many forwards arriving together (one batched flush's
        push toward a region): forwards whose callback exposes a transport
        payload (``protocols.base.VoteForward``) and share a destination
        node ride ONE ``Transport.deliver_many`` message; anything else
        falls back to individual delivery."""
        by_dst: Dict[Tuple, List] = {}
        for fwd, value in pairs:
            if fwd.fired:
                continue
            cb = fwd._deliver
            transport = getattr(cb, "transport", None)
            if transport is None or not hasattr(cb, "payload"):
                fwd.deliver_now(value)
            else:
                key = (id(transport), cb.dst)
                if key not in by_dst:
                    by_dst[key] = (transport, [])
                by_dst[key][1].append((fwd, value))
        for transport, group in by_dst.values():
            items = []
            for fwd, value in group:
                fwd.fired = True
                items.append(fwd._deliver.payload(value))
            transport.deliver_many(group[0][0]._deliver.dst, items)


class ReplicatedSimStorage(_DecisionCacheMixin):
    """Quorum-replicated storage service inside the discrete-event sim.

    Drop-in for ``SimStorage``: ``log_once`` / ``log`` / ``read_state`` /
    ``log_batch`` return sim Events, so ``Cluster`` (any registered
    protocol) runs unmodified against it.  R replica endpoints each have a region (RTTs
    from ``RegionTopology``), the shared ``LatencyModel`` service times, and a
    per-replica fail/recover schedule; a request completes on the *quorum-th*
    fastest acknowledgement, not the slowest replica.

    Two deployment modes mirror Table 3:
      * ``leader`` — callers route to the lowest-index alive replica; the
        initial leader owns every slot's implicit phase-1 (writes cost
        caller→leader + one accept round).  A post-failover leader acquires
        an epoch *lease* with one bulk prepare round and regains the same
        phase-1-free fast path — batched flushes included — instead of
        paying full prepare+accept per slot forever.
      * ``coloc``  — compute coordinates replication: the partition owner
        proposes directly to the replicas (its own vote costs one quorum
        round); termination CAS by peers pays both phases.

    Leases are bounded by ``lease_ms`` of sim time (a ``Sim.timer`` marks
    expiry); a leaseholder renews by acquiring the next epoch.  Validity is
    purely a performance gate — replicas enforce ballot order, so an
    expired or superseded leaseholder's accepts fail and fall back safely.

    Caller identity (for region lookup and slot ownership) rides on the
    ``writer`` argument the protocols already pass.
    """

    def __init__(self, sim, model: LatencyModel, n_replicas: int = 3,
                 seed: int = 0, topology: Optional[RegionTopology] = None,
                 replica_regions: Optional[Sequence[str]] = None,
                 placement: Optional[Mapping[str, str]] = None,
                 mode: str = "leader",
                 op_timeout_ms: Optional[float] = None,
                 batch: Optional[BatchConfig] = None,
                 lease_ms: float = 200.0,
                 decisions: Optional[DecisionCacheConfig] = None,
                 membership: Optional[Sequence[int]] = None,
                 lifecycle: Optional[LifecycleConfig] = None) -> None:
        assert mode in ("leader", "coloc")
        self.sim = sim
        self.model = model
        # Membership is versioned and elastic: ``member_ids`` (ascending)
        # is the CURRENT replica set; the table arrays below are indexed
        # by replica id and only ever grow (retired ids keep their state
        # but are never consulted again).  Without reconfiguration the
        # members are exactly range(n_replicas) in the same order every
        # loop always iterated — bit-identical.
        self.membership = MembershipConfig(
            1, tuple(membership) if membership is not None
            else tuple(range(n_replicas)))
        assert all(i < n_replicas for i in self.membership.replica_ids)
        self.member_ids: List[int] = list(self.membership.replica_ids)
        self.n = self.membership.n
        self.quorum = self.membership.quorum
        self.topology = topology or INTRA_ZONE
        regs = self.topology.regions
        self.replica_regions = (list(replica_regions) if replica_regions
                                else [regs[i % len(regs)]
                                      for i in range(n_replicas)])
        assert len(self.replica_regions) == n_replicas
        self.placement = dict(placement or {})
        self.mode = mode
        self.replicas = [ReplicaLog(i) for i in range(n_replicas)]
        self.fail_at = [float("inf")] * n_replicas
        self.recover_at = [float("inf")] * n_replicas
        self.rng = random.Random(seed)
        self._pids = itertools.count(1)
        self._gens: Dict[Tuple[str, str], int] = {}
        self.requests = 0
        self.round_trips = 0           # quorum scatter rounds issued
        self.forward_batches = 0       # coalesced leader→coordinator pushes
        self.batch = batch or BatchConfig()
        self._ingress = (GroupCommitIngress(sim, self.batch,
                                            self._flush_batch)
                         if self.batch.active else None)
        self.op_timeout_ms = op_timeout_ms or (
            3.0 * self.topology.max_rtt_ms
            + 12.0 * model.conditional_write_ms + 8.0)
        # Leadership lease: epoch 1 is the initial leader's implicit,
        # unbounded lease (no acquisition round — keeps the no-failure
        # timing bit-identical); failover epochs are lease_ms-bounded.
        self.lease_ms = lease_ms
        self._lease = StoreLease(1, 0, OWNER_BALLOT, float("inf"))
        self._acquiring = None         # single-flight acquisition event
        # Slots whose in-flight value a lease acquisition could not
        # re-propose at quorum: excluded from the fast path (a round-1
        # accept could contradict a possibly-chosen value); the full
        # proposer adopts the accepted value correctly.
        self._pinned: set = set()
        self.lease_acquisitions = 0
        self.lease_expiries = 0
        self.fast_path_ops = 0
        self.fallback_ops = 0
        # (epoch, holder, acquired_at) per acquisition, epoch 1 implicit.
        self.lease_history: List[Tuple[int, int, float]] = []
        # epoch -> {holder: fast ops served}; in leader mode the lease
        # property tests assert exactly one holder per epoch (in coloc,
        # epoch 1 has one holder per partition owner by construction).
        self.fast_ops_by_epoch: Dict[int, Dict] = {}
        # Elastic-membership accounting: (started_ms, cutover_ms,
        # installed_ms, old_n, new_n) per completed config change —
        # started→cutover is background state transfer (old config keeps
        # serving), cutover→installed is the disruptive epoch bump the
        # elasticity bench bounds; plus slots/payloads moved by state
        # transfer and ops that WANTED the lease fast path but had to
        # degrade to the full proposer (the silent-degradation signal
        # benches assert re-engages after a change).
        self.reconfig_history: List[
            Tuple[float, float, float, int, int]] = []
        self.reconfigurations = 0
        self.state_transfers = 0
        self.lease_degradations = 0
        self._reconfiguring = None     # single-flight config-change event
        # Durable-state lifecycle (GC watermark + anti-entropy scrub).
        self.lifecycle = LifecycleConfig.coerce(lifecycle)
        self._order: Dict[str, List[str]] = {}
        self._order_seen: set = set()
        self.watermarks: Dict[str, int] = {}
        self.gc_log: List[GcEntry] = []
        self._gc_index: Dict[Tuple[str, str], GcEntry] = {}
        self.gc_truncations = 0
        self.scrub_repairs = 0
        self.quarantines = 0
        self.corrupt_records = 0
        self.torn_records = 0
        self._init_decisions(decisions, seed)

    # -- replica liveness (sim-time schedules, like Cluster nodes) ---------
    def fail_replica(self, i: int, at: float = 0.0,
                     recover_at: float = float("inf")) -> None:
        self.fail_at[i] = at
        self.recover_at[i] = recover_at

    def replica_alive(self, i: int) -> bool:
        t = self.sim.now
        return t < self.fail_at[i] or t >= self.recover_at[i]

    def _leader_idx(self) -> Optional[int]:
        for i in self.member_ids:
            if self.replica_alive(i):
                return i
        return None

    def _region_of(self, node: str) -> str:
        return self.placement.get(node, self.topology.regions[0])

    def _backoff(self, attempt: int) -> float:
        return min(2.0 ** attempt, 8.0) * (0.5 + self.rng.random())

    # -- leadership leases (epoch ballots over sim time) -------------------
    def _lease_valid(self) -> bool:
        lease = self._lease
        now = self.sim.now
        if self.chaos is not None:
            # Clock skew on the lease clock: positive skew expires leases
            # early (spurious re-acquisitions), negative skew lets a holder
            # trust a lease longer than it should — ballots must keep every
            # outcome safe either way.
            now += self.chaos.skew_ms()
        return (self.replica_alive(lease.holder)
                and lease.valid_at(now))

    def _count_fast(self, ballot: Ballot, n_ops: int = 1,
                    holder=None) -> None:
        """Attribute fast-path ops to (epoch, serving identity).  Leader
        mode: the leaseholder replica (ballot's proposer).  Coloc mode:
        pass the partition owner explicitly — every owner shares the
        implicit epoch-1 lease over its own partition, so epoch 1
        legitimately has one holder PER PARTITION there."""
        self.fast_path_ops += n_ops
        epoch = ballot[0]
        if holder is None:
            holder = ballot[2]
        per_epoch = self.fast_ops_by_epoch.setdefault(epoch, {})
        per_epoch[holder] = per_epoch.get(holder, 0) + n_ops

    def _ensure_lease(self, li: int):
        """Generator: make replica ``li`` the valid leaseholder, acquiring
        a fresh epoch if needed.  Returns True once li holds the lease;
        False if li died (the caller re-routes or falls back).  Immediate
        — no sim events — when the lease is already valid, so the
        no-failure fast path pays nothing."""
        while True:
            if self._lease_valid() and self._lease.holder == li:
                return True
            if not self.replica_alive(li):
                return False
            if self._acquiring is not None:
                yield self._acquiring   # join the in-flight acquisition
                continue                # then re-check whom it was for
            ev = self._acquiring = self.sim.event()
            try:
                ok = yield from self._acquire_lease(li)
            finally:
                self._acquiring = None
                ev.trigger(None)
            if not ok:
                return False

    def _acquire_lease(self, li: int):
        """One bulk prepare round from replica ``li``: promote a fresh
        epoch ballot on a quorum (phase 1 for ALL current and future
        slots), complete in-flight undecided slots at it, install the
        lease.  Retries with a higher epoch when outballoted."""
        epoch = self._lease.epoch
        attempt = 0
        src = self.replica_regions[li]
        while True:
            if not self.replica_alive(li):
                return False
            epoch += 1
            ballot: Ballot = (epoch, 1, li)
            resps = yield self._scatter(
                src, lambda r, i, b=ballot: r.prepare_epoch(b),
                self.model.read_ms,
                lambda rs: sum(1 for _, (ok, *_r) in rs if ok)
                >= self.quorum, li)
            oks = 0
            inflight: Dict[Tuple[str, str], Tuple[Ballot, Vote]] = {}
            for _, (ok, promised, acc) in resps:
                if ok:
                    oks += 1
                    for key, ab, av in acc:
                        cur = inflight.get(key)
                        if cur is None or ab > cur[0]:
                            inflight[key] = (ab, av)
                else:
                    epoch = max(epoch, promised[0])
            if oks < self.quorum:
                attempt += 1
                yield self.sim.timeout(self._backoff(attempt))
                continue
            if inflight:
                # Multi-Paxos recovery: ONE accept round re-proposing every
                # in-flight value at the epoch ballot, so later round-1
                # accepts can never contradict a value the previous epoch
                # may already have chosen.
                keys = sorted(inflight)

                def apply_recover(r: ReplicaLog, i: int,
                                  keys=keys, ballot=ballot):
                    return [r.accept(k, ballot, inflight[k][1])
                            for k in keys]

                def recovered(resps) -> bool:
                    return all(sum(1 for _, vals in resps if vals[idx])
                               >= self.quorum for idx in range(len(keys)))

                resps = yield self._scatter(
                    src, apply_recover,
                    self.model.batched_write_ms(
                        len(keys), self.model.conditional_write_ms),
                    recovered, li)
                for idx, k in enumerate(keys):
                    if sum(1 for _, vals in resps
                           if vals[idx]) >= self.quorum:
                        self._cast(src,
                                   lambda r, i, k=k: r.learn(
                                       k, inflight[k][1]),
                                   self.model.plain_write_ms, li)
                        self._pinned.discard(k)
                    else:
                        # Unrecovered slot: keep it off the fast path for
                        # this and later epochs until a full proposer
                        # settles it.
                        self._pinned.add(k)
            self._lease = StoreLease(epoch, li, ballot,
                                     self.sim.now + self.lease_ms)
            self.lease_acquisitions += 1
            self.lease_history.append((epoch, li, self.sim.now))
            self.sim.timer(self.lease_ms,
                           lambda epoch=epoch: self._note_expiry(epoch))
            return True

    def _note_expiry(self, epoch: int) -> None:
        if self._lease.epoch == epoch and not self._lease.valid_at(
                self.sim.now):
            self.lease_expiries += 1

    # -- elastic membership (live reconfiguration) -------------------------
    def schedule_reconfigure(self, at_ms: float, n_replicas: int,
                             regions: Optional[Sequence[str]] = None
                             ) -> None:
        """Arm a live membership change at sim time ``at_ms``: the store
        scales to ``n_replicas`` members (growing with fresh joiners, or
        retiring the highest member ids).  Nothing is scheduled into the
        event stream before ``at_ms`` — runs without reconfiguration are
        untouched."""
        delay = max(0.0, at_ms - self.sim.now)
        self.sim.timer(delay, lambda: self.sim.process(
            self._reconfigure_proc(n_replicas, regions)))

    def _sim_copy_image(self, src_ids: Sequence[int], j: int) -> int:
        """Instant-apply bulk image copy onto replica ``j`` (the caller
        charges the batched round-trip time): merged slots via repair,
        payloads at their freshest version (versioned cutover)."""
        donors = [self.replicas[i] for i in src_ids
                  if self.replica_alive(i) and i != j]
        target = self.replicas[j]
        moved = 0
        keys = set()
        for d in donors:
            keys.update(d.keys())
        for k in keys:
            if k in self._gc_index:
                continue    # truncated: the journal entry is authoritative
            v, g, dec = merge_reads([d.read(k) for d in donors])
            if v is not None or dec:
                target.repair(k, v, g, dec)
                moved += 1
        if self._gc_index:
            # Anti-resurrection sweep: the rejoiner must not re-serve
            # slots the watermark already truncated cluster-wide.
            for k in target.keys():
                if k in self._gc_index:
                    target.truncate(k)
        pkeys = set()
        for d in donors:
            pkeys.update(d.data_keys())
        for (partition, name) in pkeys:
            best: Optional[Tuple[int, bytes]] = None
            for d in donors:
                got = d.get_data(partition, name)
                if got is not None and (best is None or got[0] > best[0]):
                    best = got
            if best is not None:
                target.put_data(partition, name, best[1], version=best[0])
                moved += 1
        self.state_transfers += 1
        return moved

    def _reconfigure_proc(self, n_new: int,
                          regions: Optional[Sequence[str]] = None):
        """Serialize config changes: a second scheduled change waits for
        the in-flight one to install, then runs against the NEW config
        (scale 3→5→3 is two complete changes, not a lost update)."""
        while self._reconfiguring is not None:
            yield self._reconfiguring
        ev = self._reconfiguring = self.sim.event()
        try:
            yield from self._reconfigure_body(n_new, regions)
        finally:
            self._reconfiguring = None
            ev.trigger(None)

    def _reconfigure_body(self, n_new: int,
                          regions: Optional[Sequence[str]] = None):
        """Generator process driving one config change end to end:

          1. grow the replica table for joiners and push each a bulk
             state-transfer image (ONE batched round trip per joiner —
             recovery-driven, not lazy read repair);
          2. epoch bump carrying the new membership: one bulk
             ``prepare_epoch`` over the UNION of both configs, promised by
             a majority of the old AND the new set (joint quorum), with
             in-flight undecided slots completed at the new ballot under
             the same joint rule;
          3. delta-copy anything decided during the transfer, then the
             versioned cutover: install the new ``MembershipConfig`` and
             hand the lease to the new config's leader at the bump ballot
             — the group-commit fast path survives the change.

        The disruption window ``reconfig_history`` records spans from the
        change starting to the new config serving fast-path ops."""
        started = self.sim.now
        old = self.membership
        old_ids = list(self.member_ids)
        new_ids = list(old_ids)
        joiners: List[int] = []
        if n_new > len(old_ids):
            regs = self.topology.regions
            for k in range(n_new - len(old_ids)):
                i = len(self.replicas)
                self.replicas.append(ReplicaLog(i))
                self.replica_regions.append(
                    regions[k] if regions is not None
                    else regs[i % len(regs)])
                self.fail_at.append(float("inf"))
                self.recover_at.append(float("inf"))
                new_ids.append(i)
                joiners.append(i)
        elif n_new < len(old_ids):
            new_ids = old_ids[:n_new]     # retire the highest member ids
        if new_ids == old_ids:
            return
        new = MembershipConfig(old.config_id + 1, tuple(new_ids))
        old_set, new_set = set(old_ids), set(new_ids)
        oq = len(old_ids) // 2 + 1
        nq = len(new_ids) // 2 + 1

        def joint(ok_ids) -> bool:
            return (sum(1 for i in ok_ids if i in old_set) >= oq
                    and sum(1 for i in ok_ids if i in new_set) >= nq)

        union = sorted(old_set | new_set)
        driver = None
        while driver is None:
            # The new config's leader drives the change (and inherits the
            # lease); with none alive, wait out the outage like _via_leader.
            driver = next((i for i in new_ids
                           if self.replica_alive(i)), None)
            if driver is None:
                yield self.sim.timeout(self.op_timeout_ms)
        src = self.replica_regions[driver]
        if joiners:
            # Joiners pull the image CONCURRENTLY, each as a pipelined
            # chunk stream (catch-up is bulk streaming, not one log write
            # per record): wall time per joiner = one RTT + the number of
            # chunk rounds at the chunk's batched service time.  Sized off
            # the leader's slot count at transfer start, so the charge
            # does not chase foreground writes landing mid-copy.
            n_items = max(1, len(self.replicas[old_ids[0]].keys()))
            rounds = -(-n_items // (TRANSFER_CHUNK * TRANSFER_STREAMS))
            waits = []
            for j in joiners:
                dur = (self.topology.rtt_ms(src, self.replica_regions[j])
                       + rounds * self.model.sample(
                           self.rng, self.model.batched_write_ms(
                               TRANSFER_CHUNK, self.model.plain_write_ms)))
                waits.append(self.sim.timeout(dur))
            yield self.sim.all_of(waits)
            for j in joiners:
                self._sim_copy_image(old_ids, j)
        # The epoch bump is the DISRUPTIVE part (cutover→installed): hold
        # the lease single-flight so no concurrent acquisition can install
        # a stale-config lease over the bump's, and so callers waiting on
        # a lease re-check after the new config is in.
        while self._acquiring is not None:
            yield self._acquiring
        acq_ev = self._acquiring = self.sim.event()
        cutover = self.sim.now
        epoch = self._lease.epoch
        attempt = 0
        while True:
            if not self.replica_alive(driver):
                driver = next((i for i in new_ids
                               if self.replica_alive(i)), None)
                if driver is None:
                    yield self.sim.timeout(self.op_timeout_ms)
                    continue
                src = self.replica_regions[driver]
            epoch += 1
            ballot: Ballot = (epoch, 1, driver)
            resps = yield self._scatter(
                src, lambda r, i, b=ballot: r.prepare_epoch(b),
                self.model.read_ms,
                lambda rs: joint([i for i, (ok, *_r) in rs if ok]),
                driver, ids=union)
            ok_ids: List[int] = []
            inflight: Dict[Tuple[str, str], Tuple[Ballot, Vote]] = {}
            for i, (ok, promised, acc) in resps:
                if ok:
                    ok_ids.append(i)
                    for key, ab, av in acc:
                        cur = inflight.get(key)
                        if cur is None or ab > cur[0]:
                            inflight[key] = (ab, av)
                else:
                    epoch = max(epoch, promised[0])
            if not joint(ok_ids):
                attempt += 1
                yield self.sim.timeout(self._backoff(attempt))
                continue
            if inflight:
                keys = sorted(inflight)

                def apply_recover(r: ReplicaLog, i: int,
                                  keys=keys, ballot=ballot):
                    return [r.accept(k, ballot, inflight[k][1])
                            for k in keys]

                def recovered(resps) -> bool:
                    return all(joint([i for i, vals in resps if vals[idx]])
                               for idx in range(len(keys)))

                resps = yield self._scatter(
                    src, apply_recover,
                    self.model.batched_write_ms(
                        len(keys), self.model.conditional_write_ms),
                    recovered, driver, ids=union)
                for idx, k in enumerate(keys):
                    if joint([i for i, vals in resps if vals[idx]]):
                        self._cast(src,
                                   lambda r, i, k=k: r.learn(
                                       k, inflight[k][1]),
                                   self.model.plain_write_ms, driver,
                                   ids=union)
                        self._pinned.discard(k)
                    else:
                        self._pinned.add(k)
            break
        for j in joiners:
            self._sim_copy_image(old_ids, j)   # delta since the bulk copy
        self.membership = new
        self.member_ids = list(new.replica_ids)
        self.n = new.n
        self.quorum = new.quorum
        self._lease = StoreLease(epoch, driver, ballot,
                                 self.sim.now + self.lease_ms)
        self.lease_acquisitions += 1
        self.lease_history.append((epoch, driver, self.sim.now))
        self.sim.timer(self.lease_ms,
                       lambda epoch=epoch: self._note_expiry(epoch))
        self._acquiring = None
        acq_ev.trigger(None)
        self.reconfigurations += 1
        self.reconfig_history.append(
            (started, cutover, self.sim.now, len(old_ids), len(new_ids)))

    # -- scatter/gather RPC layer ------------------------------------------
    def _scatter(self, src_region: str, fn, mean_ms: float, done_pred,
                 self_idx: Optional[int] = None, also=None,
                 ids: Optional[Sequence[int]] = None):
        """Send ``fn(replica, i)`` to every replica; the returned Event
        triggers with [(i, result), ...] once ``done_pred`` is satisfied,
        all replicas answered, or ``op_timeout_ms`` elapsed.  A replica dead
        at apply time silently drops the request.

        ``also`` models acceptor-side forwarding: each replica that applies
        the request ALSO sends its result toward a forward region, where
        ``cb(i, result)`` runs at arrival time (paxos-commit's "acceptors
        forward to the coordinator").  It is one ``(region, cb)`` pair or a
        list of them; pairs sharing a region ride ONE message per replica
        (a batch flush forwards many slots' votes in a single push).

        A round also concludes once every replica still ALIVE has answered
        — waiting out ``op_timeout_ms`` for a dead replica would otherwise
        park the caller (and, under group commit, the partition's serial
        lane) on every round whose predicate cannot be met, which is
        exactly the post-failover stall the leases exist to remove.  With
        no failures every replica answers, so the timing is unchanged.

        ``ids`` overrides the target set (reconfiguration rounds scatter
        over the union of old and new members); the default is the current
        membership."""
        done = self.sim.event()
        acc = {"resps": [], "count": 0}
        self.round_trips += 1
        targets = list(self.member_ids) if ids is None else list(ids)
        # Torn write: only a prefix of the targets receives this scatter
        # (the proposer believes it reached everyone).  ``alive_pending``
        # still ranges over the FULL target list, so a torn round concludes
        # only via its predicate or ``op_timeout_ms`` — never by mistaking
        # unreached replicas for answered ones.
        reached = (targets if self.chaos is None
                   else self.chaos.torn_targets(targets))
        fwd_by_region: Dict[str, List] = {}
        if also is not None:
            pairs = also if isinstance(also, list) else [also]
            for fwd_region, cb in pairs:
                fwd_by_region.setdefault(fwd_region, []).append(cb)

        def finish_if(ready: bool) -> None:
            if not done.triggered and ready:
                done.trigger(list(acc["resps"]))

        for i in targets:
            net = (0.0 if i == self_idx
                   else self.topology.rtt_ms(
                       src_region, self.replica_regions[i]) / 2.0)
            service = self.model.sample(self.rng, mean_ms)
            extra = 0.0
            if self.chaos is not None:
                if i not in reached:
                    continue
                leg = self.chaos.replica_leg(i)
                if leg is None:        # request leg lost: never applies
                    continue
                extra = leg

            def apply(i=i, net=net, service=service):
                if not self.replica_alive(i):
                    return
                val = fn(self.replicas[i], i)
                ack_extra = 0.0
                if self.chaos is not None:
                    ack = self.chaos.replica_leg(i)
                    if ack is None:    # applied, but the ack leg is lost
                        return
                    ack_extra = ack

                def respond(i=i, val=val):
                    acc["resps"].append((i, val))
                    acc["count"] += 1
                    answered = {j for j, _ in acc["resps"]}
                    alive_pending = any(
                        self.replica_alive(j) for j in targets
                        if j not in answered)
                    finish_if(done_pred(acc["resps"])
                              or not alive_pending)

                self.sim._schedule(self.sim.now + net + ack_extra, respond)
                for fwd_region, cbs in fwd_by_region.items():
                    fwd_net = self.topology.rtt_ms(
                        self.replica_regions[i], fwd_region) / 2.0
                    self.sim._schedule(
                        self.sim.now + fwd_net,
                        lambda i=i, val=val, cbs=cbs: [cb(i, val)
                                                       for cb in cbs])

            self.sim._schedule(self.sim.now + net + extra + service, apply)
        self.sim._schedule(self.sim.now + self.op_timeout_ms,
                           lambda: finish_if(True))
        return done

    def _cast(self, src_region: str, fn, mean_ms: float,
              self_idx: Optional[int] = None,
              only: Optional[Sequence[int]] = None,
              ids: Optional[Sequence[int]] = None) -> None:
        """Fire-and-forget apply (learn / read-repair pushes)."""
        for i in (self.member_ids if ids is None else ids):
            if only is not None and i not in only:
                continue
            net = (0.0 if i == self_idx
                   else self.topology.rtt_ms(
                       src_region, self.replica_regions[i]) / 2.0)
            service = self.model.sample(self.rng, mean_ms)
            extra = 0.0
            if self.chaos is not None:
                leg = self.chaos.replica_leg(i)
                if leg is None:        # fire-and-forget push lost outright
                    continue
                extra = leg

            def apply(i=i, net=net, service=service):
                if self.replica_alive(i):
                    fn(self.replicas[i], i)

            self.sim._schedule(self.sim.now + net + extra + service, apply)

    # -- leader routing ----------------------------------------------------
    def _via_leader(self, caller: str, inner, forward: Optional[_Forward] = None):
        """Route one op through the current leader; retries over failover.
        (Leader death mid-round is modelled at op granularity: the caller's
        scatter just runs from the leader's region.)

        With ``forward``, the leader pushes the result toward the forward
        target the moment the quorum round completes — in parallel with the
        reply hop back to the caller (cornus-opt1's "Paxos leader forwards
        the vote to the coordinator")."""
        src = self._region_of(caller)
        while True:
            li = self._leader_idx()
            if li is None:
                yield self.sim.timeout(self.op_timeout_ms)
                continue
            lr = self.replica_regions[li]
            yield self.sim.timeout(self.topology.rtt_ms(src, lr) / 2.0)
            if not self.replica_alive(li):   # died while request in flight
                yield self.sim.timeout(self.op_timeout_ms / 4.0)
                continue
            result = yield from inner(li, lr)
            if forward is not None and not forward.fired:
                forward.schedule(self.sim,
                                 self.topology.rtt_ms(lr, forward.region) / 2.0,
                                 result)
            yield self.sim.timeout(self.topology.rtt_ms(lr, src) / 2.0)
            return result

    # -- quorum ops (generators run from src_region) -----------------------
    def _prep_quorum(self, resps) -> bool:
        oks = sum(1 for _, (ok, *_rest) in resps if ok)
        shortcut = any(vis is not None and decided
                       for _, (_ok, _ab, _av, vis, _g, decided, _p)
                       in resps)
        return oks >= self.quorum or shortcut

    def _quorum_log_once(self, src_region: str, self_idx: Optional[int],
                         owner_fast: bool, key, state: Vote, writer: str,
                         forward: Optional[_Forward] = None,
                         fast_ballot: Optional[Ballot] = None):
        pid = None
        attempt = 0
        epoch = (fast_ballot or self._lease.ballot)[0]
        fell_back = False
        while True:
            adopted = state
            if owner_fast and attempt == 0:
                ballot = fast_ballot or OWNER_BALLOT
            else:
                if not fell_back:
                    fell_back = True
                    self.fallback_ops += 1
                if pid is None:
                    pid = next(self._pids)
                ballot = (epoch, attempt + 2, pid)
                resps = yield self._scatter(
                    src_region,
                    lambda r, i, b=ballot: r.prepare(key, b),
                    self.model.read_ms, self._prep_quorum, self_idx)
                oks, best, seen = 0, None, None
                for _, (ok, ab, av, vis, _g, decided, promised) in resps:
                    if vis is not None and decided:
                        self._pinned.discard(key)
                        if self.lease_acquisitions > 0:
                            # Post-failover: push the decision to every
                            # replica so ones that missed it (recovered
                            # empty, or holding a losing round-1 value)
                            # can't later out-ballot the chosen value.
                            # Gated on failover having happened — the
                            # no-failure event/rng stream stays
                            # bit-identical.
                            self._cast(src_region,
                                       lambda r, i, v=vis: r.learn(key, v),
                                       self.model.plain_write_ms, self_idx)
                        return vis            # first value already chosen
                    oks += 1 if ok else 0
                    if not ok and promised[0] > epoch:
                        epoch = promised[0]   # jump stale epochs, not rounds
                    if av is not None and (best is None or ab > best[0]):
                        best = (ab, av)
                    if vis is not None and seen is None:
                        seen = vis
                if oks < self.quorum:
                    attempt += 1
                    yield self.sim.timeout(self._backoff(attempt))
                    continue
                adopted = best[1] if best else (seen or state)
            resps = yield self._scatter(
                src_region,
                lambda r, i, b=ballot, v=adopted: r.accept(key, b, v),
                self.model.conditional_write_ms,
                lambda rs: sum(1 for _, ok in rs if ok) >= self.quorum,
                self_idx,
                also=self._acceptor_forward(forward, adopted))
            if sum(1 for _, ok in resps if ok) >= self.quorum:
                if owner_fast and attempt == 0:
                    self._count_fast(ballot,
                                     holder=(writer if self.mode == "coloc"
                                             else None))
                else:
                    self._pinned.discard(key)   # settled by a full round
                self._cast(src_region,
                           lambda r, i, v=adopted: r.learn(key, v, writer),
                           self.model.plain_write_ms, self_idx)
                self._gens[key] = max(self._gens.get(key, 1), 1)
                return adopted
            attempt += 1
            yield self.sim.timeout(self._backoff(attempt))

    def _acceptor_forward(self, forward: Optional[_Forward], adopted: Vote):
        """Per-accept-round forwarding state: each acceptor that accepts
        sends its ack toward the forward target; the target 'learns' the
        value when the quorum-th ack arrives (it can count, Paxos Commit
        §Gray & Lamport) — which is when we deliver."""
        if forward is None:
            return None
        acks = {"n": 0}

        def cb(i: int, ok: bool) -> None:
            if ok:
                acks["n"] += 1
                if acks["n"] >= self.quorum:
                    forward.deliver_now(adopted)

        return (forward.region, cb)

    def _quorum_write(self, src_region: str, self_idx: Optional[int],
                      key, state: Vote, writer: str, mean_ms: float):
        g = self._gens.get(key, 1) + 1   # owner-assigned LSN (single writer)
        self._gens[key] = g
        while True:
            resps = yield self._scatter(
                src_region,
                lambda r, i: r.write(key, state, g, writer), mean_ms,
                lambda rs: len(rs) >= self.quorum, self_idx)
            if len(resps) >= self.quorum:
                return state
            yield self.sim.timeout(self._backoff(1))

    def _quorum_read(self, src_region: str, self_idx: Optional[int], key):
        while True:
            resps = yield self._scatter(
                src_region, lambda r, i: r.read(key), self.model.read_ms,
                lambda rs: len(rs) >= self.quorum, self_idx)
            if len(resps) < self.quorum:
                yield self.sim.timeout(self._backoff(1))
                continue
            value, gen, decided = merge_reads([v for _, v in resps])
            if value is not None or decided:
                # Anti-entropy push to every replica (repair is idempotent
                # adopt-if-newer): replicas that answered after the quorum
                # or were down at apply time catch up on the next read.
                self._cast(src_region,
                           lambda r, i: r.repair(key, value, gen, decided),
                           self.model.plain_write_ms, self_idx)
            return value

    # -- group commit: one accept round carrying many (txn, slot) values ---
    def _batchable(self, partition: str, writer: str) -> bool:
        """Only slot-owner fast-path ops coalesce: the batch is ONE owner-
        ballot accept round, so every op in it must hold the slot's implicit
        phase-1 promise.  In coloc mode that is the partition owner's own
        ops; in leader mode everything funnels through the CURRENT
        leaseholder — the flush acquires an epoch lease on demand, so a
        post-failover leader serves batches just like the initial one."""
        if self._ingress is None:
            return False
        if self.mode == "coloc":
            return bool(writer) and writer == partition
        return self._leader_idx() is not None

    def _submit_batched(self, op: _BatchOp):
        """Wrap lane submission with the caller's network legs (leader mode)
        and the forward safety net, mirroring ``_via_leader``."""
        def gen():
            if self.mode == "leader":
                src = self._region_of(op.writer)
                li = self._leader_idx()
                lr = self.replica_regions[0 if li is None else li]
                yield self.sim.timeout(self.topology.rtt_ms(src, lr) / 2.0)
                result = yield self._ingress.submit(op)
                yield self.sim.timeout(self.topology.rtt_ms(lr, src) / 2.0)
            else:
                result = yield self._ingress.submit(op)
            result = self._tombstoned((op.partition, op.txn), result)
            if (op.fwd is not None and not op.fwd.fired
                    and not op.fwd.scheduled):
                # Raced / fallback paths: the caller's reply doubles as the
                # forward source, like the unbatched short-circuit.
                op.fwd.deliver_now(result)
            self._note(op.partition, op.txn, result)
            return result

        return self.sim.process(gen())

    def _flush_batch(self, partition: str, ops: List[_BatchOp]):
        """ONE quorum round trip for the whole batch: a single scatter whose
        payload carries every op — owner-ballot accepts for the log_once
        slots, generation writes for the plain logs — charged one base
        service time plus ``batch_size_factor`` growth.  Ops apply in
        arrival order on every replica, so intra-batch first-writer-wins
        races resolve identically to back-to-back unbatched ops.  An op
        that loses its accept round (a concurrent unbatched proposer — e.g.
        a termination CAS — promoted the slot's ballot) falls back to the
        full prepare+accept proposer, which adopts whatever value won."""
        def gen(ops=ops):
            ballot = OWNER_BALLOT
            if self.mode == "coloc":
                src, self_idx = self._region_of(partition), None
            else:
                li = self._leader_idx()
                has_lease = False
                if li is not None:
                    # Current leader acquires (or already holds) the epoch
                    # lease — the bulk phase-1 that makes one owner-ballot
                    # accept round valid for every slot in the batch.
                    has_lease = yield from self._ensure_lease(li)
                if not has_lease:
                    # No alive leaseholder: batch guarantees are off,
                    # resolve each op individually.  Count the silent
                    # degradation so benches can assert the fast path
                    # re-engaged after failover/reconfiguration.
                    self.lease_degradations += 1
                    for op in ops:
                        self.sim.process(self._finish_fallback(op))
                    return 0
                if self._pinned:
                    # Unrecovered slots can't ride the round-1 batch.
                    rest = []
                    for op in ops:
                        if op.kind == "log_once" and op.key in self._pinned:
                            self.sim.process(self._finish_fallback(op))
                        else:
                            rest.append(op)
                    ops = rest
                    if not ops:
                        return 0
                src, self_idx = self.replica_regions[li], li
                ballot = self._lease.ballot
            for op in ops:
                if op.kind == "log":
                    g = self._gens.get(op.key, 1) + 1
                    self._gens[op.key] = g
                    op.gen = g
            base = max(self.model.conditional_write_ms
                       if op.kind == "log_once"
                       else self.model.plain_write_ms for op in ops)
            mean = self.model.batched_write_ms(
                sum(op.n_records for op in ops), base)

            def apply_all(r: ReplicaLog, i: int, ballot=ballot):
                out = []
                for op in ops:
                    if op.kind == "log_once":
                        out.append(r.accept(op.key, ballot, op.state))
                    else:
                        out.append(r.write(op.key, op.state, op.gen,
                                           op.writer))
                return out

            def op_satisfied(idx: int, resps) -> bool:
                if ops[idx].kind == "log_once":
                    return sum(1 for _, vals in resps
                               if vals[idx]) >= self.quorum
                return len(resps) >= self.quorum

            resps = yield self._scatter(
                src, apply_all, mean,
                lambda rs: all(op_satisfied(i, rs)
                               for i in range(len(ops))),
                self_idx, also=self._batch_acceptor_forwards(ops))

            fwd_groups: Dict[str, List[_BatchOp]] = {}
            for idx, op in enumerate(ops):
                if not op_satisfied(idx, resps):
                    self.sim.process(self._finish_fallback(op))
                    continue
                self._count_fast(ballot,
                                 holder=(partition if self.mode == "coloc"
                                         else None))
                if op.kind == "log_once":
                    self._cast(src,
                               lambda r, i, op=op: r.learn(op.key, op.state,
                                                           op.writer),
                               self.model.plain_write_ms, self_idx)
                    self._gens[op.key] = max(self._gens.get(op.key, 1), 1)
                op.result = op.state
                op.done.trigger(op.result)
                if (self.mode == "leader" and op.fwd is not None
                        and not op.fwd.fired):
                    fwd_groups.setdefault(op.fwd.region, []).append(op)
            # Coalesced storage→coordinator delivery: all forwarded votes
            # bound for one region leave the leader as ONE push, and those
            # sharing a destination node land as ONE deliver_many message.
            for region, group in fwd_groups.items():
                delay = self.topology.rtt_ms(src, region) / 2.0
                for op in group:
                    op.fwd.scheduled = True
                self.forward_batches += 1
                self.sim._schedule(
                    self.sim.now + delay,
                    lambda group=group: _Forward.deliver_group(
                        [(op.fwd, op.result) for op in group]))
            return len(ops)

        return self.sim.process(gen())

    def _batch_acceptor_forwards(self, ops: List[_BatchOp]):
        """Per-op acceptor forwarding for a batched accept round (coloc /
        paxos-commit): reuse the per-accept quorum counting of
        ``_acceptor_forward``, adapted to pick this op's ack out of the
        replica's batch response.  ``_scatter`` groups the pairs by region,
        so one replica pushes all its acks toward a coordinator region in a
        single message."""
        if self.mode != "coloc":
            return None
        pairs = []
        for idx, op in enumerate(ops):
            if op.kind == "log_once" and op.fwd is not None:
                region, cb = self._acceptor_forward(op.fwd, op.state)
                pairs.append((region,
                              lambda i, vals, idx=idx, cb=cb: cb(i, vals[idx])))
        return pairs or None

    def _finish_fallback(self, op: _BatchOp):
        """Resolve one op that could not ride (or lost) the batched fast
        path: the full prepare+accept proposer, which discovers and adopts
        any value a competing proposer already fixed for the slot."""
        if op.kind == "log_once":
            while True:
                if self.mode == "coloc":
                    src, self_idx = self._region_of(op.writer), None
                else:
                    li = self._leader_idx()
                    if li is None:
                        yield self.sim.timeout(self.op_timeout_ms)
                        continue
                    src, self_idx = self.replica_regions[li], li
                result = yield from self._quorum_log_once(
                    src, self_idx, False, op.key, op.state, op.writer,
                    forward=op.fwd)
                break
        else:
            self.fallback_ops += 1
            if self.mode == "coloc":
                src, self_idx = self._region_of(op.writer), None
            else:
                # Route via the first ALIVE replica — `_leader_idx() or 0`
                # conflated "leader is index 0" with "replica 0 is dead and
                # so is everyone else"; wait out a total outage instead of
                # scattering from a dead replica's position.
                while True:
                    li = self._leader_idx()
                    if li is not None:
                        break
                    yield self.sim.timeout(self.op_timeout_ms)
                src, self_idx = self.replica_regions[li], li
            result = yield from self._quorum_write(
                src, self_idx, op.key, op.state, op.writer,
                self.model.plain_write_ms)
        op.result = result
        op.done.trigger(result)
        return result

    # -- decision cache (termination storms) -------------------------------
    def _push_wrapper(self, cb, node: Optional[str]):
        """Storage→watcher push leg: the alive front-end replica's
        half-RTT toward the watching node, evaluated at fire time (the
        leader may have moved since the watch was registered).  With no
        replica alive there is nobody to push — the watcher stays unserved
        and the node times out normally."""
        if node is None:
            return cb

        def wrapped(value: Vote) -> None:
            li = self._leader_idx()
            if li is None:
                return
            delay = self.topology.rtt_ms(self.replica_regions[li],
                                         self._region_of(node)) / 2.0
            self.sim._schedule(self.sim.now + delay, lambda: cb(value))

        return wrapped

    def _cached_answer(self, value: Vote, writer: str,
                       fwd: Optional[_Forward], front_idx: int):
        """Post-decision LogOnce answered by the service front-end (the
        alive replica ``front_idx``): one caller↔service read, NO quorum
        round.  Samples a dedicated rng so the main service stream is
        untouched.  Callers must verify an alive front-end exists — a
        fully-dead service has nobody to serve the index."""
        self._dindex.hits += 1
        src = self._region_of(writer)
        if self.mode == "leader":
            net = self.topology.rtt_ms(src, self.replica_regions[front_idx])
        else:
            net = self.topology.rtt_ms(src, src)
        ms = net + self.model.sample(self._cache_rng, self.model.read_ms)
        done = self.sim.event()
        self.sim._schedule(self.sim.now + ms, lambda: done.trigger(value))
        if fwd is not None:
            done.subscribe(lambda e: fwd.deliver_now(e.value))
        return done

    # -- public SimStorage-compatible API ----------------------------------
    def log_once(self, partition: str, txn: str, state: Vote,
                 writer: str = "", forward_to: Optional[str] = None,
                 on_forward=None):
        """Quorum LogOnce; with ``forward_to``/``on_forward`` the service
        additionally pushes the slot's decided value to a third compute
        node: from the leader after its accept round in leader mode
        (cornus-opt1), from each acceptor with quorum counting at the
        target in coloc mode (paxos-commit)."""
        self.requests += 1
        key = (partition, txn)
        self._track(key)
        fwd = (None if on_forward is None
               else _Forward(self._region_of(forward_to), on_forward))
        if self._gc_index and key in self._gc_index:
            # Truncated slot: answer with the journaled decision (the
            # tombstone) — a late terminator must never re-claim the slot.
            ev = self._tombstone_answer(key, writer)
            if fwd is not None:
                ev.subscribe(lambda e: fwd.deliver_now(e.value))
            return self._recorded(ev, "log_once", partition, txn, state,
                                  writer)
        sfkey = (partition, txn, state.value)
        if self._dindex is not None:
            hit = self._dindex.lookup(txn)
            # Cache answers need an alive service front-end; during a total
            # outage the op falls through to the normal path (which waits
            # for a leader), so recovery timing is not understated.
            front = self._leader_idx()
            if hit is not None and front is not None:
                # The txn's log set already holds a terminal record: this
                # attempt can only read the decision — no Paxos round.
                return self._recorded(
                    self._cached_answer(hit, writer, fwd, front),
                    "log_once", partition, txn, state, writer)
            shared = self._dindex.join(sfkey)
            if shared is not None:
                # Identical quorum round in flight: share its result.
                self._dindex.singleflight_hits += 1
                if fwd is not None:
                    shared.subscribe(lambda e: fwd.deliver_now(e.value))
                return self._recorded(shared, "log_once", partition, txn,
                                      state, writer)
        if self._batchable(partition, writer):
            ev = self._submit_batched(
                _BatchOp("log_once", partition, txn, state, writer, fwd=fwd))
            if self._dindex is not None:
                self._dindex.lead(sfkey, ev)
            return self._recorded(self._observed(ev, lane=partition),
                                  "log_once", partition, txn, state, writer)

        def gen():
            if self.mode == "coloc":
                owner = bool(writer) and writer == partition
                result = yield from self._quorum_log_once(
                    self._region_of(writer), None, owner, key, state, writer,
                    forward=fwd)
            else:
                def inner(li, lr):
                    # The routed-to leader acquires (or holds) the epoch
                    # lease; with it, this op is ONE owner-ballot accept
                    # round — initial and post-failover leaders alike.
                    # Pinned slots (unrecovered in-flight values) must go
                    # through the full proposer, which adopts correctly.
                    has_lease = yield from self._ensure_lease(li)
                    if not has_lease:
                        self.lease_degradations += 1
                    fast = has_lease and key not in self._pinned
                    result = yield from self._quorum_log_once(
                        lr, li, fast, key, state, writer,
                        fast_ballot=(self._lease.ballot if fast
                                     else None))
                    return result

                result = yield from self._via_leader(writer, inner,
                                                     forward=fwd)
            result = self._tombstoned(key, result)
            if fwd is not None and not fwd.fired and not fwd.scheduled:
                # Raced/short-circuited paths (value already decided before
                # our accept round): the caller's reply doubles as the
                # forward source.
                fwd.deliver_now(result)
            self._note(partition, txn, result)
            return result

        ev = self.sim.process(gen())
        if self._dindex is not None:
            self._dindex.lead(sfkey, ev)
        return self._recorded(self._observed(ev, lane=partition),
                              "log_once", partition, txn, state, writer)

    def _log_event(self, partition: str, txn: str, state: Vote, writer: str,
                   mean_ms: float, n_records: int = 1):
        self.requests += 1
        key = (partition, txn)
        self._track(key)
        if self._gc_index and key in self._gc_index:
            return self._tombstone_answer(key, writer)
        if self._batchable(partition, writer):
            return self._observed(self._submit_batched(
                _BatchOp("log", partition, txn, state, writer,
                         n_records=n_records)), lane=partition)

        def gen():
            if self.mode == "coloc":
                result = yield from self._quorum_write(
                    self._region_of(writer), None, key, state, writer,
                    mean_ms)
            else:
                result = yield from self._via_leader(
                    writer, lambda li, lr: self._quorum_write(
                        lr, li, key, state, writer, mean_ms))
            result = self._tombstoned(key, result)
            self._note(partition, txn, result)
            return result

        return self._observed(self.sim.process(gen()), lane=partition)

    def log(self, partition: str, txn: str, state: Vote, writer: str = ""):
        return self._recorded(
            self._log_event(partition, txn, state, writer,
                            self.model.plain_write_ms),
            "log", partition, txn, state, writer)

    def log_batch(self, partition: str, txn: str, state: Vote,
                  n_records: int, writer: str = ""):
        # §5.6 batched record: a pre-formed n_records batch through the same
        # amortization model (and, when active, the same ingress lanes) as
        # storage-side group commit.
        return self._recorded(
            self._log_event(partition, txn, state, writer,
                            self.model.batched_write_ms(n_records),
                            n_records=n_records),
            "log_batch", partition, txn, state, writer)

    def read_state(self, partition: str, txn: str, writer: str = ""):
        self.requests += 1
        key = (partition, txn)
        if self._gc_index and key in self._gc_index:
            return self._recorded(self._tombstone_answer(key, writer),
                                  "read", partition, txn, None, writer)

        def gen():
            if self.mode == "coloc":
                result = yield from self._quorum_read(
                    self._region_of(writer), None, key)
            else:
                result = yield from self._via_leader(
                    writer, lambda li, lr: self._quorum_read(lr, li, key))
            result = self._tombstoned(key, result)
            self._note(partition, txn, result)
            return result

        return self._recorded(self.sim.process(gen()), "read", partition,
                              txn, None, writer)

    # -- durable-state lifecycle -------------------------------------------
    def _track(self, key: Tuple[str, str]) -> None:
        if self.lifecycle is None:
            return
        if key not in self._order_seen:
            self._order_seen.add(key)
            self._order.setdefault(key[0], []).append(key[1])

    def _tombstoned(self, key: Tuple[str, str], result):
        """Post-completion tombstone check: an op that was IN FLIGHT when
        ``gc_pass`` truncated its slot may have raced the truncation —
        e.g. a late terminator's accept round landing on the freshly
        emptied slot and "winning" a conflicting value.  The journaled
        decision is authoritative; the raced result must never surface."""
        e = self._gc_index.get(key) if self._gc_index else None
        if e is not None and e.decision is not None:
            return Vote(e.decision)
        return result

    def _tombstone_answer(self, key: Tuple[str, str], writer: str):
        """One read-cost round trip answering from the truncation journal
        (the GC watermark's tombstone for the slot)."""
        e = self._gc_index[key]
        value = Vote(e.decision)
        src = self._region_of(writer)
        if self.mode == "leader":
            li = self._leader_idx()
            dst = (self.replica_regions[li] if li is not None else src)
        else:
            dst = src
        ms = (self.topology.rtt_ms(src, dst)
              + self.model.sample(self.rng, self.model.read_ms))
        done = self.sim.event()
        self.sim._schedule(self.sim.now + ms, lambda: done.trigger(value))
        return done

    def gc_pass(self, now: float = 0.0) -> int:
        """Advance each partition's low-watermark past txns whose terminal
        decision is durable (decided) on a QUORUM of member disks and
        truncate the slots below it, journaling each removal."""
        lc = self.lifecycle
        if lc is None or not lc.gc:
            return 0
        members = [self.replicas[i] for i in self.member_ids]
        # (key, vote) -> copies on member disks; >= quorum copies of a
        # terminal value is quorum durability whether the slot was decided
        # by Paxos learn or a ``log``-path generation write.
        counts: Dict[Tuple[Tuple[str, str], str], int] = {}
        seen_keys = set()
        for r in members:
            seen_keys.update(r.keys())
        for k in seen_keys:
            if k in self._gc_index:
                # Resurrected garbage (an op that raced an earlier
                # truncation landed on the emptied slot): re-truncate and
                # keep it out of the census — the journal is authoritative.
                for r in members:
                    r.truncate(k)
                continue
            for r in members:
                v, _g, _d = r.read(k)
                if v is not None and v.is_decision():
                    ck = (k, v.value)
                    counts[ck] = counts.get(ck, 0) + 1
        settled: Dict[str, Vote] = {}
        for e in self.gc_log:
            if e.decision is not None:
                settled.setdefault(e.txn, Vote(e.decision))
        for (k, val), n_copies in counts.items():
            if n_copies >= self.quorum:
                settled.setdefault(k[1], Vote(val))
        n = 0
        for partition, order in self._order.items():
            wm = self.watermarks.get(partition, 0)
            while wm < len(order):
                txn = order[wm]
                key = (partition, txn)
                if key in self._gc_index:
                    wm += 1
                    continue
                dec = settled.get(txn)
                if dec is None:
                    break
                v, _g, _d = merge_reads([r.read(key) for r in members])
                e = GcEntry(partition, txn, None if v is None else v.value,
                            dec.value, True, at=self.sim.now)
                self.gc_log.append(e)
                self._gc_index[key] = e
                for r in members:
                    r.truncate(key)
                wm += 1
                n += 1
            if wm > self.watermarks.get(partition, 0):
                self.watermarks[partition] = wm
        self.gc_truncations += n
        return n

    def scrub_pass(self) -> int:
        """Anti-entropy: per-partition digest exchange among alive members,
        repair of divergent/corrupt replicas, quarantine + state transfer
        for members past the corrupt threshold.  Instant-apply (the sim's
        background maintenance plane does not contend with foreground
        quorum traffic for service time)."""
        lc = self.lifecycle
        if lc is None or not lc.scrub:
            return 0
        alive = [(i, self.replicas[i]) for i in self.member_ids
                 if self.replica_alive(i)]
        if len(alive) < 2:
            return 0
        digests = [r.partition_digests() for _i, r in alive]
        all_parts = set()
        for dg in digests:
            all_parts.update(dg)
        suspect_parts = {p for p in all_parts
                         if len({dg.get(p) for dg in digests}) > 1}
        corrupt_by = {i: set(r.corrupt_keys()) for i, r in alive}
        self.corrupt_records += sum(len(ks) for ks in corrupt_by.values())
        keys = set()
        for _i, r in alive:
            keys.update(k for k in r.keys() if k[0] in suspect_parts)
        for ks in corrupt_by.values():
            keys.update(ks)
        repaired = 0
        for k in sorted(keys):
            if k in self._gc_index:
                for _i, r in alive:
                    r.truncate(k)
                continue
            reads = [(r, r.read(k)) for _i, r in alive]
            v, g, d = merge_reads([rd for _r, rd in reads])
            if v is None and not d:
                continue
            for r, (rv, rg, rd) in reads:
                if rg < g or (d and not rd) or (v is not None
                                                and rv is None):
                    r.repair(k, v, g, d)
                    repaired += 1
        self.scrub_repairs += repaired
        for i, _r in alive:
            if len(corrupt_by[i]) >= lc.quarantine_threshold:
                self.quarantines += 1
                self._sim_copy_image(self.member_ids, i)
        return repaired

    def bitflip(self, rng: random.Random) -> bool:
        """Chaos hook: rot one decided, repairable slot record on one
        member replica (another member must hold an intact decided copy,
        so the scrubber — or lazy read repair — can fix it)."""
        if self.lifecycle is None:
            return False
        members = [self.replicas[i] for i in self.member_ids]
        holders: Dict[Tuple[str, str], List[ReplicaLog]] = {}
        for r in members:
            for k in r.keys():
                v, _g, d = r.read(k)
                if v is not None and d:
                    holders.setdefault(k, []).append(r)
        cands = sorted(k for k, rs in holders.items() if len(rs) >= 2)
        if not cands:
            return False
        key = cands[rng.randrange(len(cands))]
        rs = holders[key]
        victim = rs[rng.randrange(len(rs))]
        return victim.corrupt_slot(key)

    def partition_log(self, partition: str) -> List[Tuple[str, str]]:
        order = self._order.get(partition)
        if order is not None:
            wm = self.watermarks.get(partition, 0)
            return [(partition, t) for t in order[wm:]
                    if (partition, t) not in self._gc_index]
        keys = set()
        for i in self.member_ids:
            keys.update(k for k in self.replicas[i].keys()
                        if k[0] == partition)
        return sorted(keys)

    def is_truncated(self, key: Tuple[str, str]) -> bool:
        return key in self._gc_index

    def watermark_lag(self) -> int:
        return sum(len(order) - self.watermarks.get(p, 0)
                   for p, order in self._order.items())

    def snapshot(self) -> Dict[Tuple[str, str], Vote]:
        """Merged view over every MEMBER replica's disk (ground truth for
        tests); retired replicas' stale volumes are never consulted."""
        members = [self.replicas[i] for i in self.member_ids]
        keys = set()
        for r in members:
            keys.update(r.keys())
        out = {}
        for k in keys:
            if k in self._gc_index:
                continue      # truncated slots live in the journal
            v, _, _ = merge_reads([r.read(k) for r in members])
            if v is not None:
                out[k] = v
        return out


# --------------------------------------------------------------------------
# Threaded group commit: BatchingStore decorator
# --------------------------------------------------------------------------
class _ThreadBatchOp:
    __slots__ = ("kind", "args", "event", "result", "error", "promoted")

    def __init__(self, kind: str, args: tuple):
        self.kind = kind
        self.args = args
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None
        self.promoted = False          # woken to LEAD, not with a result


class _ThreadLane:
    __slots__ = ("lock", "pending", "leader_active")

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.pending: List[_ThreadBatchOp] = []
        self.leader_active = False


class BatchingStore:
    """Group-commit decorator for the threaded stores (``MemoryStore`` /
    ``FileStore`` / ``ReplicatedStore``).

    Same blocking three-operation surface as the wrapped store.  Concurrent
    ``log_once`` / ``log`` calls targeting one partition coalesce: the first
    caller becomes the batch *leader*, sleeps ``window_s`` collecting
    followers, then applies every queued op against the inner store in
    arrival order — one leader round trip (``round_trips``) per batch —
    and hands each follower its own result (or exception, e.g.
    ``QuorumUnavailable``).  Arrival order decides first-writer-wins per
    slot exactly as unbatched calls would; reads pass straight through.

    ``window_s=0`` still batches whatever queued while the previous leader
    was executing (piggyback group commit), which is the recommended
    deployment: zero added latency when idle, amortization under load.
    """

    def __init__(self, inner, window_s: float = 0.0,
                 max_batch: int = 64) -> None:
        assert max_batch >= 1
        self.inner = inner
        self.window_s = window_s
        self.max_batch = max_batch
        self._lanes: Dict[str, _ThreadLane] = {}
        self._lanes_lock = threading.Lock()
        self.round_trips = 0
        self.batched_ops = 0

    # Everything not intercepted (read_state, writer_of, snapshot, log_data,
    # put_data/get_data, fail_replica, cas_attempts, ...) delegates.
    def __getattr__(self, name: str):
        return getattr(self.inner, name)

    def _lane(self, partition: str) -> _ThreadLane:
        with self._lanes_lock:
            lane = self._lanes.get(partition)
            if lane is None:
                lane = self._lanes[partition] = _ThreadLane()
            return lane

    def _apply(self, op: _ThreadBatchOp) -> None:
        try:
            fn = getattr(self.inner, op.kind)
            op.result = fn(*op.args)
        except BaseException as e:          # surfaced in the caller's thread
            op.error = e

    def _submit(self, partition: str, op: _ThreadBatchOp) -> Vote:
        lane = self._lane(partition)
        with lane.lock:
            lane.pending.append(op)
            lead = not lane.leader_active
            if lead:
                lane.leader_active = True
        if not lead:
            op.event.wait()
            if op.promoted:
                # The previous leader finished its round with ops (ours
                # included) still queued and handed leadership over, so no
                # caller ever leads more than one round (a leader trapped
                # draining other threads' ops would see unbounded latency).
                lead = True
        if lead:
            # ONE leader round: our op was queued before we took
            # leadership, so it is always in this batch.
            if self.window_s > 0:
                time.sleep(self.window_s)
            with lane.lock:
                batch = lane.pending[:self.max_batch]
                lane.pending = lane.pending[self.max_batch:]
            # One round trip for the whole batch.
            self.round_trips += 1
            self.batched_ops += len(batch)
            for b in batch:
                self._apply(b)
            with lane.lock:
                nxt = lane.pending[0] if lane.pending else None
                if nxt is None:
                    lane.leader_active = False
                else:
                    nxt.promoted = True
            for b in batch:
                if b is not op:
                    b.event.set()
            if nxt is not None:
                nxt.event.set()
        if op.error is not None:
            raise op.error
        return op.result

    def log_once(self, partition: str, txn: str, state: Vote,
                 writer: str = "") -> Vote:
        return self._submit(partition, _ThreadBatchOp(
            "log_once", (partition, txn, state, writer)))

    def log(self, partition: str, txn: str, state: Vote,
            writer: str = "") -> Vote:
        return self._submit(partition, _ThreadBatchOp(
            "log", (partition, txn, state, writer)))
