"""Transaction-state vocabulary for Cornus / 2PC (paper §3.2, §3.5).

A transaction's *state record* in a participant's log is one of
VOTE_YES / COMMIT / ABORT.  ``LogOnce`` semantics: the first write of a
transaction's state wins; later writes return the existing state.

The *global decision* (paper Definition 1):
  COMMIT  iff every participant's log holds VOTE_YES (or COMMIT),
  ABORT   iff any participant's log holds ABORT,
  UNDETERMINED otherwise.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence


class Vote(enum.Enum):
    """State record types that may appear in a transaction log."""

    VOTE_YES = "VOTE-YES"
    COMMIT = "COMMIT"
    ABORT = "ABORT"

    def is_decision(self) -> bool:
        return self in (Vote.COMMIT, Vote.ABORT)


class Decision(enum.Enum):
    COMMIT = "COMMIT"
    ABORT = "ABORT"
    UNDETERMINED = "UNDETERMINED"


def global_decision(states: Dict[str, Optional[Vote]],
                    participants: Sequence[str]) -> Decision:
    """Paper Definition 1, evaluated over a snapshot of all logs."""
    votes = [states.get(p) for p in participants]
    if any(v == Vote.ABORT for v in votes):
        return Decision.ABORT
    if all(v in (Vote.VOTE_YES, Vote.COMMIT) for v in votes):
        return Decision.COMMIT
    return Decision.UNDETERMINED


@dataclass
class LogRecord:
    """One record in a per-partition transaction-state log."""

    txn_id: str
    state: Vote
    # Who wrote the record: the owning participant, or a peer running the
    # termination protocol on its behalf (paper Alg. 1 line 28).
    writer: str = ""
    # Event-time of the write (simulated ms, or wall-clock in live mode).
    at: float = 0.0


@dataclass
class TxnOutcome:
    """What a single node concluded about a transaction, and when."""

    txn_id: str
    node: str
    decision: Decision
    # Caller-observed latency (coordinator only): from protocol start to the
    # moment the decision could be returned to the txn caller.
    caller_latency_ms: Optional[float] = None
    # Full completion time of this node's local protocol.
    done_at_ms: float = 0.0
    # Phase breakdown for Fig. 6(b,d)-style plots.
    prepare_ms: float = 0.0
    commit_ms: float = 0.0
    ran_termination: bool = False
    termination_ms: float = 0.0


@dataclass
class TxnSpec:
    """Static description of one distributed transaction's commit run."""

    txn_id: str
    coordinator: str
    participants: Sequence[str]  # includes coordinator iff it owns a partition
    # Per-participant vote it *would* cast (True = yes). Abort votes model
    # local conflicts (e.g. NO-WAIT lock failures during execution).
    votes: Dict[str, bool] = field(default_factory=dict)
    # Participants that only read (paper §3.6).
    read_only: frozenset = frozenset()
    # Whether read-only-ness is known to the coordinator before 2PC starts.
    read_only_known_upfront: bool = True

    def vote_of(self, p: str) -> bool:
        return self.votes.get(p, True)

    @property
    def all_read_only(self) -> bool:
        return set(self.participants) <= set(self.read_only)
