"""Cornus atomic commit — the paper's core contribution.

Public surface:
  state      – Vote / Decision / TxnSpec / global_decision (Def. 1)
  storage    – MemoryStore / FileStore / SimStorage + latency models
  protocols  – pluggable commit-protocol API: Transport + TxnContext +
               CommitProtocol strategies, register()/get_protocol() registry
               (cornus, 2pc, cl, cornus-opt1, paxos-commit)
  protocol   – Cluster facade wiring the three together (back-compat)
  variants   – Table-3 RTT model + runnable deployments per row
  sim        – deterministic discrete-event kernel
  chaos      – seeded FaultSchedule + Nemesis fault injection, retry
               policy / circuit breaker, failure-repro bundles
  history    – operation histories + AC1–AC3 / writer-of /
               recoverability / AC-GC checker (machine-verified safety)
  lifecycle  – checksummed record framing, LifecycleConfig, GC journal
"""
from .sim import Sim
from .state import Decision, TxnOutcome, TxnSpec, Vote, global_decision
from .control import (AdaptiveTimeouts, DecisionCacheConfig, DecisionIndex,
                      EwmaStat, LeaseKeeper, QuorumUnavailable,
                      ThreadControlPlane)
from .storage import (AZURE_BLOB, AZURE_BLOB_SEPARATE_ACL, AZURE_REDIS,
                      COMPUTE_RTT_MS, CROSS_REGION, CROSS_ZONE, INTRA_ZONE,
                      SLOW_REDIS, BatchConfig, BatchingStore, FileStore,
                      GroupCommitIngress, LatencyModel, MembershipConfig,
                      MemoryStore, RegionTopology, ReplicaLog,
                      ReplicatedSimStorage, ReplicatedStore, SimStorage,
                      StoreLease, merge_reads)
from .stores import (StoreConfig, build_store, get_store,
                     register_store, registered_stores)
from .chaos import (BitFlip, ChaosStore, CircuitBreaker, ClockSkew,
                    CrashRestart, FaultSchedule, GuardedStorage, LinkChaos,
                    Nemesis, NetPartition, RetryPolicy, TornTail, TornWrite,
                    Truncation, load_repro_bundle, write_repro_bundle)
from .history import (HistoryOp, HistoryRecorder, Violation, check_history,
                      check_run, collect_decisions)
from .lifecycle import (CorruptRecord, GcEntry, LifecycleConfig,
                        decode_record, encode_record)
from .protocols import (CommitProtocol, Transport, TxnContext, get_protocol,
                        register, registered_protocols)
from .protocol import Cluster, ProtocolConfig
from .variants import (SIMULATED_RTT_ROWS,
                       measured_caller_latency_ms,
                       predicted_caller_latency_ms, rtt_table)

__all__ = [
    "Sim", "Decision", "TxnOutcome", "TxnSpec", "Vote", "global_decision",
    "MemoryStore", "FileStore", "SimStorage", "LatencyModel",
    "AZURE_REDIS", "AZURE_BLOB", "AZURE_BLOB_SEPARATE_ACL", "SLOW_REDIS",
    "COMPUTE_RTT_MS", "Cluster", "ProtocolConfig",
    "CommitProtocol", "Transport", "TxnContext",
    "register", "get_protocol", "registered_protocols",
    "rtt_table", "predicted_caller_latency_ms", "measured_caller_latency_ms",
    "SIMULATED_RTT_ROWS",
    "RegionTopology", "INTRA_ZONE", "CROSS_ZONE", "CROSS_REGION",
    "ReplicatedStore", "ReplicatedSimStorage", "ReplicaLog", "merge_reads",
    "QuorumUnavailable", "StoreLease", "MembershipConfig",
    "BatchConfig", "BatchingStore", "GroupCommitIngress",
    "DecisionCacheConfig", "DecisionIndex", "AdaptiveTimeouts", "EwmaStat",
    "LeaseKeeper", "ThreadControlPlane",
    "StoreConfig", "build_store", "get_store",
    "register_store", "registered_stores",
    "FaultSchedule", "Nemesis", "LinkChaos", "NetPartition", "ClockSkew",
    "TornWrite", "CrashRestart", "BitFlip", "TornTail", "Truncation",
    "RetryPolicy", "CircuitBreaker",
    "GuardedStorage", "ChaosStore", "write_repro_bundle",
    "load_repro_bundle",
    "HistoryOp", "HistoryRecorder", "Violation", "check_history",
    "check_run", "collect_decisions",
    "CorruptRecord", "GcEntry", "LifecycleConfig",
    "encode_record", "decode_record",
]
