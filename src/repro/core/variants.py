"""Table-3 deployment rows + the analytical RTT model (§5.6, extended §6).

The protocol *implementations* live in ``repro.core.protocols`` (one
registered strategy class per family member).  This module keeps:

* ``rtt_table()`` — the analytical RTT model of Table 3 for protocols
  integrating with Paxos-replicated storage.
* ``SIMULATED_RTT_ROWS`` — every Table-3 row's runnable deployment:
  (registered protocol name, replicated-storage mode).
* ``measured_caller_latency_ms()`` — runs one commit per row on the
  discrete-event sim and must land EXACTLY on the analytic RTT multiple.
"""
from __future__ import annotations

from typing import Dict

from .protocol import Cluster, ProtocolConfig
from .state import Decision, TxnSpec


def rtt_table() -> Dict[str, Dict]:
    """Table 3: RTTs on the critical path when storage is Paxos-replicated.

    Counted from coordinator starting the protocol until the decision can be
    returned to the caller, as `prepare + commit = total` RTTs.
    """
    rows = {
        "2pc": dict(prepare=3.0, commit=2.0,
                    requires=[]),
        "cornus": dict(prepare=3.0, commit=0.0,
                       requires=["storage supports conditional write"]),
        "cornus-opt1": dict(prepare=2.5, commit=0.0,
                            requires=["paxos leader forwards to coordinator"]),
        "2pc-coloc": dict(prepare=2.0, commit=1.0,
                          requires=["participant coordinates replication"]),
        "cornus-coloc": dict(prepare=2.0, commit=0.0,
                             requires=["participant coordinates replication"]),
        "paxos-commit": dict(prepare=1.5, commit=0.0,
                             requires=["participant coordinates replication",
                                       "acceptors forward to coordinator"]),
    }
    for r in rows.values():
        r["total"] = r["prepare"] + r["commit"]
    return rows


def predicted_caller_latency_ms(protocol: str, paxos_rtt_ms: float) -> float:
    """Caller latency predicted by Table 3 given one inter-replica RTT."""
    return rtt_table()[protocol]["total"] * paxos_rtt_ms


# Every Table-3 row now has a runnable simulated deployment:
# row name -> (registered protocol name, replicated-storage mode).
SIMULATED_RTT_ROWS = {
    "2pc": ("2pc", "leader"),
    "cornus": ("cornus", "leader"),
    "cornus-opt1": ("cornus-opt1", "leader"),
    "2pc-coloc": ("2pc", "coloc"),
    "cornus-coloc": ("cornus", "coloc"),
    "paxos-commit": ("paxos-commit", "coloc"),
}


def measured_caller_latency_ms(protocol: str, paxos_rtt_ms: float,
                               n_participants: int = 2,
                               n_replicas: int = 3,
                               seed: int = 0,
                               batch_window_ms: float = 0.0,
                               storm_control: bool = False) -> float:
    """Measured counterpart of ``predicted_caller_latency_ms``.

    Runs ONE commit on the discrete-event sim against a quorum-replicated
    store under a uniform topology where every link (compute↔compute,
    compute↔storage, inter-replica) costs ``paxos_rtt_ms`` and service
    times are ZERO — so the result lands exactly on Table 3's RTT
    multiples (validated with equality, not a tolerance, in the tests).

    ``batch_window_ms`` threads the storage-ingress group-commit window
    through: 0 (the default) is the exact passthrough the equality check
    runs against; a positive window exercises the batched fast path (adds
    up to one window of queueing delay to each logged vote).

    ``storm_control`` enables the full termination-storm stack (storage
    decision cache + singleflight + push, compute-side termination dedup)
    — on the no-failure critical path NONE of it may fire, so the measured
    latency must stay EXACTLY on the Table-3 prediction (tested).
    """
    from .sim import Sim
    from .storage import (BatchConfig, DecisionCacheConfig, LatencyModel,
                          RegionTopology, ReplicatedSimStorage)

    if protocol not in SIMULATED_RTT_ROWS:
        raise ValueError(f"no simulated deployment for {protocol!r}; "
                         f"one of {sorted(SIMULATED_RTT_ROWS)}")
    proto, mode = SIMULATED_RTT_ROWS[protocol]
    topo = RegionTopology.uniform("table3", ("r0",), paxos_rtt_ms)
    model = LatencyModel("paxos-null", conditional_write_ms=0.0,
                         plain_write_ms=0.0, read_ms=0.0, jitter=0.0)
    sim = Sim()
    storage = ReplicatedSimStorage(
        sim, model, n_replicas=n_replicas, seed=seed, topology=topo,
        mode=mode, batch=BatchConfig(window_ms=batch_window_ms,
                                     serial=batch_window_ms > 0),
        decisions=DecisionCacheConfig(cache=storm_control,
                                      singleflight=storm_control,
                                      push=storm_control))
    nodes = ["c"] + [f"p{i}" for i in range(n_participants)]
    tmo = 50.0 * paxos_rtt_ms
    cfg = ProtocolConfig(protocol=proto, topology=topo,
                         vote_timeout_ms=tmo, decision_timeout_ms=tmo,
                         votereq_timeout_ms=tmo, termination_retry_ms=tmo,
                         coop_retry_ms=tmo,
                         push_decisions=storm_control,
                         termination_dedup=storm_control)
    cl = Cluster(sim, storage, nodes, cfg)
    # Pure coordinator (owns no partition) — Table 3's accounting.
    spec = TxnSpec(txn_id="t3", coordinator="c",
                   participants=[n for n in nodes if n != "c"])
    cl.run_txn(spec)
    sim.run(until=1000.0 * paxos_rtt_ms)
    out = cl.outcomes[("t3", "c")]
    assert out.decision == Decision.COMMIT, out
    return out.caller_latency_ms
