"""Protocol variants evaluated in §5.6 and Table 3.

* ``CoordinatorLogCluster`` — the coordinator-log (CL) optimization
  [Stamos & Cristian]: participants reply votes WITHOUT logging; the
  coordinator batches all participants' logs + its decision into ONE storage
  write, then replies to the caller.  Faster than 2PC (one batched write vs
  sequential prepare-then-decision), slower than Cornus (the caller still
  waits for a storage write), and it violates site autonomy (§5.6).

* ``rtt_table()`` — the analytical RTT model of Table 3 for protocols
  integrating with Paxos-replicated storage.
"""
from __future__ import annotations

from typing import Dict, List

from .protocol import Cluster, ProtocolConfig
from .state import Decision, TxnOutcome, TxnSpec, Vote


class CoordinatorLogCluster(Cluster):
    """2PC with centralized (coordinator) logging — §5.6 'CL'."""

    def _coordinator(self, spec: TxnSpec):
        cfg, sim, me = self.cfg, self.sim, spec.coordinator
        txn = spec.txn_id
        t0 = sim.now
        out = TxnOutcome(txn_id=txn, node=me, decision=Decision.UNDETERMINED)

        if spec.all_read_only and spec.read_only_known_upfront:
            out.decision = Decision.COMMIT
            out.caller_latency_ms = 0.0
            out.done_at_ms = sim.now
            self._decide(me, txn, Decision.COMMIT)
            self._record(out)
            return out

        for p in spec.participants:
            if p != me:
                self.send(me, p, txn, "vote-req",
                          {"participants": list(spec.participants)})
        pending = [p for p in spec.participants if p != me]
        waits = [self.wait(me, txn, f"vote:{p}", cfg.vote_timeout_ms)
                 for p in pending]
        results = yield self.sim.all_of(waits)
        prepare_done = sim.now
        out.prepare_ms = prepare_done - t0
        my_vote = "VOTE-YES" if spec.vote_of(me) else "ABORT"
        any_abort = (any(tag == "msg" and val == "ABORT"
                         for tag, val in results)
                     or any(tag == "timeout" for tag, val in results)
                     or my_vote == "ABORT")
        decision = Decision.ABORT if any_abort else Decision.COMMIT

        # ONE batched write: every participant's redo log + the decision.
        yield self.storage.log_batch(
            me, txn, Vote.COMMIT if decision == Decision.COMMIT
            else Vote.ABORT, n_records=len(spec.participants) + 1, writer=me)
        if not self.alive(me):
            return out

        out.decision = decision
        out.caller_latency_ms = sim.now - t0
        out.commit_ms = sim.now - prepare_done
        self._decide(me, txn, decision)
        for p in pending:
            self.send(me, p, txn, "decision", decision)
        out.done_at_ms = sim.now
        self._record(out)
        return out

    def _participant(self, spec: TxnSpec, me: str):
        cfg, sim = self.cfg, self.sim
        txn = spec.txn_id
        if me == spec.coordinator:
            return
        out = TxnOutcome(txn_id=txn, node=me, decision=Decision.UNDETERMINED)

        if spec.all_read_only and spec.read_only_known_upfront:
            self._decide(me, txn, Decision.COMMIT)
            out.decision = Decision.COMMIT
            self._record(out)
            return out

        tag, msg = yield self.wait(me, txn, "vote-req", cfg.votereq_timeout_ms)
        if tag == "timeout" or not self.alive(me):
            self._decide(me, txn, Decision.ABORT)
            out.decision = Decision.ABORT
            self._record(out)
            return out
        st = self._local(me, txn)
        # CL: reply the vote immediately — NO local logging. The vote reply
        # carries this participant's redo records (bigger ack message, §5.6).
        vote = "VOTE-YES" if spec.vote_of(me) else "ABORT"
        st["status"] = "voted"
        self.send(me, spec.coordinator, txn, f"vote:{me}", vote)
        tag, decision = yield self.wait(me, txn, "decision",
                                        cfg.decision_timeout_ms)
        if tag == "msg":
            self._decide(me, txn, decision)
            out.decision = decision
        out.done_at_ms = sim.now
        self._record(out)
        return out


def rtt_table() -> Dict[str, Dict]:
    """Table 3: RTTs on the critical path when storage is Paxos-replicated.

    Counted from coordinator starting the protocol until the decision can be
    returned to the caller, as `prepare + commit = total` RTTs.
    """
    rows = {
        "2pc": dict(prepare=3.0, commit=2.0,
                    requires=[]),
        "cornus": dict(prepare=3.0, commit=0.0,
                       requires=["storage supports conditional write"]),
        "cornus-opt1": dict(prepare=2.5, commit=0.0,
                            requires=["paxos leader forwards to coordinator"]),
        "2pc-coloc": dict(prepare=2.0, commit=1.0,
                          requires=["participant coordinates replication"]),
        "cornus-coloc": dict(prepare=2.0, commit=0.0,
                             requires=["participant coordinates replication"]),
        "paxos-commit": dict(prepare=1.5, commit=0.0,
                             requires=["participant coordinates replication",
                                       "acceptors forward to coordinator"]),
    }
    for r in rows.values():
        r["total"] = r["prepare"] + r["commit"]
    return rows


def predicted_caller_latency_ms(protocol: str, paxos_rtt_ms: float) -> float:
    """Caller latency predicted by Table 3 given one inter-replica RTT."""
    return rtt_table()[protocol]["total"] * paxos_rtt_ms


# Table-3 rows the replicated simulator can actually run, and the storage
# deployment mode each corresponds to.
SIMULATED_RTT_ROWS = {
    "2pc": ("2pc", "leader"),
    "cornus": ("cornus", "leader"),
    "2pc-coloc": ("2pc", "coloc"),
    "cornus-coloc": ("cornus", "coloc"),
}


def measured_caller_latency_ms(protocol: str, paxos_rtt_ms: float,
                               n_participants: int = 2,
                               n_replicas: int = 3,
                               seed: int = 0) -> float:
    """Measured counterpart of ``predicted_caller_latency_ms``.

    Runs ONE commit on the discrete-event sim against a quorum-replicated
    store under a uniform topology where every link (compute↔compute,
    compute↔storage, inter-replica) costs ``paxos_rtt_ms`` and service times
    are negligible — so the result should land on Table 3's RTT multiples.
    """
    from .sim import Sim
    from .storage import LatencyModel, RegionTopology, ReplicatedSimStorage

    if protocol not in SIMULATED_RTT_ROWS:
        raise ValueError(f"no simulated deployment for {protocol!r}; "
                         f"one of {sorted(SIMULATED_RTT_ROWS)}")
    base, mode = SIMULATED_RTT_ROWS[protocol]
    topo = RegionTopology.uniform("table3", ("r0",), paxos_rtt_ms)
    model = LatencyModel("paxos-null", conditional_write_ms=1e-3,
                         plain_write_ms=1e-3, read_ms=1e-3, jitter=0.0)
    sim = Sim()
    storage = ReplicatedSimStorage(sim, model, n_replicas=n_replicas,
                                   seed=seed, topology=topo, mode=mode)
    nodes = ["c"] + [f"p{i}" for i in range(n_participants)]
    tmo = 50.0 * paxos_rtt_ms
    cfg = ProtocolConfig(protocol=base, topology=topo,
                         vote_timeout_ms=tmo, decision_timeout_ms=tmo,
                         votereq_timeout_ms=tmo, termination_retry_ms=tmo,
                         coop_retry_ms=tmo)
    cl = Cluster(sim, storage, nodes, cfg)
    # Pure coordinator (owns no partition) — Table 3's accounting.
    spec = TxnSpec(txn_id="t3", coordinator="c",
                   participants=[n for n in nodes if n != "c"])
    cl.run_txn(spec)
    sim.run(until=1000.0 * paxos_rtt_ms)
    out = cl.outcomes[("t3", "c")]
    assert out.decision == Decision.COMMIT, out
    return out.caller_latency_ms
