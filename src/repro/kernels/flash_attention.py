"""Flash attention for TPU (Pallas): blocked online-softmax, MXU-aligned.

TPU-native formulation (not a CUDA port):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the kv dim is the
    innermost sequential dim, with fp32 running (m, l, acc) carried in VMEM
    scratch across kv iterations — the canonical TPU flash pattern.
  * BlockSpecs tile q/k/v into (block_q × head_dim) / (block_kv × head_dim)
    VMEM tiles; block sizes default to 128 (MXU lane width).
  * GQA handled by the k/v index_map (q head h reads kv head h // group).
  * Supports causal masking, sliding windows (local attention), gemma-style
    logit soft-capping, and decode-time kv_len masking — the same contract
    as ``repro.models.layers.attention``.

Validated in interpret mode on CPU against ``ref.attention_ref`` over a
shape/dtype sweep (tests/test_kernels.py); compiled path requires a real
TPU backend.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, softcap, kv_len, block_q, block_kv,
            n_kv_blocks, q_offset):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0) + q_offset
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    keep = jnp.ones((block_q, block_kv), jnp.bool_)
    if causal:
        keep &= k_pos <= q_pos
        if window > 0:
            keep &= (q_pos - k_pos) < window
    if kv_len is not None:
        keep &= k_pos < kv_len
    s = jnp.where(keep, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    q_offset=0, kv_len=None,
                    block_q=DEFAULT_BLOCK_Q, block_kv=DEFAULT_BLOCK_KV,
                    interpret=False):
    """q: (B,Hq,Sq,hd)  k,v: (B,Hkv,Skv,hd) -> (B,Hq,Sq,hd)."""
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    n_q = -(-Sq // block_q)
    n_kv = -(-Skv // block_kv)
    pad_q, pad_kv = n_q * block_q - Sq, n_kv * block_kv - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        # Padded kv columns must be masked out.
        kv_len = Skv if kv_len is None else kv_len

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(hd), causal=causal, window=window,
        softcap=softcap, kv_len=kv_len, block_q=block_q, block_kv=block_kv,
        n_kv_blocks=n_kv, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b, h, qi, ki, g=g: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, hd),
                         lambda b, h, qi, ki, g=g: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, n_q * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
