"""Chunked selective-scan (Mamba) for TPU (Pallas).

The XLA path materializes per-chunk state tensors in HBM ((chunk,B,di,N)
fp32 — the §Roofline memory-bound term for jamba).  This kernel keeps the
running SSM state (di_block × N) resident in VMEM scratch across the whole
sequence: grid = (batch, di_blocks, chunks) with chunks innermost-
sequential; each step loads one (chunk × di_block) tile of u/dt and one
(chunk × N) tile of b/c, runs the recurrence, writes y, and carries h in
VMEM — HBM traffic is exactly one read of the inputs + one write of y.

The in-chunk loop is a fori over time steps on (di_block, N) tiles — on TPU
these are VPU element-wise ops; hardware-efficient variants reformulate to
MXU matmuls, which does not change the HBM traffic this kernel eliminates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64
DEFAULT_DI_BLOCK = 256


def _kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, h0_ref, y_ref, hout_ref,
            h_scr, *, chunk, n_chunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)        # (bd, N)

    a = a_ref[...].astype(jnp.float32)                    # (bd, N)

    def step(t, h):
        u_t = u_ref[0, t].astype(jnp.float32)             # (bd,)
        dt_t = dt_ref[0, t].astype(jnp.float32)           # (bd,)
        b_t = b_ref[0, t].astype(jnp.float32)             # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)             # (N,)
        abar = jnp.exp(dt_t[:, None] * a)                 # (bd, N)
        h = abar * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y_ref[0, t] = (h @ c_t).astype(y_ref.dtype)       # (bd,)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, step, h_scr[...])

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hout_ref[0] = h_scr[...]


def mamba_scan(u, dt, a, b, c, h0, *, chunk=DEFAULT_CHUNK,
               di_block=DEFAULT_DI_BLOCK, interpret=False):
    """u,dt: (B,S,di)  a: (di,N)  b,c: (B,S,N)  h0: (B,di,N) fp32.

    Returns (y (B,S,di), h_last (B,di,N) fp32).
    """
    B, S, di = u.shape
    N = a.shape[-1]
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    di_block = min(di_block, di)
    n_di = -(-di // di_block)
    assert di % di_block == 0, (di, di_block)
    pad = n_chunks * chunk - S
    if pad:
        # dt=0 padding is the identity update (abar=1, bbar=0).
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, n_di, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, di_block), lambda bi, d, ci: (bi, ci, d)),
            pl.BlockSpec((1, chunk, di_block), lambda bi, d, ci: (bi, ci, d)),
            pl.BlockSpec((di_block, N), lambda bi, d, ci: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, d, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, d, ci: (bi, ci, 0)),
            pl.BlockSpec((1, di_block, N), lambda bi, d, ci: (bi, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, di_block), lambda bi, d, ci: (bi, ci, d)),
            pl.BlockSpec((1, di_block, N), lambda bi, d, ci: (bi, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_chunks * chunk, di), u.dtype),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((di_block, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, a, b, c, h0)
    return y[:, :S], h_last
