"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` — the
kernel body runs in Python per grid step, bit-faithful to the TPU dataflow.
On a TPU backend the same calls compile through Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax

from .decode_attention import flash_decode
from .flash_attention import flash_attention
from .mamba_scan import mamba_scan
from .mlstm_scan import mlstm_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return flash_attention(q, k, v, **kw)


def decode_attention(q, k, v, kv_len, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return flash_decode(q, k, v, kv_len, **kw)


def selective_scan(u, dt, a, b, c, h0, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return mamba_scan(u, dt, a, b, c, h0, **kw)


def mlstm(q, k, v, i_gate, f_gate, c0, **kw):
    kw.setdefault("interpret", not _on_tpu())
    return mlstm_scan(q, k, v, i_gate, f_gate, c0, **kw)
