"""Flash-decode for TPU (Pallas): single-query attention over a long cache.

Decode reads ONE query token against a seq_len KV cache — the op is purely
memory-bound (arithmetic intensity ≈ 1 flop/byte), so the kernel's job is to
stream K/V through VMEM exactly once with fp32 online-softmax carries.

grid = (batch, q_heads, kv_blocks); kv innermost-sequential with VMEM
scratch (m, l, acc) — same carry discipline as flash_attention but with a
q tile of the GQA group size instead of a seq block.  kv_len masks the
valid prefix of the preallocated cache.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_KV = 512
NEG_INF = -1e30


def _kernel(qlen_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, block_kv, n_kv_blocks, softcap):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale            # (g, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    kv_len = qlen_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (g, bk)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(k_pos < kv_len, s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_decode(q, k, v, kv_len, *, softcap=0.0,
                 block_kv=DEFAULT_BLOCK_KV, interpret=False):
    """q: (B,Hq,1,hd)  k,v: (B,Hkv,T,hd)  kv_len: scalar int32.

    Returns (B,Hq,1,hd).  The GQA group (g = Hq/Hkv) rides in the q tile so
    the MXU sees a (g × hd)·(hd × bk) matmul per block.
    """
    B, Hq, one, hd = q.shape
    assert one == 1
    Hkv, T = k.shape[1], k.shape[2]
    g = Hq // Hkv
    block_kv = min(block_kv, T)
    n_kv = -(-T // block_kv)
    pad = n_kv * block_kv - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    # regroup q: (B, Hkv, g, hd)
    qg = q[:, :, 0].reshape(B, Hkv, g, hd)
    kv_len_arr = jnp.full((1,), kv_len, jnp.int32) if jnp.ndim(kv_len) == 0 \
        else kv_len.reshape(1).astype(jnp.int32)

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(hd), block_kv=block_kv,
        n_kv_blocks=n_kv, softcap=softcap)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, n_kv),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # kv_len, tiny
            pl.BlockSpec((1, 1, g, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len_arr, qg.reshape(B, Hkv, g, hd), k, v)
    return out.reshape(B, Hq, 1, hd)
