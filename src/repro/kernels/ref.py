"""Pure-jnp oracles for every Pallas kernel (independent, naive math).

These are deliberately the SIMPLEST correct implementations — materialized
masks, sequential scans — so kernel tests compare against unambiguous
ground truth rather than against another optimized path.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0,
                  q_offset=0, kv_len=None):
    """q: (B,Hq,Sq,hd)  k,v: (B,Hkv,Skv,hd)  ->  (B,Hq,Sq,hd). fp32 math."""
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(hd)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Skv)
    keep = jnp.ones((Sq, Skv), bool)
    if causal:
        keep &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            keep &= (q_pos[:, None] - k_pos[None, :]) < window
    if kv_len is not None:
        keep &= (k_pos < kv_len)[None, :]
    s = jnp.where(keep[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)
                      ).astype(q.dtype)


def mamba_scan_ref(u, dt, a, b, c, h0):
    """Sequential selective scan.  u,dt: (B,S,di)  a: (di,N)
    b,c: (B,S,N)  h0: (B,di,N)  ->  y (B,S,di), h_last."""
    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp
        abar = jnp.exp(dt_t[..., None] * a)                 # (B,di,N)
        h = abar * h + dt_t[..., None] * b_t[:, None, :] * u_t[..., None]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (u.swapaxes(0, 1), dt.swapaxes(0, 1), b.swapaxes(0, 1),
          c.swapaxes(0, 1))
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                              tuple(x.astype(jnp.float32) for x in xs))
    return ys.swapaxes(0, 1), h_last


def mlstm_ref(q, k, v, i_gate, f_gate, c0, n0):
    """Sequential mLSTM (gated linear attention form used by the model).

    q,k,v: (B,S,H,hd)  i,f: (B,S,H) in (0,1)  c0: (B,H,hd,hd)  n0: (B,H,hd)
    y_t = q_t · C_t  with  C_t = f_t C_{t-1} + i_t k_t v_tᵀ  (all fp32).
    """
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)

    def step(carry, inp):
        C, n = carry
        q_t, k_t, v_t, i_t, f_t = inp                      # (B,H,hd)…
        C = f_t[..., None, None] * C + \
            i_t[..., None, None] * jnp.einsum("bhd,bhe->bhde", k_t, v_t)
        n = f_t[..., None] * n + i_t[..., None] * k_t
        y = jnp.einsum("bhd,bhde->bhe", q_t * scale, C)
        return (C, n), y

    # reorder (B,S,H,…) -> (S,B,H,…)
    qs, ks, vs = (t.transpose(1, 0, 2, 3).astype(jnp.float32)
                  for t in (q, k, v))
    is_, fs = (t.transpose(1, 0, 2).astype(jnp.float32)
               for t in (i_gate, f_gate))
    (c_last, n_last), ys = jax.lax.scan(
        step, (c0.astype(jnp.float32), n0.astype(jnp.float32)),
        (qs, ks, vs, is_, fs))
    return ys.transpose(1, 0, 2, 3), c_last, n_last
