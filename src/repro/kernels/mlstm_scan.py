"""Chunkwise-parallel mLSTM for TPU (Pallas).

xLSTM's matrix-memory cell is a gated linear attention:
    C_t = f_t C_{t-1} + i_t k_t v_tᵀ ,   y_t = (q_t/√d) · C_t

Chunkwise form (the MXU-friendly one): per chunk of length c,
    intra:  y += ((q Kᵀ) ⊙ D) V      D_ts = exp(F_t - F_s)·i_s  (t ≥ s)
    inter:  y += exp(F_t) · q C_prev
    state:  C ← exp(F_c) C_prev + (K ⊙ r)ᵀ V,  r_s = exp(F_c - F_s)·i_s
with F the in-chunk cumulative log-forget.  All three terms are (c×d)·(d×d)
matmuls — MXU work — while the (d×d) state C stays resident in VMEM scratch
across chunks.  grid = (B·H, chunks), chunks innermost-sequential.

Gates arrive as raw (0,1) i/f values; log/exp stabilization happens in fp32
inside the kernel.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _kernel(q_ref, k_ref, v_ref, i_ref, f_ref, c0_ref, y_ref, cout_ref,
            c_scr, *, chunk, n_chunks, scale):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        c_scr[...] = c0_ref[0].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32) * scale             # (c, d)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    ig = i_ref[0].astype(jnp.float32)                    # (c,)
    fg = f_ref[0].astype(jnp.float32)

    logf = jnp.log(fg + 1e-8)
    cum = jnp.cumsum(logf)                               # (c,) ≤ 0
    # intra-chunk decay matrix D_ts = exp(cum_t - cum_s) · i_s for t >= s
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ratio = cum[:, None] - cum[None, :]
    d_mat = jnp.where(t_idx >= s_idx, jnp.exp(ratio) * ig[None, :], 0.0)

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y = jax.lax.dot_general(scores * d_mat, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk: decay_t · q_t C_prev
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        q, c_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: C = exp(cum_c) C + Σ_s exp(cum_c - cum_s) i_s k_s v_sᵀ
    rem = jnp.exp(cum[-1] - cum) * ig                    # (c,)
    c_scr[...] = c_scr[...] * jnp.exp(cum[-1]) + jax.lax.dot_general(
        k * rem[:, None], v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        cout_ref[0] = c_scr[...]


def mlstm_scan(q, k, v, i_gate, f_gate, c0, *, chunk=DEFAULT_CHUNK,
               interpret=False):
    """q,k,v: (B,S,H,hd)  i,f: (B,S,H) in (0,1)  c0: (B,H,hd,hd) fp32.

    Returns (y (B,S,H,hd), c_last (B,H,hd,hd) fp32).  Matches
    ``ref.mlstm_ref`` (which runs the recurrence sequentially).
    """
    B, S, H, hd = q.shape
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        # f=1 (log 0), i=0 padding is the identity update.
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (q, k, v))
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)))
        f_gate = jnp.pad(f_gate, ((0, 0), (0, pad), (0, 0)),
                         constant_values=1.0)
    Sp = n_chunks * chunk
    # (B,S,H,…) -> (B*H, chunks… ) layout
    qb = q.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)
    kb = k.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)
    vb = v.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)
    ib = i_gate.transpose(0, 2, 1).reshape(B * H, Sp)
    fb = f_gate.transpose(0, 2, 1).reshape(B * H, Sp)
    c0b = c0.reshape(B * H, hd, hd)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks,
                               scale=1.0 / math.sqrt(hd))
    y, c_last = pl.pallas_call(
        kernel,
        grid=(B * H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, chunk), lambda bh, ci: (bh, ci)),
            pl.BlockSpec((1, hd, hd), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, hd, hd), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
            jax.ShapeDtypeStruct((B * H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(qb, kb, vb, ib, fb, c0b)
    y = y[:, :S].reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return y, c_last.reshape(B, H, hd, hd)
