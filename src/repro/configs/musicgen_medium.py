"""MusicGen-medium backbone [arXiv:2306.05284; hf facebook/musicgen-medium].

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 — decoder-only over
EnCodec tokens.  The EnCodec tokenizer + text conditioning are STUBS per the
assignment: input_specs() supplies precomputed frame embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    pattern=("attn",),
    rope_theta=10_000.0,
    input_mode="embeds",
    tie_embeddings=True,
    source="arXiv:2306.05284; hf",
)
