"""Gemma-2 2B [arXiv:2408.00118; hf google/gemma-2-2b].

26L d_model=2304 8H GQA kv=4 head_dim=256 d_ff=9216 vocab=256000.
Alternating local(4096)/global attention, logit softcap 50 (attn) / 30
(final), pre+post RMSNorm, embeddings scaled by √d_model.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    pattern=("attn_local", "attn"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norm=True,
    embed_scale=2304 ** 0.5,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="arXiv:2408.00118; hf",
    notes="8 q heads < TP16: attention TP falls back to head_dim (256) "
          "sharding per DESIGN §6.",
)
