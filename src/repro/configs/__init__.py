"""Assigned-architecture registry: ``get_config(arch_id)``.

Each module defines ``CONFIG`` (exact published numbers, see per-file source
notes) — smoke tests use ``repro.models.config.smoke(CONFIG)``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ModelConfig

ARCH_IDS: List[str] = [
    "minicpm_2b",
    "llama3_2_1b",
    "gemma3_4b",
    "gemma2_2b",
    "kimi_k2_1t_a32b",
    "qwen3_moe_235b_a22b",
    "qwen2_vl_72b",
    "musicgen_medium",
    "xlstm_125m",
    "jamba_v0_1_52b",
]

# CLI ids use dashes / dots; module names use underscores.
ALIASES = {
    "minicpm-2b": "minicpm_2b",
    "llama3.2-1b": "llama3_2_1b",
    "gemma3-4b": "gemma3_4b",
    "gemma2-2b": "gemma2_2b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "musicgen-medium": "musicgen_medium",
    "xlstm-125m": "xlstm_125m",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCH_IDS and mod_name != "cornus_ycsb":
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
