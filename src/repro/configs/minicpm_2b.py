"""MiniCPM-2B [arXiv:2404.06395; hf openbmb/MiniCPM-2B].

40L d_model=2304 36H (MHA, kv=36) d_ff=5760 vocab=122753, llama-like with
μP-style scaling: scale_emb=12, depth-scaled residuals (1.4/√40), logits
divided by d_model/256.  Trained with the WSD schedule (repro.optim.wsd).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122_753,
    pattern=("attn",),
    rope_theta=10_000.0,
    embed_scale=12.0,
    residual_scale=1.4 / (40 ** 0.5),
    logit_divisor=2304.0 / 256.0,
    tie_embeddings=True,
    source="arXiv:2404.06395; hf",
    notes="WSD schedule arch; MHA (36 q heads shard unevenly over TP=16, "
          "GSPMD pads 36->48 lanes).",
)
