"""Gemma-3 4B [hf:google/gemma-3-4b-pt; unverified].

34L d_model=2560 8H GQA kv=4 head_dim=256 d_ff=10240 vocab=262144.
5:1 local:global layer pattern (window 1024), dual rope theta (local 10k,
global 1M), qk-norm, pre+post norms, 128k context target.
34 = 5 full periods of 6 + 4 remainder (unrolled local layers).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262_144,
    pattern=("attn_local",) * 5 + ("attn",),
    window=1024,
    rope_theta=1_000_000.0,
    local_rope_theta=10_000.0,
    qk_norm=True,
    post_norm=True,
    embed_scale=2560 ** 0.5,
    tie_embeddings=True,
    source="hf:google/gemma-3-4b-pt",
    notes="long_500k SKIPPED: the every-6th global full-attention layer "
          "makes 512k prefill O(S^2); see DESIGN §5.",
)
