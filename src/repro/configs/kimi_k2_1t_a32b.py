"""Kimi K2 (1T total, 32B active) [arXiv:2501.*; paper-table, unverified].

61L d_model=7168 64H GQA kv=8 vocab=163840, MoE: 384 experts top-8 with
expert d_ff=2048 + 1 shared expert.  The assignment table specifies GQA
(kv=8); the real model uses MLA — we follow the table (noted in DESIGN §5).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,            # expert FFN width (table value)
    vocab_size=163_840,
    pattern=("attn",),
    moe_period=1,
    n_experts=384,
    experts_per_token=8,
    expert_d_ff=2048,
    n_shared_experts=1,
    rope_theta=50_000.0,
    tie_embeddings=False,
    source="arXiv:2501.kimi2 (paper table)",
    notes="Trillion-param MoE: EP=16 over 'model' axis (24 experts/chip), "
          "FSDP over 'data'. head_dim=112=7168/64.",
)
