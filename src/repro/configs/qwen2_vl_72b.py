"""Qwen2-VL 72B backbone [arXiv:2409.12191; hf Qwen/Qwen2-VL-72B].

80L d_model=8192 64H GQA kv=8 d_ff=29568 vocab=152064, M-RoPE
(temporal/height/width sections 16/24/24 of head_dim/2=64).
The vision frontend is a STUB per the assignment: input_specs() supplies
precomputed patch embeddings for the first patch_frac of the sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152_064,
    pattern=("attn",),
    mrope=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    input_mode="mixed",
    patch_frac=0.25,
    tie_embeddings=False,
    source="arXiv:2409.12191; hf",
)
