"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-235B-A22B; hf-verified family].

94L d_model=4096 64H GQA kv=4 vocab=151936, MoE: 128 experts top-8,
expert d_ff=1536, no shared expert, qk-norm (qwen3), head_dim=128.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=1536,            # expert FFN width
    vocab_size=151_936,
    pattern=("attn",),
    moe_period=1,
    n_experts=128,
    experts_per_token=8,
    expert_d_ff=1536,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="hf:Qwen/Qwen3-235B-A22B",
)
