"""xLSTM-125M [arXiv:2405.04517; unverified].

12L d_model=768 4H vocab=50304, d_ff=0 (cells carry their own projections).
mLSTM (matrix memory, chunkwise-parallel) with interleaved sLSTM
(recurrent scalar memory) at a 5:1 ratio — the paper's xLSTM[a:b] notation.
Attention-free ⇒ runs the long_500k cell (O(1)-state decode).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    tie_embeddings=True,
    source="arXiv:2405.04517",
)
