"""Jamba v0.1 (52B) [arXiv:2403.19887; hf ai21labs/Jamba-v0.1].

32L d_model=4096 32H GQA kv=8 d_ff=14336 vocab=65536.
Period-8 blocks with attention at index 4 (1:7 attn:mamba interleave);
MoE (16 experts, top-2, d_ff=14336) every other layer (odd offsets).
Hybrid ⇒ runs long_500k (only 4 attention layers hold a 512k KV cache,
sequence-parallel over the 'data' axis).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65_536,
    pattern=("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba",
             "mamba"),
    moe_period=2,
    moe_offset=1,
    n_experts=16,
    experts_per_token=2,
    expert_d_ff=14336,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    rope_theta=10_000.0,
    tie_embeddings=False,
    source="arXiv:2403.19887; hf",
)
