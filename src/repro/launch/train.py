"""End-to-end training driver with Cornus-committed checkpointing.

Runs a real (reduced-config or custom) model on the local device(s):
  data pipeline → jitted train_step (fwd+bwd+AdamW, WSD schedule) →
  every ``ckpt_every`` steps, a Cornus checkpoint epoch: the process acts as
  all ``n_hosts`` fleet members (size-balanced shard partitioning), votes
  each host's shard set into the FileStore, and the epoch commits iff the
  collective votes are durable — Algorithm 1, deployed.

Restart semantics: ``resume=True`` restores the newest COMMITTED epoch
(in-flight epochs are resolved by the termination protocol, never waited
on) and the stateless data pipeline replays from the restored step, so a
killed-and-restarted run produces the exact same loss curve as an unkilled
one — asserted in tests/test_train_loop.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt import (CornusCheckpointer, latest_committed, pack_tree,
                    partition_leaves, restore_params)
from ..ckpt.commit import AsyncCheckpointer
from ..core.state import Decision
from ..core.storage import FileStore
from ..data import DataConfig, Prefetcher, make_pipeline
from ..models import config as mc
from ..models import lm
from ..optim import AdamWConfig, adamw_init
from . import steps as S


@dataclass
class RunConfig:
    arch: str = "llama3.2-1b"
    use_smoke: bool = True              # reduced config (CPU-trainable)
    steps: int = 50
    batch: int = 8
    seq_len: int = 128
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    n_hosts: int = 4                    # fleet size this process acts as
    resume: bool = False
    async_ckpt: bool = False
    data_source: str = "synthetic"
    lr: float = 1e-3
    warmup: int = 20
    seed: int = 0
    remat: str = "none"
    log_every: int = 10
    # Fault injection: kill the run (raise) right AFTER this step's vote of
    # host 0 only — leaves the epoch in-flight for restart tests.
    die_mid_checkpoint_at: Optional[int] = None


@dataclass
class RunResult:
    losses: List[float] = field(default_factory=list)
    steps_done: int = 0
    restored_from: Optional[int] = None
    ckpt_outcomes: List = field(default_factory=list)
    wall_s: float = 0.0


class MidCheckpointCrash(RuntimeError):
    pass


def _hosts(n: int) -> List[str]:
    return [f"host{i}" for i in range(n)]


def train(run: RunConfig) -> RunResult:
    t_start = time.time()
    cfg = mc.smoke(_arch_cfg(run.arch)) if run.use_smoke \
        else _arch_cfg(run.arch)
    if run.data_source.startswith("bytes:"):
        assert cfg.vocab_size >= 256
    dcfg = DataConfig(batch=run.batch, seq_len=run.seq_len,
                      vocab_size=cfg.vocab_size, source=run.data_source,
                      seed=run.seed)
    pipeline = make_pipeline(dcfg)

    opt_cfg = AdamWConfig(lr=run.lr, weight_decay=0.01)
    settings = S.TrainSettings(remat=run.remat, opt=opt_cfg,
                               warmup=run.warmup, stable=10**6, decay=1)
    params = lm.init_model(cfg, jax.random.key(run.seed))
    opt_state = adamw_init(params, opt_cfg)

    store = FileStore(run.ckpt_dir)
    hosts = _hosts(run.n_hosts)
    result = RunResult()
    start_step = 0

    if run.resume:
        epoch = latest_committed(store, hosts)
        if epoch is not None:
            full = {"params": params, "opt": {"m": opt_state["m"],
                                              "v": opt_state["v"]}}
            full = restore_params(store, hosts, epoch, full)
            params, opt_state["m"], opt_state["v"] = \
                full["params"], full["opt"]["m"], full["opt"]["v"]
            opt_state["count"] = jnp.asarray(epoch, jnp.int32)
            start_step = epoch
            result.restored_from = epoch

    train_step = jax.jit(S.make_train_step(cfg, settings),
                         donate_argnums=(0, 1))
    checkpointers = {h: CornusCheckpointer(store, h, hosts,
                                           straggler_timeout_s=10.0)
                     for h in hosts}
    async_ck = {h: AsyncCheckpointer(c) for h, c in checkpointers.items()} \
        if run.async_ckpt else None

    prefetch = Prefetcher(pipeline, start_step)
    try:
        for step in range(start_step, run.steps):
            got_step, batch = prefetch.get()
            assert got_step == step
            jbatch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, loss = train_step(
                params, opt_state, jbatch, jnp.asarray(step, jnp.int32))
            result.losses.append(float(loss))
            result.steps_done = step + 1
            if run.log_every and (step + 1) % run.log_every == 0:
                print(f"[train] step {step+1:5d} loss {float(loss):.4f}",
                      flush=True)

            if (step + 1) % run.ckpt_every == 0:
                outcome = _checkpoint(run, cfg, params, opt_state, step + 1,
                                      hosts, checkpointers, async_ck)
                if outcome is not None:
                    result.ckpt_outcomes.append(outcome)
    finally:
        prefetch.stop()
    if async_ck:
        for h in hosts:
            result.ckpt_outcomes.extend(async_ck[h].join())
    result.wall_s = time.time() - t_start
    return result


def _checkpoint(run, cfg, params, opt_state, epoch, hosts, checkpointers,
                async_ck):
    full = {"params": params,
            "opt": {"m": opt_state["m"], "v": opt_state["v"]}}
    parts = partition_leaves(full, len(hosts))
    payloads = {h: pack_tree(full, keys) for h, keys in zip(hosts, parts)}

    if run.die_mid_checkpoint_at == epoch:
        # Crash after host0's vote only: epoch left UNDETERMINED on storage.
        checkpointers[hosts[0]].vote(epoch, payloads[hosts[0]])
        raise MidCheckpointCrash(f"injected crash in epoch {epoch}")

    if async_ck is not None:
        for h in hosts:
            async_ck[h].save(epoch, payloads[h])
        return None
    # This process acts as the whole fleet: all hosts vote first (in a real
    # deployment these are concurrent), then the collective state resolves.
    import time as _time
    t0 = _time.monotonic()
    for h in hosts:
        checkpointers[h].vote(epoch, payloads[h])
    t1 = _time.monotonic()
    decision, forced = checkpointers[hosts[0]].resolve(epoch)
    from ..ckpt import CheckpointOutcome
    return CheckpointOutcome(epoch, decision,
                             vote_ms=(t1 - t0) * 1e3,
                             resolve_ms=(_time.monotonic() - t1) * 1e3,
                             forced_aborts=forced)


def _arch_cfg(arch: str):
    from ..configs import get_config
    return get_config(arch)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--n-hosts", type=int, default=4)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args(argv)
    run = RunConfig(arch=args.arch, steps=args.steps, batch=args.batch,
                    seq_len=args.seq_len, ckpt_every=args.ckpt_every,
                    ckpt_dir=args.ckpt_dir, n_hosts=args.n_hosts,
                    resume=args.resume, async_ckpt=args.async_ckpt,
                    data_source=args.data, lr=args.lr)
    res = train(run)
    print(f"[train] done: {res.steps_done} steps, "
          f"final loss {res.losses[-1]:.4f}, "
          f"{len(res.ckpt_outcomes)} checkpoints, {res.wall_s:.1f}s")


if __name__ == "__main__":
    main()
