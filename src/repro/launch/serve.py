"""Batched serving driver: prefill + jitted decode loop with sampling.

Wave-based batched serving: a request queue is drained in fixed-size batch
waves; each wave prefills once and decodes step-by-step (greedy / temperature
/ top-k), stopping on EOS or max_new_tokens.  Per-wave cache buffers are
donated across steps so decode runs in-place.

DEPRECATED as a serving frontend: fixed waves admit nothing while a wave is
in flight and give no backpressure, deadlines, or transactional session
state.  ``repro.serve`` (``ServeEngine`` + ``ContinuousBatcher``) replaces
the ad-hoc batching here; ``generate``/``_sample`` remain the reference
prefill+decode loop and stay supported.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models import config as mc
from ..models import lm


@dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 => greedy
    top_k: int = 0                  # 0 => full softmax
    eos_id: Optional[int] = None
    max_len: int = 256
    seed: int = 0


def _sample(logits, scfg: ServeConfig, rng):
    logits = logits[:, -1, :]
    if scfg.temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), rng
    logits = logits / scfg.temperature
    if scfg.top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -scfg.top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    rng, sub = jax.random.split(rng)
    return jax.random.categorical(sub, logits).astype(jnp.int32), rng


def generate(cfg: mc.ModelConfig, params, prompts: jax.Array,
             scfg: ServeConfig) -> np.ndarray:
    """prompts: (B, S_prompt) int32 — one wave. Returns (B, new_tokens)."""
    B, S = prompts.shape
    assert S + scfg.max_new_tokens <= scfg.max_len

    prefill = jax.jit(lambda p, b: lm.prefill(cfg, p, b, scfg.max_len))
    decode = jax.jit(lambda p, b, c, pos: lm.decode_step(cfg, p, b, c, pos),
                     donate_argnums=(2,))

    logits, cache, _ = prefill(params, {"tokens": prompts})
    rng = jax.random.key(scfg.seed)
    tok, rng = _sample(logits[:, :, :cfg.vocab_size], scfg, rng)
    out = [tok]
    done = jnp.zeros((B,), bool)
    for t in range(1, scfg.max_new_tokens):
        if scfg.eos_id is not None:
            done = done | (tok == scfg.eos_id)
            if bool(done.all()):
                break
        logits, cache = decode(params, {"tokens": tok[:, None]}, cache,
                               jnp.asarray(S + t - 1, jnp.int32))
        tok, rng = _sample(logits[:, :, :cfg.vocab_size], scfg, rng)
        out.append(tok)
    return np.stack([np.asarray(t) for t in out], axis=1)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray


class BatchServer:
    """Deprecated shim: drains a request queue in fixed-size waves.

    Kept working for old callers, but new code should drive
    ``repro.serve.ServeEngine`` — continuous batching with a bounded
    queue, deadlines, and per-step transactional commits — and use
    ``generate`` directly for the model compute.
    """

    def __init__(self, cfg: mc.ModelConfig, params, batch_size: int,
                 scfg: ServeConfig):
        warnings.warn(
            "BatchServer's fixed-wave batching is deprecated; use "
            "repro.serve.ServeEngine (continuous batching + transactional "
            "sessions) — see README 'Transactional serving'",
            DeprecationWarning, stacklevel=2)
        self.cfg, self.params = cfg, params
        self.batch = batch_size
        self.scfg = scfg
        self.stats: Dict[str, float] = {"waves": 0, "requests": 0,
                                        "tokens": 0, "wall_s": 0.0}

    def serve(self, requests: Sequence[Request]) -> Dict[int, np.ndarray]:
        t0 = time.time()
        results: Dict[int, np.ndarray] = {}
        for i in range(0, len(requests), self.batch):
            wave = list(requests[i:i + self.batch])
            # pad the wave to full batch by repeating the last request
            while len(wave) < self.batch:
                wave.append(wave[-1])
            maxlen = max(r.prompt.shape[0] for r in wave)
            prompts = np.stack([
                np.pad(r.prompt, (maxlen - r.prompt.shape[0], 0))
                for r in wave])
            toks = generate(self.cfg, self.params,
                            jnp.asarray(prompts, jnp.int32), self.scfg)
            for r, row in zip(requests[i:i + self.batch], toks):
                results[r.rid] = row
                self.stats["requests"] += 1
                self.stats["tokens"] += row.shape[0]
            self.stats["waves"] += 1
        self.stats["wall_s"] += time.time() - t0
        return results


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args(argv)

    from ..configs import get_config
    cfg = mc.smoke(get_config(args.arch))
    params = lm.init_model(cfg, jax.random.key(0))
    scfg = ServeConfig(max_new_tokens=args.max_new,
                       temperature=args.temperature, max_len=128)
    server = BatchServer(cfg, params, args.batch, scfg)
    rng = np.random.RandomState(0)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size, (16,)).astype(np.int32))
            for i in range(args.requests)]
    out = server.serve(reqs)
    tput = server.stats["tokens"] / max(server.stats["wall_s"], 1e-9)
    print(f"[serve] {len(out)} requests, {server.stats['tokens']:.0f} tokens,"
          f" {tput:.1f} tok/s over {server.stats['waves']:.0f} waves")


if __name__ == "__main__":
    main()
