"""Step functions lowered by the dry-run and run by the drivers.

  train_step   — loss + grad + AdamW update (the train_4k cells)
  prefill_step — prompt forward, returns last-position logits + KV cache
  decode_step  — one token against a max_len cache (decode_32k / long_500k)

Plus per-shape ``input_specs`` (ShapeDtypeStructs with NamedShardings — no
allocation) and ``period_body_fn`` used by the dry-run to cost one scan
period (XLA's cost model counts while-loop bodies once; the dry-run scales
the body cost by the trip count).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import config as mc
from ..models import lm
from ..models.layers import PSpec, param_structs
from ..optim import (AdamWConfig, adamw_init, adamw_update, CompressionConfig,
                     compress_gradients, decompress_gradients,
                     error_feedback_update, wsd_schedule)
from .sharding import Rules, constrain, use_rules


@dataclass(frozen=True)
class TrainSettings:
    remat: str = "dots"
    opt: AdamWConfig = AdamWConfig()
    # int8 gradient compression around the DP all-reduce (beyond-paper).
    compress: Optional[CompressionConfig] = None
    schedule: str = "wsd"
    warmup: int = 100
    stable: int = 10_000
    decay: int = 1_000


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------
def make_train_step(cfg: mc.ModelConfig, settings: TrainSettings,
                    rules: Optional[Rules] = None):
    def lr_scale(step):
        return wsd_schedule(step, warmup=settings.warmup,
                            stable=settings.stable, decay=settings.decay)

    def train_step(params, opt_state, batch, step):
        with use_rules(rules):
            def loss_fn(p):
                loss, _ = lm.forward(cfg, p, batch, remat=settings.remat)
                return loss

            loss, grads = jax.value_and_grad(loss_fn)(params)
            if settings.compress is not None:
                grads = _compressed_allreduce(grads, settings.compress, rules)
            new_params, new_opt = adamw_update(
                grads, opt_state, params, settings.opt, lr_scale(step))
        return new_params, new_opt, loss

    return train_step


def _compressed_allreduce(grads, ccfg: CompressionConfig, rules):
    """Quantize → (implicit DP psum) → dequantize.

    Under pure pjit the DP reduction is fused into the backward pass by
    SPMD, so there is no separate all-reduce to intercept; we re-shard the
    gradient leaves through an int8 bottleneck with a sharding constraint,
    which materializes the int8 collective in HLO.  Error feedback is
    carried in the optimizer state by the full driver (repro.launch.train);
    here the stateless form is used for lowering.
    """
    q, s, pre = compress_gradients(grads, ccfg)
    q = jax.tree_util.tree_map(
        lambda t: constrain(t, ("fsdp",) + (None,) * (t.ndim - 1)), q)
    return decompress_gradients(q, s)


def make_prefill_step(cfg: mc.ModelConfig, max_len: int,
                      rules: Optional[Rules] = None):
    def prefill_step(params, batch):
        with use_rules(rules):
            logits, cache, pos = lm.prefill(cfg, params, batch, max_len)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: mc.ModelConfig, rules: Optional[Rules] = None):
    def decode_step(params, batch, cache, pos):
        with use_rules(rules):
            logits, new_cache = lm.decode_step(cfg, params, batch, cache, pos)
        return logits, new_cache

    return decode_step


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; zero allocation)
# ---------------------------------------------------------------------------
def _sds(shape, dtype, rules: Optional[Rules], axes):
    sh = rules.sharding(axes, shape) if rules else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def batch_specs(cfg: mc.ModelConfig, B: int, S: int, rules, *,
                with_labels: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.input_mode == "tokens":
        out["tokens"] = _sds((B, S), jnp.int32, rules, ("batch", None))
    elif cfg.input_mode == "embeds":
        out["frame_embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16, rules,
                                   ("batch", None, None))
    else:  # mixed VLM
        n_patch = max(1, int(S * cfg.patch_frac)) if S > 1 else 0
        n_text = S - n_patch
        out["patch_embeds"] = _sds((B, n_patch, cfg.d_model), jnp.bfloat16,
                                   rules, ("batch", None, None))
        out["tokens"] = _sds((B, n_text), jnp.int32, rules, ("batch", None))
    if with_labels:
        out["labels"] = _sds((B, S), jnp.int32, rules, ("batch", None))
    return out


def model_structs(cfg: mc.ModelConfig, rules, dtype=jnp.bfloat16):
    return param_structs(lm.model_specs(cfg), rules, dtype)


def opt_structs(cfg: mc.ModelConfig, rules, opt_cfg: AdamWConfig):
    specs = lm.model_specs(cfg)

    def mk(s: PSpec):
        sh = rules.sharding(s.axes, s.shape) if rules else None
        return jax.ShapeDtypeStruct(s.shape, opt_cfg.state_dtype, sharding=sh)

    moments = jax.tree_util.tree_map(mk, specs,
                                     is_leaf=lambda x: isinstance(x, PSpec))
    return {"m": moments, "v": jax.tree_util.tree_map(lambda x: x, moments),
            "count": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_structs(cfg: mc.ModelConfig, B: int, max_len: int, rules,
                  dtype=jnp.bfloat16):
    specs = lm.cache_specs(cfg, B, max_len)

    def mk(s: PSpec):
        sh = rules.sharding(s.axes, s.shape) if rules else None
        return jax.ShapeDtypeStruct(s.shape, s.dtype or dtype, sharding=sh)

    return jax.tree_util.tree_map(mk, specs,
                                  is_leaf=lambda x: isinstance(x, PSpec))


def input_specs(cfg: mc.ModelConfig, shape: mc.ShapeConfig,
                rules: Optional[Rules], settings: TrainSettings):
    """Everything the step for this shape-kind takes, as structs."""
    B, S = shape.global_batch, shape.seq_len
    params = model_structs(cfg, rules)
    if shape.kind == "train":
        return dict(
            params=params,
            opt_state=opt_structs(cfg, rules, settings.opt),
            batch=batch_specs(cfg, B, S, rules, with_labels=True),
            step=jax.ShapeDtypeStruct((), jnp.int32),
        )
    if shape.kind == "prefill":
        return dict(params=params,
                    batch=batch_specs(cfg, B, S, rules, with_labels=False))
    # decode: one new token against a seq_len cache
    one = batch_specs(cfg, B, 1, rules, with_labels=False)
    return dict(params=params, batch=one,
                cache=cache_structs(cfg, B, S, rules),
                pos=jax.ShapeDtypeStruct((), jnp.int32))


# ---------------------------------------------------------------------------
# Period body (dry-run cost scaling)
# ---------------------------------------------------------------------------
def make_period_body(cfg: mc.ModelConfig, shape: mc.ShapeConfig,
                     rules: Optional[Rules], settings: TrainSettings):
    """One scan-period of the layer stack, as its own jit-able function.

    Used by the dry-run: XLA cost analysis counts a while-loop body once, so
    the full-module cost is corrected by (n_periods - 1) × body cost.
    Returns (fn, example_args) or None when there is no scanned stack.
    """
    if cfg.n_periods <= 1:
        return None
    B = shape.global_batch
    S = 1 if shape.kind == "decode" else shape.seq_len
    layer_tree = {f"p{p}": lm.layer_specs(cfg, p)
                  for p in range(len(cfg.pattern))}
    lp = param_structs(layer_tree, rules, jnp.bfloat16)
    x = _sds((B, S, cfg.d_model), jnp.bfloat16, rules, ("batch", None, None))
    if cfg.mrope:
        pos = _sds((3, B, S), jnp.int32, rules, (None, "batch", None))
    else:
        pos = _sds((B, S), jnp.int32, rules, ("batch", None))

    cache = None
    if shape.kind == "decode":
        cache_tree = {
            f"p{p}": lm.MIXERS[cfg.pattern[p]][2](cfg, B, shape.seq_len)
            for p in range(len(cfg.pattern))}
        cache = cache_structs_from(cache_tree, rules)

    from ..models.blocks import Ctx, layer_apply

    def body_train(layer_params, x, positions):
        with use_rules(rules):
            def fwd(lp_, x_):
                h = x_
                aux = 0.0
                for p, kind in enumerate(cfg.pattern):
                    ctx = lm._layer_ctx(cfg, kind, "train", positions, None,
                                        0, 0)
                    h, _, a = layer_apply(cfg, kind, cfg.is_moe_layer(p),
                                          lp_[f"p{p}"], h, ctx)
                    aux = aux + a
                return jnp.sum(h.astype(jnp.float32)) + aux

            fn = fwd
            if settings.remat != "none":
                fn = lm._remat_wrap(fwd, settings.remat)
            val, grads = jax.value_and_grad(fn, argnums=(0, 1))(
                layer_params, x)
        return val, grads

    def body_infer(layer_params, x, positions, cache_in, pos_scalar):
        with use_rules(rules):
            h = x
            caches = {}
            for p, kind in enumerate(cfg.pattern):
                mode = "decode" if shape.kind == "decode" else "prefill"
                c_in = cache_in[f"p{p}"] if cache_in is not None else None
                ctx = lm._layer_ctx(cfg, kind, mode, positions, c_in,
                                    pos_scalar, shape.seq_len)
                h, c_out, _ = layer_apply(cfg, kind, cfg.is_moe_layer(p),
                                          layer_params[f"p{p}"], h, ctx)
                if c_out is not None:
                    caches[f"p{p}"] = c_out
        return h, caches

    if shape.kind == "train":
        return body_train, (lp, x, pos)
    return (lambda lp_, x_, pos_, c_: body_infer(
        lp_, x_, pos_, c_, jnp.int32(0))), (lp, x, pos, cache)


def cache_structs_from(spec_tree, rules, dtype=jnp.bfloat16):
    def mk(s: PSpec):
        sh = rules.sharding(s.axes, s.shape) if rules else None
        return jax.ShapeDtypeStruct(s.shape, s.dtype or dtype, sharding=sh)
    return jax.tree_util.tree_map(mk, spec_tree,
                                  is_leaf=lambda x: isinstance(x, PSpec))
