"""Logical-axis sharding rules → NamedSharding.

Model code names tensor dims with *logical* axes ("batch", "model", "fsdp",
"expert", …); this module maps them onto the physical mesh with divisibility
checks (GSPMD tolerates uneven shards by padding, so we only refuse to shard
dims smaller than the axis) and provides ``constrain()`` — a no-op unless a
rule set is active, so the same model code runs on 1 CPU device in tests and
on the 512-chip production mesh in the dry-run.

Default rule set (see DESIGN.md §6):
  batch   -> (pod, data)     data parallel across pods
  fsdp    -> data            ZeRO-3 weight sharding
  model   -> model           tensor parallel (heads / d_ff / vocab)
  expert  -> model           expert parallel
  kv_seq  -> data            sequence-parallel KV cache (long-context decode)
"""
from __future__ import annotations

import contextlib
import math
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


_STATE = threading.local()


def _active() -> Optional["Rules"]:
    return getattr(_STATE, "rules", None)


@dataclass
class Rules:
    mesh: Mesh
    logical: Dict[str, Tuple[str, ...]] = field(default_factory=dict)
    # Dims we refused to shard (dim < axis size) land here for the report.
    fallbacks: list = field(default_factory=list)

    def __post_init__(self):
        axes = self.mesh.axis_names
        base = {
            "batch": tuple(a for a in ("pod", "data") if a in axes),
            "fsdp": ("data",) if "data" in axes else (),
            "model": ("model",) if "model" in axes else (),
            "expert": ("model",) if "model" in axes else (),
            "kv_seq": ("data",) if "data" in axes else (),
            # Decode KV caches: batch takes "data", so the cache's seq dim
            # takes "model" (flash-decode style); at batch=1 (long-context)
            # seq takes BOTH axes.
            "cache_seq": ("model",) if "model" in axes else (),
            "cache_seq_full": tuple(a for a in ("data", "model")
                                    if a in axes),
        }
        base.update(self.logical)
        self.logical = base
        self.sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    def axis_size(self, logical_name: str) -> int:
        return math.prod(self.sizes[a] for a in self.logical.get(logical_name, ()))

    def spec(self, axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """Build a PartitionSpec; drop shardings that don't fit the dim."""
        used: set = set()
        out = []
        for i, name in enumerate(axes):
            if name is None:
                out.append(None)
                continue
            mesh_axes = tuple(a for a in self.logical.get(name, ())
                              if a not in used)
            if not mesh_axes:
                out.append(None)
                continue
            total = math.prod(self.sizes[a] for a in mesh_axes)
            if shape is not None and shape[i] < total:
                self.fallbacks.append((tuple(axes), i, name, shape[i], total))
                out.append(None)
                continue
            used.update(mesh_axes)
            out.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def sharding(self, axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    prev = _active()
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint under the active rules; identity otherwise."""
    rules = _active()
    if rules is None:
        return x
    spec = rules.spec(axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))


def current_rules() -> Optional[Rules]:
    return _active()


# Sharding profiles (perf iteration levers, EXPERIMENTS §Perf):
#   default — TP on "model", DP+ZeRO-3 on "data" (the baseline table)
#   fsdp    — no tensor parallelism: batch over every axis, weights ZeRO-3
#             over (data, model).  Right answer for small dense models where
#             TP activation all-reduces dwarf FSDP weight gathers.
#   sp      — Megatron-style sequence parallelism: residual stream sharded
#             on seq over the TP axis; converts activation all-reduce into
#             reduce-scatter + all-gather (half the wire bytes).
PROFILES = {
    "default": {},
    "fsdp": {
        "batch": ("pod", "data", "model"),
        "fsdp": ("data", "model"),
        "model": (),
        "expert": (),
        "cache_seq": (),
    },
    "sp": {
        "seq": ("model",),
    },
}


def make_rules(mesh, profile: str = "default") -> Rules:
    overrides = dict(PROFILES[profile])
    if "pod" not in mesh.axis_names and "batch" in overrides:
        overrides["batch"] = tuple(a for a in overrides["batch"]
                                   if a != "pod")
    return Rules(mesh, logical=overrides)
