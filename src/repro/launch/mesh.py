"""Production mesh construction.

Target: TPU v5e pods — 16×16 = 256 chips per pod, 2 pods for the multi-pod
dry-run.  Defined as functions so importing this module never touches jax
device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def auto_axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` where supported; jax<0.5 has no AxisType
    (Auto is already the default there), so pass nothing."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_types_kwargs(len(axes)))


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"),
                         **auto_axis_types_kwargs(2))
