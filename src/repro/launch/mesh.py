"""Production mesh construction.

Target: TPU v5e pods — 16×16 = 256 chips per pod, 2 pods for the multi-pod
dry-run.  Defined as functions so importing this module never touches jax
device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
