import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input-shape × mesh) cell against the
production meshes — (16,16)=256 chips single-pod, (2,16,16)=512 chips
multi-pod — and extracts the roofline inputs:

  * cost_analysis  FLOPs / bytes   (per-device; while-loop bodies counted
    once by XLA, so the scanned layer stack's body is compiled separately
    and its cost scaled by (n_periods - 1))
  * collective "wire bytes" per device, parsed from optimized HLO with
    replica-group-size-aware factors (ring model):
        all-gather (g-1)/g · out     all-reduce 2(g-1)/g · out
        reduce-scatter (g-1) · out   all-to-all (g-1)/g · out
        collective-permute 1 · out
  * memory_analysis (argument/output/temp bytes per device)

Writes one JSON per cell under --out (default artifacts/dryrun).

NOTE: the XLA_FLAGS line above MUST run before any jax import — this module
is the only place the 512-device world is created.
"""
import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import ALIASES, ARCH_IDS, get_config
from ..models.config import ALL_SHAPES, ModelConfig, ShapeConfig
from .mesh import make_production_mesh
from .sharding import Rules, make_rules
from . import steps as S


def cost_dict(compiled) -> Dict:
    """``Compiled.cost_analysis()`` normalized across jax versions: older
    releases return a one-element list of dicts, newer ones the dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-device wire bytes + op counts by collective type."""
    out = {c: {"bytes": 0.0, "count": 0, "result_bytes": 0.0}
           for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, op, _ = m.groups()
        res = _shape_bytes(type_str)
        g = 1
        mb = _GROUPS_BRACE_RE.search(line)
        if mb:
            g = len(mb.group(1).split(","))
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                g = int(mi.group(2))
        g = max(g, 1)
        if op == "all-gather":
            wire = res * (g - 1) / g
        elif op == "all-reduce":
            wire = res * 2 * (g - 1) / g
        elif op == "reduce-scatter":
            wire = res * (g - 1)
        elif op == "all-to-all":
            wire = res * (g - 1) / g
        else:  # collective-permute
            wire = res
        out[op]["bytes"] += wire
        out[op]["count"] += 1
        out[op]["result_bytes"] += res
    return out


def _merge_scaled(base: Dict, body: Dict, scale: int) -> Dict:
    out = {}
    for k in base:
        out[k] = {f: base[k][f] + scale * body[k][f] for f in base[k]}
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Hand-derived 'useful' FLOPs: 6·N_active·D train, 2·N_active·D infer."""
    n = cfg.active_param_count() - cfg.padded_vocab * cfg.d_model  # non-embed
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n * tokens
        # logits matmul fwd+bwd
        base += 6.0 * shape.global_batch * shape.seq_len * \
            cfg.d_model * cfg.padded_vocab
        return base
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens + 2.0 * tokens * cfg.d_model * cfg.padded_vocab
    # decode: one token/seq against cache (attention adds 2·S·d per kv layer)
    tokens = shape.global_batch
    flops = 2.0 * n * tokens + 2.0 * tokens * cfg.d_model * cfg.padded_vocab
    n_attn = sum(1 for k in cfg.full_pattern if k.startswith("attn"))
    flops += (4.0 * cfg.n_kv_heads * cfg.hd * shape.seq_len
              * cfg.n_heads // max(cfg.n_kv_heads, 1)) * n_attn * tokens
    return flops


def run_cell(arch: str, shape: ShapeConfig, multi_pod: bool,
             settings: S.TrainSettings, profile: str = "default") -> Dict:
    cfg = get_config(arch)
    mesh_name = "multi" if multi_pod else "single"
    rec: Dict = {"arch": arch, "shape": shape.name, "mesh": mesh_name,
                 "profile": profile}
    if shape.name == "long_500k" and not cfg.subquadratic:
        rec["skipped"] = ("full-attention arch: 512k context needs "
                          "sub-quadratic attention (DESIGN §5)")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    rules = make_rules(mesh, profile)
    specs = S.input_specs(cfg, shape, rules, settings)

    if shape.kind == "train":
        fn = S.make_train_step(cfg, settings, rules)
        args = (specs["params"], specs["opt_state"], specs["batch"],
                specs["step"])
        donate = (0, 1)
    elif shape.kind == "prefill":
        fn = S.make_prefill_step(cfg, shape.seq_len, rules)
        args = (specs["params"], specs["batch"])
        donate = ()
    else:
        fn = S.make_decode_step(cfg, rules)
        args = (specs["params"], specs["batch"], specs["cache"], specs["pos"])
        donate = (2,)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

        ca = cost_dict(compiled)
        ma = compiled.memory_analysis()
        coll = parse_collectives(compiled.as_text())

        # Scale the scanned-stack body by its trip count.
        body_ca: Dict = {}
        body_coll: Dict = {c: {"bytes": 0.0, "count": 0, "result_bytes": 0.0}
                           for c in COLLECTIVES}
        trips = 0
        body = S.make_period_body(cfg, shape, rules, settings)
        if body is not None:
            body_fn, body_args = body
            bc = jax.jit(body_fn).lower(*body_args).compile()
            body_ca = cost_dict(bc)
            body_coll = parse_collectives(bc.as_text())
            trips = cfg.n_periods - 1

    flops = float(ca.get("flops", 0.0)) + trips * float(
        body_ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0)) + trips * float(
        body_ca.get("bytes accessed", 0.0))
    coll_total = _merge_scaled(coll, body_coll, trips)

    rec.update(
        n_devices=n_dev,
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        flops_per_device=flops,
        hbm_bytes_per_device=byts,
        collectives=coll_total,
        collective_bytes_per_device=sum(v["bytes"]
                                        for v in coll_total.values()),
        memory=dict(
            argument_bytes=ma.argument_size_in_bytes,
            output_bytes=ma.output_size_in_bytes,
            temp_bytes=ma.temp_size_in_bytes,
            alias_bytes=ma.alias_size_in_bytes,
        ),
        params_total=cfg.param_count(),
        params_active=cfg.active_param_count(),
        model_flops_total=model_flops(cfg, shape),
        trip_scaled_periods=trips,
        sharding_fallbacks=len(rules.fallbacks),
    )
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id (dash form) or 'all'")
    ap.add_argument("--shape", default="all",
                    help="train_4k|prefill_32k|decode_32k|long_500k|all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--remat", default="dots",
                    choices=["none", "dots", "full"])
    ap.add_argument("--opt-dtype", default="float32",
                    choices=["float32", "bfloat16"])
    ap.add_argument("--profile", default="default",
                    choices=["default", "fsdp", "sp"])
    args = ap.parse_args(argv)

    from ..optim import AdamWConfig
    settings = S.TrainSettings(
        remat=args.remat,
        opt=AdamWConfig(state_dtype=jnp.bfloat16 if args.opt_dtype ==
                        "bfloat16" else jnp.float32))

    archs = list(ALIASES) if args.arch == "all" else [args.arch]
    shapes = [s for s in ALL_SHAPES
              if args.shape in ("all", s.name)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "multi" if multi else "single"
                tag = f"{arch}__{shape.name}__{mesh_name}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rec = run_cell(arch, shape, multi, settings,
                                   args.profile)
                except Exception as e:  # a dry-run failure is a real bug
                    rec = {"arch": arch, "shape": shape.name,
                           "mesh": mesh_name, "error": repr(e)[:2000]}
                    failures.append(tag)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = ("SKIP" if "skipped" in rec else
                          "FAIL" if "error" in rec else
                          f"ok {rec['compile_s']:6.1f}s "
                          f"flops/dev={rec['flops_per_device']:.3e} "
                          f"coll/dev={rec['collective_bytes_per_device']:.3e}")
                print(f"[dryrun] {tag:55s} {status}", flush=True)
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES: {failures}")
        return 1
    print("[dryrun] all cells passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
