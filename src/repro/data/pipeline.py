"""Deterministic, resumable data pipeline.

Two sources:
  * ``SyntheticTokens`` — tokens are a pure function of (step, host), so any
    restart at step S reproduces the exact stream with zero state (this is
    the property that makes checkpoint-restart exact).
  * ``ByteCorpus``     — byte-level LM windows over a real file (examples
    train on the framework's own source code); windows are drawn by a
    counter-based RNG keyed on step, so it is stateless/resumable too.

``Prefetcher`` overlaps host-side batch assembly with device compute via a
background thread + bounded queue (the CPU analogue of the input pipeline
overlap you'd run on TPU hosts).
"""
from __future__ import annotations

import os
import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    batch: int = 8
    seq_len: int = 128
    vocab_size: int = 512
    source: str = "synthetic"      # synthetic | bytes:<path>
    seed: int = 0
    host: int = 0
    n_hosts: int = 1


class SyntheticTokens:
    """tokens[b, t] = hash(step, host, b, t) — fully stateless.

    Tokens are drawn from a fixed zipf-like unigram distribution
    (p ∝ 1/(rank+10)), NOT uniformly: a uniform stream sits exactly at the
    ln(vocab) cross-entropy floor, so "loss decreases" becomes a
    seed-dependent coin flip.  The skewed marginal keeps a robustly
    learnable signal (the model recovers the unigram bias within a few
    steps) while staying a pure function of (seed, host, step).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        ranks = np.arange(cfg.vocab_size, dtype=np.float64)
        p = 1.0 / (ranks + 10.0)
        self._p = p / p.sum()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed, counter=[0, 0, cfg.host, step]))
        toks = rng.choice(cfg.vocab_size, size=(cfg.batch, cfg.seq_len),
                          p=self._p).astype(np.int32)
        return {"tokens": toks, "labels": toks.copy()}


class ByteCorpus:
    """Byte-level LM over a file; vocab = 256 (must fit cfg.vocab_size)."""

    def __init__(self, cfg: DataConfig, path: str):
        assert cfg.vocab_size >= 256, "byte LM needs vocab >= 256"
        with open(path, "rb") as f:
            self.data = np.frombuffer(f.read(), dtype=np.uint8)
        assert len(self.data) > cfg.seq_len + 1, "corpus too small"
        self.cfg = cfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=cfg.seed ^ 0xC0FFEE, counter=[0, 0, cfg.host, step]))
        starts = rng.integers(0, len(self.data) - cfg.seq_len - 1, cfg.batch)
        toks = np.stack([self.data[s:s + cfg.seq_len] for s in starts])
        return {"tokens": toks.astype(np.int32),
                "labels": toks.astype(np.int32)}


def make_pipeline(cfg: DataConfig):
    if cfg.source == "synthetic":
        return SyntheticTokens(cfg)
    if cfg.source.startswith("bytes:"):
        return ByteCorpus(cfg, cfg.source.split(":", 1)[1])
    raise ValueError(f"unknown data source {cfg.source}")


class Prefetcher:
    """Background-thread prefetch of ``batch_at(step)`` with bounded depth."""

    def __init__(self, source, start_step: int, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.next_to_produce = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while not self._stop.is_set():
            batch = self.source.batch_at(self.next_to_produce)
            step = self.next_to_produce
            self.next_to_produce += 1
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue

    def get(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
