from .pipeline import (ByteCorpus, DataConfig, Prefetcher, SyntheticTokens,
                       make_pipeline)

__all__ = ["DataConfig", "SyntheticTokens", "ByteCorpus", "Prefetcher",
           "make_pipeline"]
