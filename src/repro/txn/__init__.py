"""Sundial-like distributed transaction substrate (paper §5.1).

Partitioned store with per-partition NO-WAIT 2PL lock tables, a closed-loop
transaction executor running on the discrete-event sim, and the paper's two
workloads (YCSB with zipfian skew, TPC-C NewOrder/Payment).
"""
from .store import LockTable, LockMode
from .workload import (GeoYCSBWorkload, TPCCWorkload, YCSBWorkload,
                       zipf_sampler)
from .executor import (AdaptiveTimeouts, BenchConfig, BenchResult,
                       median_of_trials, run_bench)

__all__ = ["LockTable", "LockMode", "YCSBWorkload", "TPCCWorkload",
           "GeoYCSBWorkload",
           "zipf_sampler", "BenchConfig", "BenchResult", "run_bench",
           "median_of_trials", "AdaptiveTimeouts"]
