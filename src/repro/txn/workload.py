"""Workload generators: YCSB (§5.1.3) and TPC-C (§5.4).

YCSB: one table partitioned round-robin; each transaction accesses 16 tuples,
50/50 read/write by default, keys drawn zipfian(θ) — θ=0 is uniform.

TPC-C: 50/50 NewOrder/Payment over W warehouses spread across nodes; fewer
warehouses ⇒ hotter warehouse/district rows ⇒ more NO-WAIT aborts.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Callable, List, Sequence, Tuple


# One logical data access: (partition_node, key, is_write).
Access = Tuple[str, str, bool]


def zipf_sampler(n: int, theta: float, rng: random.Random) -> Callable[[], int]:
    """Gray et al. zipfian over [0, n); theta=0 degenerates to uniform."""
    if theta <= 1e-9:
        return lambda: rng.randrange(n)
    # Precompute zeta constants once (n is small enough per partition).
    zetan = sum(1.0 / (i ** theta) for i in range(1, n + 1))
    zeta2 = sum(1.0 / (i ** theta) for i in range(1, 3))
    alpha = 1.0 / (1.0 - theta)
    eta = (1.0 - (2.0 / n) ** (1.0 - theta)) / (1.0 - zeta2 / zetan)

    def sample() -> int:
        u = rng.random()
        uz = u * zetan
        if uz < 1.0:
            return 0
        if uz < 1.0 + 0.5 ** theta:
            return 1
        return int(n * (eta * u - eta + 1.0) ** alpha)

    return sample


@dataclass
class Txn:
    txn_id: str
    coordinator: str
    accesses: List[Access]

    @property
    def participants(self) -> List[str]:
        seen: List[str] = []
        for node, _, _ in self.accesses:
            if node not in seen:
                seen.append(node)
        return seen

    @property
    def read_only_parts(self) -> frozenset:
        writes = {n for n, _, w in self.accesses if w}
        return frozenset(p for p in self.participants if p not in writes)

    @property
    def is_distributed(self) -> bool:
        return len(self.participants) > 1


class YCSBWorkload:
    def __init__(self, nodes: Sequence[str], theta: float = 0.0,
                 accesses_per_txn: int = 16, read_ratio: float = 0.5,
                 keys_per_partition: int = 10_000, seed: int = 0,
                 partition_theta: float = 0.0):
        self.nodes = list(nodes)
        self.theta = theta
        self.n_access = accesses_per_txn
        self.read_ratio = read_ratio
        self.rng = random.Random(seed)
        self.keys = keys_per_partition
        self._zipf = zipf_sampler(keys_per_partition, theta, self.rng)
        # Hot-partition skew (group-commit contention benches): partitions
        # drawn zipfian(partition_theta) instead of uniformly — θ=0 keeps
        # the original uniform draw, bit-identically.
        self.partition_theta = partition_theta
        self._pzipf = zipf_sampler(len(self.nodes), partition_theta, self.rng)
        self._seq = 0

    def next_txn(self, coordinator: str) -> Txn:
        self._seq += 1
        accesses: List[Access] = []
        used = set()
        while len(accesses) < self.n_access:
            node = self.nodes[self._pzipf()]
            key = f"k{self._zipf()}"
            if (node, key) in used:
                continue
            used.add((node, key))
            is_write = self.rng.random() >= self.read_ratio
            accesses.append((node, key, is_write))
        return Txn(f"ycsb-{coordinator}-{self._seq}", coordinator, accesses)


class GeoYCSBWorkload(YCSBWorkload):
    """Geo-distributed YCSB (extended §6): coordinators run in a *home*
    region while the data — and therefore every participant — lives on
    partitions in the other regions.  Commit then always crosses region
    boundaries, which is the scenario where the number of round trips on
    the critical path (Table 3) dominates caller latency.
    """

    def __init__(self, nodes: Sequence[str], placement, home_region: str,
                 **kw):
        self.home_region = home_region
        self.placement = dict(placement)
        remote = [n for n in nodes
                  if self.placement.get(n) != home_region]
        # Degenerate placements (everything in the home region) fall back to
        # plain YCSB over all nodes rather than generating empty txns.
        super().__init__(remote or list(nodes), **kw)


class TPCCWorkload:
    """NewOrder + Payment (50/50), simplified to their lock footprints."""

    def __init__(self, nodes: Sequence[str], n_warehouses: int,
                 seed: int = 0, remote_item_prob: float = 0.01):
        assert n_warehouses >= 1
        self.nodes = list(nodes)
        self.W = n_warehouses
        self.rng = random.Random(seed)
        self.remote_prob = remote_item_prob
        self._seq = 0

    def _wh_node(self, w: int) -> str:
        return self.nodes[w % len(self.nodes)]

    def next_txn(self, coordinator: str) -> Txn:
        self._seq += 1
        rng = self.rng
        w = rng.randrange(self.W)
        home = self._wh_node(w)
        d = rng.randrange(10)
        accesses: List[Access] = []
        if rng.random() < 0.5:
            # Payment: W_YTD (hot!), district, customer — all writes.
            accesses.append((home, f"WH{w}", True))
            accesses.append((home, f"D{w}.{d}", True))
            accesses.append((home, f"C{w}.{d}.{rng.randrange(3000)}", True))
            # 15% remote customer payment.
            if rng.random() < 0.15 and self.W > 1:
                rw = rng.randrange(self.W)
                accesses.append((self._wh_node(rw),
                                 f"C{rw}.{rng.randrange(10)}.{rng.randrange(3000)}",
                                 True))
            name = "payment"
        else:
            # NewOrder: district next_o_id (hot), warehouse (read),
            # 5–15 stock rows, ~1% on remote warehouses.
            accesses.append((home, f"WH{w}", False))
            accesses.append((home, f"D{w}.{d}", True))
            for _ in range(rng.randrange(5, 16)):
                if rng.random() < self.remote_prob and self.W > 1:
                    sw = rng.randrange(self.W)
                else:
                    sw = w
                accesses.append((self._wh_node(sw),
                                 f"S{sw}.{rng.randrange(100_000)}", True))
            name = "neworder"
        # Dedup identical keys, keep strongest mode.
        merged = {}
        for node, key, wr in accesses:
            merged[(node, key)] = merged.get((node, key), False) or wr
        acc = [(n, k, wmode) for (n, k), wmode in merged.items()]
        return Txn(f"tpcc-{name}-{coordinator}-{self._seq}", coordinator, acc)
