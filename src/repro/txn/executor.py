"""Closed-loop distributed-transaction executor on the discrete-event sim.

Reproduces the paper's measurement setup (§5.1): N compute nodes, each with
`threads_per_node` closed-loop workers executing stored-procedure txns; data
accesses go to the owning partition over 0.5 ms RTT RPCs; NO-WAIT 2PL aborts
on conflict with exponential backoff + retry; commit runs whatever protocol
``BenchConfig.protocol`` names in the commit-protocol registry (cornus, 2pc,
cl, cornus-opt1, paxos-commit, ...) against the simulated storage service.
Latencies are collected for *distributed* transactions only, like the paper.
"""
from __future__ import annotations

import multiprocessing
import os
import random
import sys
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from ..core.chaos import FaultSchedule, GuardedStorage, Nemesis
from ..core.control import AdaptiveTimeouts, DecisionCacheConfig
from ..core.history import HistoryRecorder, check_history
from ..core.lifecycle import LifecycleConfig
from ..core.protocol import Cluster, ProtocolConfig
from ..core.protocols import get_protocol
from ..core.sim import Sim
from ..core.state import Decision, TxnSpec, Vote
from ..core.storage import (COMPUTE_RTT_MS, BatchConfig, LatencyModel,
                            RegionTopology)
from ..core.stores import StoreConfig, build_store
from .store import LockMode, LockTable
from .workload import Txn

__all__ = ["AdaptiveTimeouts", "BenchConfig", "BenchResult",
           "median_of_trials", "percentile", "run_bench"]


def percentile(xs: List[float], q: float) -> float:
    """The percentile rule every bench result reports (nearest-rank on the
    sorted sample, clamped) — shared so the serving SLO reports and the sim
    ``BenchResult`` quote identical statistics."""
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, int(q * len(s)))]


@dataclass
class BenchConfig:
    protocol: str = "cornus"          # any registered protocol name
    n_nodes: int = 4
    threads_per_node: int = 8
    horizon_ms: float = 2000.0        # issue window (sim time)
    rtt_ms: float = COMPUTE_RTT_MS
    access_cpu_ms: float = 0.02       # local processing per access
    backoff_ms: float = 1.0
    max_attempts: int = 25
    elr: bool = False
    seed: int = 0
    # --- replicated / geo-distributed storage (extended §6) ---------------
    replication: int = 1              # R=1 keeps the single SimStorage
    topology: Optional[RegionTopology] = None
    placement: Optional[Dict[str, str]] = None   # node -> region
    replica_regions: Optional[List[str]] = None  # per-replica region
    # leader | coloc | None → the protocol's preferred mode (paxos-commit
    # needs participants coordinating replication, i.e. coloc).
    storage_mode: Optional[str] = None
    # (replica_idx, fail_at_ms[, recover_at_ms]) outage schedule
    replica_failures: tuple = ()
    # (at_ms, n_replicas) live membership changes (replicated-sim only):
    # at each ``at_ms`` the store reconfigures to ``n_replicas`` members —
    # scale-out grows fresh joiners via recovery-driven state transfer,
    # scale-in retires the highest member ids.  Empty (the default) arms
    # nothing: the run is bit-identical to the pre-elasticity executor.
    reconfigurations: tuple = ()
    # Storage backend by registry name (core.stores).  None — the default —
    # keeps the historical auto-pick: "replicated-sim" when replication > 1
    # or a topology is set, else "sim".  Naming a threaded backend here is
    # rejected (run_bench drives a discrete-event Sim).
    store: Optional[str] = None
    # Restrict closed-loop clients to these nodes (geo: home-region
    # coordinators only); None = clients on every node.
    coordinator_nodes: Optional[List[str]] = None
    # --- storage-side group commit (batching) ------------------------------
    # window=0 + serial=False (the default) is an exact passthrough: every
    # request keeps its own concurrent round trip, bit-identical to the
    # pre-batching simulator.  storage_serial=True models the serial log
    # device per partition (one write round trip in flight at a time);
    # batch_window_ms/batch_max control how aggressively queued requests
    # coalesce into one round trip (see core.storage.BatchConfig);
    # batch_window_ms="auto" is the load-proportional window clamped to
    # [0, batch_max_window_ms].
    batch_window_ms: "float | str" = 0.0
    batch_max: int = 64
    storage_serial: bool = False
    batch_max_window_ms: float = 4.0
    # Leadership-lease term for the replicated leader-mode store: how long
    # a post-failover leader's epoch (acquired with ONE bulk prepare round)
    # stays valid before a renewal round.  The initial leader's implicit
    # epoch-1 lease never expires, so the no-failure case pays nothing.
    lease_ms: float = 200.0
    # Protocol timeouts (vote/decision/termination).  None — the default —
    # auto-computes the static floor from service times + topology AND
    # attaches an ``AdaptiveTimeouts`` policy that raises (never lowers)
    # the effective timeout to track the EWMA of observed storage latency,
    # serial-lane queueing delay included, with desynchronizing jitter:
    # no-failure runs whose static timeouts never fire are bit-identical,
    # while saturated runs stop spuriously terminating healthy txns.  An
    # explicit float pins fully static timeouts (the paper's deployments
    # likewise tune timeouts per storage service).
    timeout_ms: Optional[float] = None
    # --- termination-storm controls (all default-off) ----------------------
    # Storage-side decision cache: once any slot of a txn holds a terminal
    # record, later log_once calls are answered from the index (one cheap
    # read — no CAS/Paxos round, no serial-lane slot).
    decision_cache: bool = False
    # Storage-side singleflight: concurrent identical in-flight log_once
    # rounds for one (partition, txn, state) coalesce into ONE round.
    termination_singleflight: bool = False
    # Storage pushes a txn's first terminal value to still-waiting
    # participants (via the transport deliver machinery), so most of them
    # never time out into the termination protocol at all.
    decision_push: bool = False
    # Compute-side per-(node, txn) singleflight on terminate().
    termination_dedup: bool = False
    # Per-lane adaptive timeouts: the attached AdaptiveTimeouts policy reads
    # the EWMA of the lane (partition) a wait is actually gated on instead
    # of the service-global aggregate, so one hot zipf lane raises only its
    # own deadlines.  Default-off: the global-EWMA baselines stay
    # bit-identical (lane stats are recorded either way — pure bookkeeping).
    per_lane_timeouts: bool = False
    # A transaction attempt aborted by the commit protocol (terminated /
    # voted ABORT) retries under a FRESH commit-protocol txn id: LogOnce
    # slots of the aborted attempt stay terminal forever, so retrying the
    # same id can only re-abort (the gaveup black hole the termination
    # storm feeds).  NO-WAIT conflicts detected before the protocol runs
    # leave no records and are unaffected either way.
    retry_fresh_ids: bool = False
    # --- chaos plane / history checker (all default-off) -------------------
    # A core.chaos.FaultSchedule to inject (message chaos, partitions,
    # clock skew, torn writes, crash–restarts).  None arms nothing: the
    # run is bit-identical to the pre-chaos executor.
    chaos: Optional[FaultSchedule] = None
    # Record every storage op + decision into a core.history recorder and
    # run the AC1–AC3 / writer-of / recoverability checker post-run
    # (results in BenchResult.violations / .violation_details).
    record_history: bool = False
    # Wrap storage ops in the retry + per-partition circuit-breaker guard.
    # None (default) = auto: guarded exactly when a chaos schedule is set
    # (chaos-eaten ops leave events forever untriggered; only idempotent
    # re-issue recovers them).  True/False forces it either way.
    storage_guard: Optional[bool] = None
    # Extra (node, crash_at_ms, restart_at_ms) crash–restarts armed on the
    # cluster directly (the schedule's own crashes ride cfg.chaos).
    crash_restarts: tuple = ()
    # --- durable-state lifecycle (default-off) -----------------------------
    # A core.lifecycle.LifecycleConfig (or its dict form) arming CRC32
    # record framing, watermark GC and the anti-entropy scrubber on the
    # store.  None — the default — builds the store exactly as before:
    # every existing baseline stays bit-identical.
    lifecycle: Optional[object] = None


@dataclass
class BenchResult:
    protocol: str
    n_nodes: int
    commits: int = 0
    aborts: int = 0                  # failed attempts (NO-WAIT conflicts)
    gaveups: int = 0
    latencies: List[float] = field(default_factory=list)
    exec_ms: List[float] = field(default_factory=list)
    abort_ms: List[float] = field(default_factory=list)
    prepare_ms: List[float] = field(default_factory=list)
    commit_ms: List[float] = field(default_factory=list)
    horizon_ms: float = 0.0
    # Storage-side accounting (group-commit amortization).  requests counts
    # logical API calls; round_trips counts wire rounds paid — one per op
    # on the single SimStorage (== requests with batching off), one per
    # quorum scatter on ReplicatedSimStorage (reads and multi-phase
    # proposals pay several, so it can exceed requests there).  Compare
    # round_trips across batch modes of the SAME config, not across
    # storage deployments.
    storage_requests: int = 0
    storage_round_trips: int = 0
    # Leadership-lease accounting (replicated leader mode; 0/empty
    # elsewhere).  fast_path_ops counts ops served by an owner/lease-ballot
    # single accept round (batched flush ops included); fallback_ops counts
    # ops that paid the full prepare+accept (or a per-op batch fallback);
    # lease_history holds (epoch, holder_replica, acquired_at_ms) per
    # post-failover acquisition — time-to-fast-path falls out of it.
    lease_acquisitions: int = 0
    fast_path_ops: int = 0
    fallback_ops: int = 0
    lease_history: List[tuple] = field(default_factory=list)
    # Elastic membership: (started_ms, cutover_ms, installed_ms, old_n,
    # new_n) per completed config change (started→cutover is background
    # state transfer, cutover→installed the disruptive epoch bump), and
    # ops that wanted the lease fast path but degraded to the full
    # proposer (0/empty without reconfiguration).
    reconfig_history: List[tuple] = field(default_factory=list)
    lease_degradations: int = 0
    # Termination-storm accounting: termination runs started, runs absorbed
    # by the compute-side per-(node, txn) singleflight, log_once calls
    # answered from the storage decision cache, calls that joined an
    # in-flight identical round, and proactive decision pushes delivered.
    terminations: int = 0
    dedup_hits: int = 0
    decision_cache_hits: int = 0
    singleflight_hits: int = 0
    decisions_pushed: int = 0
    # Fault attribution (all zero without a chaos schedule): what the
    # nemesis actually injected, what the delivery guard suppressed, what
    # the retry/breaker layer absorbed, and how many crash–restart
    # recoveries ran.  ``violations`` is the history checker's verdict
    # (−1 = checker not run; details capped for picklability).
    msgs_dropped: int = 0
    msgs_duplicated: int = 0
    msgs_delayed: int = 0
    msgs_reordered: int = 0
    partitions_healed: int = 0
    torn_writes: int = 0
    duplicate_deliveries: int = 0
    guard_retries: int = 0
    breaker_trips: int = 0
    breaker_half_opens: int = 0
    crash_restarts: int = 0
    recoveries_run: int = 0
    violations: int = -1
    violation_details: List[str] = field(default_factory=list)
    # Durable-state lifecycle accounting (all zero with lifecycle=None):
    # slots truncated by the GC watermark, un-truncated slots still behind
    # it at run end, scrub repairs performed, volumes quarantined, and the
    # checksum layer's corrupt / torn record detections.  recovery_spans
    # holds (node, t_restart, t_done, slots_scanned) per durable restart —
    # the recovery-time bound benchmarks/recovery_gc.py gates.
    gc_truncations: int = 0
    watermark_lag: int = 0
    scrub_repairs: int = 0
    quarantines: int = 0
    corrupt_records: int = 0
    torn_records: int = 0
    recovery_spans: List[tuple] = field(default_factory=list)

    @staticmethod
    def _avg(xs: List[float]) -> float:
        return sum(xs) / len(xs) if xs else 0.0

    def _percentile(self, q: float) -> float:
        return percentile(self.latencies, q)

    @property
    def avg_latency_ms(self) -> float:
        return self._avg(self.latencies)

    @property
    def p50_latency_ms(self) -> float:
        return self._percentile(0.50)

    @property
    def p95_latency_ms(self) -> float:
        return self._percentile(0.95)

    @property
    def p99_latency_ms(self) -> float:
        return self._percentile(0.99)

    @property
    def throughput_tps(self) -> float:
        return self.commits / (self.horizon_ms / 1000.0) if self.horizon_ms else 0.0

    def breakdown(self) -> Dict[str, float]:
        return {"execution": self._avg(self.exec_ms),
                "abort": self._avg(self.abort_ms),
                "prepare": self._avg(self.prepare_ms),
                "commit": self._avg(self.commit_ms),
                "p50": self.p50_latency_ms,
                "p95": self.p95_latency_ms,
                "msgs_dropped": self.msgs_dropped,
                "msgs_duplicated": self.msgs_duplicated,
                "msgs_delayed": self.msgs_delayed,
                "msgs_reordered": self.msgs_reordered,
                "partitions_healed": self.partitions_healed,
                "torn_writes": self.torn_writes,
                "duplicate_deliveries": self.duplicate_deliveries,
                "guard_retries": self.guard_retries,
                "breaker_trips": self.breaker_trips,
                "breaker_half_opens": self.breaker_half_opens,
                "crash_restarts": self.crash_restarts,
                "recoveries_run": self.recoveries_run,
                "violations": self.violations,
                "gc_truncations": self.gc_truncations,
                "watermark_lag": self.watermark_lag,
                "scrub_repairs": self.scrub_repairs,
                "quarantines": self.quarantines,
                "corrupt_records": self.corrupt_records,
                "torn_records": self.torn_records}


def run_bench(workload_factory, model: LatencyModel,
              cfg: BenchConfig) -> BenchResult:
    """Run one trial; `workload_factory(nodes, seed)` builds the generator."""
    sim = Sim()
    # Resolve the protocol up front (validates the name; no branching —
    # every protocol-specific behaviour lives behind the strategy class).
    proto_cls = get_protocol(cfg.protocol)
    nodes = [f"n{i}" for i in range(cfg.n_nodes)]
    placement = dict(cfg.placement) if cfg.placement else (
        cfg.topology.place_round_robin(nodes) if cfg.topology else {})
    batch = BatchConfig(window_ms=cfg.batch_window_ms,
                        max_batch=cfg.batch_max, serial=cfg.storage_serial,
                        max_window_ms=cfg.batch_max_window_ms)
    decisions = DecisionCacheConfig(cache=cfg.decision_cache,
                                    singleflight=cfg.termination_singleflight,
                                    push=cfg.decision_push)
    # Storage goes through the unified store registry (core.stores): the
    # builders pass EXACTLY the kwargs this function always passed to the
    # constructors, so every simulated baseline stays bit-identical.
    backend = cfg.store or ("replicated-sim"
                            if cfg.replication > 1 or cfg.topology is not None
                            else "sim")
    mode = (cfg.storage_mode or proto_cls.preferred_storage_mode or "leader")
    lifecycle = LifecycleConfig.coerce(cfg.lifecycle)
    storage = build_store(StoreConfig(
        backend=backend, model=model, seed=cfg.seed, batch=batch,
        decisions=decisions, replication=cfg.replication,
        topology=cfg.topology, replica_regions=cfg.replica_regions,
        placement=placement, mode=mode, lease_ms=cfg.lease_ms,
        lifecycle=lifecycle), sim=sim)
    if hasattr(storage, "fail_replica"):   # single-store backends: no-op
        for outage in cfg.replica_failures:
            storage.fail_replica(*outage)
    if cfg.reconfigurations:
        if not hasattr(storage, "schedule_reconfigure"):
            raise ValueError(f"backend {backend!r} does not support live "
                             f"membership changes (reconfigurations=)")
        for at_ms, n_new in cfg.reconfigurations:
            storage.schedule_reconfigure(at_ms, n_new)
    # Timeouts must sit above the storage service's tail latency, or healthy
    # transactions get spuriously terminated (the paper's deployments tune
    # timeouts per service; we scale with the model's write latency, and in
    # geo deployments with the worst link RTT times the quorum round count).
    topo_rtt = cfg.topology.max_rtt_ms if cfg.topology else 0.0
    # Group-commit deployments wait out the batch window (and, with a serial
    # log device, some queueing) before a write returns: scale timeouts with
    # the window so a healthy batched write is not spuriously terminated.
    policy = None
    if cfg.timeout_ms is not None:
        tmo = cfg.timeout_ms
    else:
        tmo = max(
            25.0, 8.0 * model.conditional_write_ms + 4.0 * cfg.rtt_ms
            + 8.0 * topo_rtt + 8.0 * batch.worst_case_window_ms)
        # The static formula becomes the FLOOR of an adaptive policy that
        # tracks the observed (queueing-inclusive) storage latency: a
        # saturated serial lane raises the effective timeouts instead of
        # feeding a termination storm; runs where the static timeouts
        # never fire are unchanged (the policy is raise-only).
        policy = AdaptiveTimeouts(storage, seed=cfg.seed,
                                  per_lane=cfg.per_lane_timeouts)
    pcfg = ProtocolConfig(protocol=cfg.protocol,
                          rtt_ms=cfg.rtt_ms, elr=cfg.elr,
                          vote_timeout_ms=tmo, decision_timeout_ms=tmo,
                          votereq_timeout_ms=tmo, termination_retry_ms=tmo,
                          coop_retry_ms=tmo,
                          topology=cfg.topology, placement=placement,
                          push_decisions=cfg.decision_push,
                          termination_dedup=cfg.termination_dedup,
                          timeout_policy=policy)
    # --- chaos plane + history checker (all no-ops when unarmed) ----------
    history = None
    if cfg.record_history:
        history = HistoryRecorder(sim)
        storage.history = history       # sim services: subscription-only
    use_guard = (cfg.storage_guard if cfg.storage_guard is not None
                 else cfg.chaos is not None)
    raw_storage = storage
    if use_guard:
        # Per-attempt deadline above the service's own worst case, so the
        # guard only re-issues ops chaos genuinely ate (idempotent: LogOnce
        # re-issues read the winner).
        deadline = max(30.0, 1.5 * tmo,
                       getattr(storage, "op_timeout_ms", 0.0) + 10.0)
        storage = GuardedStorage(storage, sim, seed=cfg.seed,
                                 deadline_ms=deadline)
    cluster = Cluster(sim, storage, nodes, pcfg)
    nemesis = None
    if cfg.chaos is not None:
        nemesis = Nemesis(cfg.chaos, sim).attach(
            transport=cluster.transport, storage=raw_storage,
            cluster=cluster)
    for node, crash_at, restart_at in cfg.crash_restarts:
        cluster.schedule_crash_restart(node, crash_at, restart_at)
    crashes_armed = bool(cfg.crash_restarts) or (
        cfg.chaos is not None and bool(cfg.chaos.crashes))
    # Background lifecycle passes: fixed deterministic cadences (no rng
    # draws), re-armed recursively until just past the issue horizon so
    # late decisions still settle and truncate.
    if lifecycle is not None:
        lifecycle_end = cfg.horizon_ms + 400.0
        if lifecycle.scrub and lifecycle.scrub_interval_ms > 0 \
                and hasattr(raw_storage, "scrub_pass"):
            def _scrub_tick():
                raw_storage.scrub_pass()
                nxt = sim.now + lifecycle.scrub_interval_ms
                if nxt < lifecycle_end:
                    sim._schedule(nxt, _scrub_tick)
            sim._schedule(lifecycle.scrub_interval_ms, _scrub_tick)
        if lifecycle.gc and lifecycle.gc_interval_ms > 0 \
                and hasattr(raw_storage, "gc_pass"):
            def _gc_tick():
                raw_storage.gc_pass(sim.now)
                nxt = sim.now + lifecycle.gc_interval_ms
                if nxt < lifecycle_end:
                    sim._schedule(nxt, _gc_tick)
            sim._schedule(lifecycle.gc_interval_ms, _gc_tick)
    locks = {n: LockTable(n) for n in nodes}

    def release(node: str, txn: str, *_):
        locks[node].release_all(txn)

    cluster.on_finish = lambda node, txn, dec, t: release(node, txn)
    cluster.on_precommit = release  # only fires when cfg.elr

    workload = workload_factory(nodes, cfg.seed)
    res = BenchResult(cfg.protocol, cfg.n_nodes, horizon_ms=cfg.horizon_ms)
    rng = random.Random(cfg.seed ^ 0x5EED)

    def client(node: str, cid: int):
        while sim.now < cfg.horizon_ms:
            if crashes_armed and not cluster.alive(node):
                # Crashed node: its closed-loop clients are down too; they
                # resume issuing once the node restarts.  Only evaluated
                # when crash–restarts are armed, so ordinary runs never
                # consult liveness here (bit-identical).
                yield sim.timeout(5.0)
                continue
            txn = workload.next_txn(node)
            t_arrive = sim.now
            abort_time = 0.0
            attempt = 0
            committed = False
            while attempt < cfg.max_attempts:
                attempt += 1
                # A protocol-aborted attempt leaves terminal LogOnce records
                # under its txn id; with retry_fresh_ids each attempt runs
                # the commit protocol (and takes locks) under its own
                # incarnation id, so a terminated attempt's poisoned slots
                # can't abort every retry into a gaveup.  Attempt 1 keeps
                # the workload id, so runs that never retry are unchanged.
                attempt_id = (txn.txn_id
                              if attempt == 1 or not cfg.retry_fresh_ids
                              else f"{txn.txn_id}~r{attempt}")
                t_attempt = sim.now
                ok = True
                touched: List[str] = []
                for (pnode, key, is_write) in txn.accesses:
                    mode = LockMode.EXCLUSIVE if is_write else LockMode.SHARED
                    if pnode != node:
                        # RPC to the owning partition (geo-aware RTT).
                        yield sim.timeout(pcfg.link_rtt_ms(node, pnode))
                    yield sim.timeout(cfg.access_cpu_ms)
                    if pnode not in touched:
                        touched.append(pnode)
                    if not locks[pnode].try_lock(attempt_id, key, mode):
                        ok = False
                        break
                if not ok:
                    res.aborts += 1
                    for p in touched:
                        locks[p].release_all(attempt_id)
                    backoff = cfg.backoff_ms * attempt * (0.5 + rng.random())
                    yield sim.timeout(backoff)
                    abort_time += sim.now - t_attempt
                    continue
                # Execution done — run atomic commit.
                exec_ms = sim.now - t_attempt
                spec = TxnSpec(
                    txn_id=attempt_id, coordinator=node,
                    participants=txn.participants,
                    read_only=txn.read_only_parts,
                    read_only_known_upfront=True)
                if not txn.is_distributed:
                    # Single-partition fast path: one forced commit record,
                    # written by the owning partition (which may be a node
                    # other than the coordinator, e.g. a TPC-C home
                    # warehouse or any geo participant — then the commit
                    # request/ack round trip to the owner is on the path).
                    owner = txn.participants[0]
                    if owner != node:
                        yield sim.timeout(pcfg.link_rtt_ms(node, owner))
                    if owner not in txn.read_only_parts:
                        yield storage.log(owner, attempt_id, Vote.COMMIT,
                                          writer=owner)
                    release(owner, attempt_id)
                    committed = True
                else:
                    done = cluster.run_txn(spec)
                    out = yield done
                    committed = out is not None and out.decision == Decision.COMMIT
                    if committed:
                        res.prepare_ms.append(out.prepare_ms)
                        res.commit_ms.append(out.commit_ms)
                if committed:
                    if txn.is_distributed:
                        res.commits += 1
                        res.latencies.append(sim.now - t_arrive)
                        res.exec_ms.append(exec_ms)
                        res.abort_ms.append(abort_time)
                    break
                else:
                    for p in txn.participants:
                        locks[p].release_all(attempt_id)
                    yield sim.timeout(cfg.backoff_ms * attempt)
                    abort_time += sim.now - t_attempt
            if not committed:
                res.gaveups += 1

    client_nodes = cfg.coordinator_nodes if cfg.coordinator_nodes else nodes
    for n in client_nodes:
        for c in range(cfg.threads_per_node):
            sim.process(client(n, c))
    sim.run(until=cfg.horizon_ms + 500.0)
    res.storage_requests = storage.requests
    res.storage_round_trips = storage.round_trips
    res.lease_acquisitions = getattr(storage, "lease_acquisitions", 0)
    res.fast_path_ops = getattr(storage, "fast_path_ops", 0)
    res.fallback_ops = getattr(storage, "fallback_ops", 0)
    res.lease_history = list(getattr(storage, "lease_history", ()))
    res.reconfig_history = list(getattr(storage, "reconfig_history", ()))
    res.lease_degradations = getattr(storage, "lease_degradations", 0)
    res.terminations = cluster.ctx.terminations
    res.dedup_hits = cluster.ctx.dedup_hits
    res.decision_cache_hits = getattr(storage, "decision_cache_hits", 0)
    res.singleflight_hits = getattr(storage, "singleflight_hits", 0)
    res.decisions_pushed = getattr(storage, "decisions_pushed", 0)
    # Fault attribution + machine-checked safety (zero / -1 when unarmed).
    if nemesis is not None:
        res.msgs_dropped = nemesis.msgs_dropped
        res.msgs_duplicated = nemesis.msgs_duplicated
        res.msgs_delayed = nemesis.msgs_delayed
        res.msgs_reordered = nemesis.msgs_reordered
        res.partitions_healed = nemesis.partitions_healed
        res.torn_writes = nemesis.torn_writes
    res.duplicate_deliveries = cluster.transport.duplicate_deliveries
    if use_guard:
        res.guard_retries = storage.retries
        res.breaker_trips = storage.breaker.trips
        res.breaker_half_opens = storage.breaker.half_opens
    res.crash_restarts = cluster.crash_restarts
    res.recoveries_run = cluster.recoveries_run
    if lifecycle is not None:
        # Final passes so the snapshot/checker sees repaired, settled
        # state: scrub first (repairs corrupt replicas), then one last GC.
        if lifecycle.scrub and hasattr(raw_storage, "scrub_pass"):
            raw_storage.scrub_pass()
        if lifecycle.gc and hasattr(raw_storage, "gc_pass"):
            raw_storage.gc_pass(sim.now)
        res.gc_truncations = getattr(raw_storage, "gc_truncations", 0)
        wl = getattr(raw_storage, "watermark_lag", None)
        res.watermark_lag = wl() if callable(wl) else 0
        res.scrub_repairs = getattr(raw_storage, "scrub_repairs", 0)
        res.quarantines = getattr(raw_storage, "quarantines", 0)
        res.corrupt_records = getattr(raw_storage, "corrupt_records", 0)
        res.torn_records = getattr(raw_storage, "torn_records", 0)
    res.recovery_spans = list(cluster.recovery_spans)
    if cfg.record_history:
        found = check_history(history, cluster.ctx,
                              snapshot=raw_storage.snapshot(),
                              participant_logs=proto_cls.participant_logs,
                              gc_log=getattr(raw_storage, "gc_log", None))
        res.violations = len(found)
        res.violation_details = [str(v) for v in found[:20]]
    return res


# Fork-inherited context for parallel trials: the workload factories used
# throughout the benches are closures/lambdas (unpicklable as arguments),
# but with the "fork" start method the child processes inherit them via the
# parent's address space — only the BenchResult travels back (picklable
# dataclass of primitives).
_TRIAL_CTX: Optional[Tuple] = None


def _trial_cfg(cfg: BenchConfig, t: int) -> BenchConfig:
    return BenchConfig(**{**cfg.__dict__, "seed": cfg.seed + 1000 * t})


def _run_trial(t: int) -> BenchResult:
    workload_factory, model, cfg = _TRIAL_CTX
    return run_bench(workload_factory, model, _trial_cfg(cfg, t))


def median_of_trials(workload_factory, model: LatencyModel, cfg: BenchConfig,
                     trials: int = 3,
                     processes: Optional[int] = None) -> BenchResult:
    """Paper §5.1.4: take the trial with median average latency.

    Trials are independent deterministic sims (per-trial seeds derived
    exactly as the serial implementation always did), so they fan out
    across worker processes when the platform supports ``fork`` — cutting
    benchmark/CI wall time to the slowest single trial.  The result (and
    the median pick, a stable sort on avg latency) is bit-identical to the
    serial path; pass ``processes=1`` to force serial execution.
    """
    global _TRIAL_CTX
    runs: Optional[List[BenchResult]] = None
    n_procs = min(trials, processes if processes is not None
                  else (os.cpu_count() or 1))
    # Forking a process that already initialized JAX's thread pools is
    # unsafe; default to serial there (an explicit ``processes`` opts in —
    # the forked children only run the pure-Python sim).
    fork_ok = hasattr(os, "fork") and (processes is not None
                                       or "jax" not in sys.modules)
    if trials > 1 and n_procs > 1 and fork_ok:
        _TRIAL_CTX = (workload_factory, model, cfg)
        try:
            ctx = multiprocessing.get_context("fork")
            with ctx.Pool(n_procs) as pool:
                runs = pool.map(_run_trial, range(trials))
        except OSError as e:            # sandboxed / fork denied: go serial
            print(f"# median_of_trials: fork pool unavailable ({e!r}), "
                  f"running trials serially", file=sys.stderr)
            runs = None
        finally:
            _TRIAL_CTX = None
    if runs is None:
        runs = [run_bench(workload_factory, model, _trial_cfg(cfg, t))
                for t in range(trials)]
    runs.sort(key=lambda r: r.avg_latency_ms)
    return runs[len(runs) // 2]
