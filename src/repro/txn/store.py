"""Partitioned store: per-partition NO-WAIT 2PL lock tables.

The paper's default concurrency control is NO-WAIT (§5.1.4): a conflicting
lock request aborts the requesting transaction immediately — deadlock-free,
and the reason contention shows up as abort/retry time (Fig 7b) rather than
lock-wait time.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Set, Tuple


class LockMode(enum.Enum):
    SHARED = "S"
    EXCLUSIVE = "X"


@dataclass
class _Entry:
    mode: LockMode
    holders: Set[str] = field(default_factory=set)


class LockTable:
    """One partition's lock table. Keys are opaque strings."""

    def __init__(self, partition: str):
        self.partition = partition
        self._locks: Dict[str, _Entry] = {}
        self._held_by: Dict[str, Set[str]] = {}  # txn -> keys
        self.acquires = 0
        self.conflicts = 0

    def try_lock(self, txn: str, key: str, mode: LockMode) -> bool:
        """NO-WAIT acquire: False ⇒ caller must abort the transaction."""
        self.acquires += 1
        e = self._locks.get(key)
        if e is None or not e.holders:
            self._locks[key] = _Entry(mode, {txn})
        elif txn in e.holders:
            if mode == LockMode.EXCLUSIVE and e.mode == LockMode.SHARED:
                if len(e.holders) > 1:
                    self.conflicts += 1
                    return False  # upgrade blocked by co-readers
                e.mode = LockMode.EXCLUSIVE
        elif mode == LockMode.SHARED and e.mode == LockMode.SHARED:
            e.holders.add(txn)
        else:
            self.conflicts += 1
            return False
        self._held_by.setdefault(txn, set()).add(key)
        return True

    def release_all(self, txn: str) -> int:
        """Drop every lock txn holds here (commit/abort/ELR-precommit)."""
        keys = self._held_by.pop(txn, set())
        for k in keys:
            e = self._locks.get(k)
            if e is None:
                continue
            e.holders.discard(txn)
            if not e.holders:
                del self._locks[k]
        return len(keys)

    def held(self, txn: str) -> Set[str]:
        return set(self._held_by.get(txn, ()))
