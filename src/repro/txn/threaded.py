"""Wall-clock commit bench: real threads against the threaded stores.

The simulated benches prove the protocol *logic*; this harness proves the
unified control plane (``core.control``) on the stores a real deployment
would use — ``MemoryStore`` / ``ReplicatedStore`` under genuinely
concurrent closed-loop workers measured with the wall clock.

Each worker thread commits transactions back-to-back by replaying the
protocol's storage choreography, derived from the SAME strategy-class
flags the sim uses (``participant_logs`` / ``vote_via_log_once`` /
``eager_decision_record``), so write counts per row match Table 3:

  cornus family – LogOnce(VOTE-YES) per participant; no decision record
                  on the critical path.
  2pc           – plain forced prepare log per participant PLUS an eager
                  forced commit record before replying (the latency cost
                  Cornus removes).
  cl            – participants don't log; one coordinator decision record.

Every forced write pays a fixed per-op service delay injected INSIDE the
store op (``perform()``), so throughput is dominated by how many forced
writes each protocol puts on the critical path — machine-independent up
to noise — and a control-plane cache hit, which answers without running
the op, really is cheaper than a CAS round.

A straggler storm exercises the storm controls end-to-end: every
``straggler_every``-th transaction parks before one vote write while
``terminators`` racer threads CAS ABORT into its slots through the same
barrier — producing real decision-cache hits, singleflight joins, and
watcher pushes on the threaded control plane.  On the replicated backend
a ``LeaseKeeper`` holds the store's leadership lease and workers write
under its identity, so commits ride the phase-1-free fast path
(``fast_path_ops``) exactly like the PR-4 sim results claim.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core.control import (DecisionCacheConfig, LeaseKeeper, STORM_CONTROL)
from ..core.protocols import get_protocol
from ..core.state import Vote
from ..core.storage import (DelayedMemoryStore, DelayedReplicatedStore,
                            MemoryStore, ReplicatedStore)
from ..core.variants import SIMULATED_RTT_ROWS

__all__ = ["WallclockConfig", "WallclockResult", "commit_txn",
           "run_wallclock", "wallclock_rows", "WALLCLOCK_BACKENDS"]

# Table-3 deployment → threaded backend: the "leader" rows run against the
# single shared store, the "coloc" rows against the quorum-replicated one.
WALLCLOCK_BACKENDS = {"leader": "memory", "coloc": "replicated"}


@dataclass
class WallclockConfig:
    protocol: str = "cornus"          # any registered protocol name
    backend: str = "memory"           # "memory" | "replicated"
    n_nodes: int = 4
    workers: int = 4                  # closed-loop worker threads
    txns_per_worker: int = 40
    participants_per_txn: int = 3
    service_delay_ms: float = 0.4     # per forced store op, inside perform()
    # Straggler storm: every k-th txn parks before one vote write while
    # terminator threads race ABORT into its slots.  0 disables.
    straggler_every: int = 8
    straggler_delay_ms: float = 4.0
    terminators: int = 2
    seed: int = 0
    decisions: DecisionCacheConfig = field(default=STORM_CONTROL)
    replication: int = 3              # replicated backend only
    lease: bool = True                # replicated: run a LeaseKeeper


@dataclass
class WallclockResult:
    protocol: str
    backend: str
    commits: int = 0
    terminated: int = 0               # txns aborted by the storm
    elapsed_s: float = 0.0
    # Control-plane counters (same names as the sim results).
    decision_cache_hits: int = 0
    singleflight_hits: int = 0
    decisions_pushed: int = 0
    fast_path_ops: int = 0
    fallback_ops: int = 0
    lease_acquisitions: int = 0
    lease_degradations: int = 0       # keeper slow-path answers (surfaced)

    @property
    def throughput_tps(self) -> float:
        return self.commits / self.elapsed_s if self.elapsed_s > 0 else 0.0


# The delayed threaded stores now live in ``core.storage`` (shared with the
# serving harness and constructible through the store factory); keep the
# old private names importable.
_DelayedMemoryStore = DelayedMemoryStore
_DelayedReplicatedStore = DelayedReplicatedStore


def commit_txn(store, proto, txn: str, coordinator: str,
               participants: Sequence[str],
               writer_for: Callable[[str], str] = lambda p: p,
               before_vote: Optional[Callable[[int, str], None]] = None
               ) -> bool:
    """Replay one Table-3 commit choreography against a threaded store.

    The storage write sequence is derived from the protocol strategy's
    capability flags (the same flags the sim uses), so forced-write counts
    per row match Table 3 — see the module docstring.  ``writer_for``
    supplies the identity stamped on each write (a lease holder's for the
    replicated fast path); ``before_vote(i, participant)`` runs before the
    i-th vote write, which is where the wall-clock bench parks stragglers.
    Returns True on COMMIT, False when a terminal record beat a vote.
    """
    if not proto.participant_logs:
        # cl: one coordinator decision record, participants log nothing.
        got = store.log_once(coordinator, txn, Vote.COMMIT,
                             writer=writer_for(coordinator))
        return got == Vote.COMMIT
    outcome = None
    for i, p in enumerate(participants):
        if before_vote is not None:
            before_vote(i, p)
        if proto.vote_via_log_once:
            got = store.log_once(p, txn, Vote.VOTE_YES,
                                 writer=writer_for(p))
        else:
            got = store.log(p, txn, Vote.VOTE_YES, writer=writer_for(p))
        if got != Vote.VOTE_YES:
            outcome = got              # a terminal record beat the vote
            break
    if outcome is None:
        if proto.eager_decision_record:
            # 2PC: the commit record is the ground truth — forced before
            # the caller hears COMMIT.
            store.log(coordinator, txn, Vote.COMMIT,
                      writer=writer_for(coordinator))
        return True
    return outcome == Vote.COMMIT


class _StallBoard:
    """Rendezvous between stalled workers and terminator racers.

    A worker parks a txn (its slots) here before sleeping out its
    straggler delay.  The board is append-only and every terminator reads
    it through its OWN cursor, so ALL racers process the SAME txns in the
    same order — their ``log_once`` calls for one slot (aligned by a
    barrier) really are concurrent: one leads, the rest singleflight-join,
    and later slots of an already-terminated txn hit the decision cache."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._items: List[Tuple[str, List[str]]] = []
        self.closed = False

    def park(self, txn: str, slots: List[str]) -> None:
        with self._lock:
            self._items.append((txn, list(slots)))

    def close(self) -> None:
        self.closed = True

    def items_from(self, cursor: int) -> List[Tuple[str, List[str]]]:
        with self._lock:
            return self._items[cursor:]


def _build_store(cfg: WallclockConfig):
    delay_s = cfg.service_delay_ms / 1e3
    if cfg.backend == "replicated":
        return DelayedReplicatedStore(delay_s, n_replicas=cfg.replication,
                                      seed=cfg.seed,
                                      decisions=cfg.decisions)
    if cfg.backend == "memory":
        return DelayedMemoryStore(delay_s, decisions=cfg.decisions)
    raise ValueError(f"unknown wallclock backend {cfg.backend!r}")


def run_wallclock(cfg: WallclockConfig) -> WallclockResult:
    """Run one protocol row against one threaded backend, wall-clock timed."""
    proto = get_protocol(cfg.protocol)
    store = _build_store(cfg)
    nodes = [f"n{i}" for i in range(cfg.n_nodes)]
    npart = max(1, min(cfg.participants_per_txn, cfg.n_nodes))
    res = WallclockResult(cfg.protocol, cfg.backend)
    res_lock = threading.Lock()

    keeper = None
    if cfg.backend == "replicated" and cfg.lease:
        keeper = LeaseKeeper(store, holder="wallclock-leader")

    def writer_for(p: str) -> str:
        # Replicated deployments write under the lease holder's identity
        # (one committer process holds the epoch): phase-1-free accepts.
        if keeper is not None:
            lease = keeper.ensure()
            if lease is not None:
                return lease.holder
        return p

    board = _StallBoard() if cfg.straggler_every else None
    storm = cfg.straggler_every and cfg.terminators > 0
    barrier = threading.Barrier(cfg.terminators) if storm else None

    def commit_one(worker: int, seq: int) -> None:
        txn = f"w{worker}t{seq}"
        coord = nodes[(worker + seq) % cfg.n_nodes]
        parts = [nodes[(worker + seq + i) % cfg.n_nodes]
                 for i in range(npart)]
        straggle = bool(storm and seq % cfg.straggler_every ==
                        cfg.straggler_every - 1)

        def park(i: int, _p: str, txn=txn, parts=parts, straggle=straggle):
            if straggle and i == len(parts) - 1:
                # Park before the last vote: terminators race ABORT into
                # this txn's slots while we sleep — and a watcher sees the
                # pushed decision (no polling).
                pushed: List[Vote] = []
                store.watch_decision(txn, pushed.append)
                board.park(txn, parts)
                time.sleep(cfg.straggler_delay_ms / 1e3)

        committed = commit_txn(store, proto, txn, coord, parts,
                               writer_for=writer_for, before_vote=park)
        with res_lock:
            if committed:
                res.commits += 1
            else:
                res.terminated += 1

    def worker_loop(worker: int) -> None:
        for seq in range(cfg.txns_per_worker):
            commit_one(worker, seq)

    def terminator_loop(tid: int) -> None:
        cursor = 0
        while not board.closed:
            fresh = board.items_from(cursor)
            if not fresh:
                time.sleep(5e-4)           # poll well inside the stall window
                continue
            cursor += len(fresh)
            for txn, slots in fresh:
                for p in slots:
                    try:
                        barrier.wait(timeout=1.0)
                    except threading.BrokenBarrierError:
                        pass
                    try:
                        store.log_once(p, txn, Vote.ABORT,
                                       writer=f"term{tid}")
                    except Exception:
                        pass               # storm racers never fail the run

    workers = [threading.Thread(target=worker_loop, args=(w,), daemon=True)
               for w in range(cfg.workers)]
    terms = ([threading.Thread(target=terminator_loop, args=(t,),
                               daemon=True)
              for t in range(cfg.terminators)] if storm else [])
    t0 = time.monotonic()
    for t in workers + terms:
        t.start()
    for t in workers:
        t.join()
    res.elapsed_s = time.monotonic() - t0
    if board is not None:
        board.close()
    if barrier is not None:
        barrier.abort()
    for t in terms:
        t.join(timeout=2.0)

    res.decision_cache_hits = store.decision_cache_hits
    res.singleflight_hits = store.singleflight_hits
    res.decisions_pushed = store.decisions_pushed
    res.fast_path_ops = getattr(store, "fast_path_ops", 0)
    res.fallback_ops = getattr(store, "fallback_ops", 0)
    res.lease_acquisitions = (keeper.acquisitions if keeper is not None
                              else getattr(store, "lease_acquisitions", 0))
    res.lease_degradations = (keeper.degradations if keeper is not None
                              else getattr(store, "lease_degradations", 0))
    return res


def wallclock_rows() -> Dict[str, Tuple[str, str]]:
    """Table-3 row → (protocol, threaded backend) for the wall-clock bench."""
    return {row: (protocol, WALLCLOCK_BACKENDS[mode])
            for row, (protocol, mode) in SIMULATED_RTT_ROWS.items()}
