"""Shard (de)serialization: pytree leaves ↔ bytes, and host partitioning.

Format: npz of path-keyed arrays (fast, dependency-free, self-describing).
``partition_leaves`` deterministically assigns leaf paths to hosts by a
size-balanced greedy rule, so a restore can reassemble the full tree from
any historical host count — this is what makes restarts *elastic*.

Also home to the k-of-n erasure codec (``ec_encode`` / ``ec_decode``): a
Reed-Solomon-lite code over GF(256) with a Vandermonde generator matrix,
numpy-only.  A checkpoint payload split into ``k`` data stripes becomes
``n`` fragments — one per replica volume — any ``k`` of which reconstruct
the payload.  With (k=2, n=5) a restore needs just TWO surviving volumes
(a *minority*) at 2.5× storage instead of the 5× of full replication.
Fragments carry a self-describing header (k, n, index, payload length),
so a restore can decode from whatever subset survived without any
out-of-band metadata.
"""
from __future__ import annotations

import io
import struct
from typing import Dict, List, Sequence, Tuple

import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    import jax  # lazy: the EC codec below is numpy-only
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def pack_tree(tree, keys: Sequence[str] | None = None) -> bytes:
    """Serialize (a subset of) a pytree's leaves."""
    flat = _flatten(tree)
    if keys is not None:
        flat = {k: flat[k] for k in keys}
    buf = io.BytesIO()
    np.savez(buf, **flat)
    return buf.getvalue()


def unpack_tree(payload: bytes) -> Dict[str, np.ndarray]:
    buf = io.BytesIO(payload)
    with np.load(buf) as z:
        return {k: z[k] for k in z.files}


def merge_into_tree(tree, flat: Dict[str, np.ndarray]):
    """Write flat path->array entries back into a template pytree."""
    import jax
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key in flat:
            arr = flat[key]
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), out)


def partition_leaves(tree, n_hosts: int) -> List[List[str]]:
    """Deterministic size-balanced assignment of leaf paths to hosts."""
    flat = _flatten(tree)
    items = sorted(flat.items(), key=lambda kv: (-kv[1].nbytes, kv[0]))
    buckets: List[List[str]] = [[] for _ in range(n_hosts)]
    loads = [0] * n_hosts
    for key, arr in items:
        i = loads.index(min(loads))
        buckets[i].append(key)
        loads[i] += max(1, arr.nbytes)
    return buckets


# ---------------------------------------------------------------------------
# k-of-n erasure codec (Reed-Solomon-lite over GF(256), numpy-only)
# ---------------------------------------------------------------------------
# GF(2^8) with the AES reduction polynomial 0x11d; exp table doubled so a
# log-sum (max 508) indexes without a mod.
_GF_EXP = np.zeros(512, dtype=np.uint8)
_GF_LOG = np.zeros(256, dtype=np.int32)
_x = 1
for _i in range(255):
    _GF_EXP[_i] = _x
    _GF_LOG[_x] = _i
    _x <<= 1
    if _x & 0x100:
        _x ^= 0x11D
_GF_EXP[255:510] = _GF_EXP[:255]

# Full 256x256 product table: _GF_MUL[c] maps a byte vector through "*c"
# with one fancy-index — the whole codec is table lookups and XORs.
_GF_MUL = np.zeros((256, 256), dtype=np.uint8)
_nz = np.arange(1, 256)
for _c in range(1, 256):
    _GF_MUL[_c, 1:] = _GF_EXP[_GF_LOG[_c] + _GF_LOG[_nz]]


def _gf_mul(a: int, b: int) -> int:
    if a == 0 or b == 0:
        return 0
    return int(_GF_EXP[_GF_LOG[a] + _GF_LOG[b]])


def _gf_inv(a: int) -> int:
    if a == 0:
        raise ZeroDivisionError("GF(256) inverse of 0")
    return int(_GF_EXP[255 - _GF_LOG[a]])


# Fragment header: magic, k, n, fragment index, original payload length.
_EC_HEADER = struct.Struct(">4sBBBQ")
_EC_MAGIC = b"ECS1"


def ec_encode(payload: bytes, k: int, n: int) -> List[bytes]:
    """Encode ``payload`` into ``n`` fragments, any ``k`` of which decode.

    Fragment j is the GF(256) inner product of the k data stripes with the
    Vandermonde row (x_j^0 .. x_j^{k-1}), x_j = j+1: distinct nonzero
    evaluation points, so every k×k row subset is invertible.
    """
    if not 1 <= k <= n <= 255:
        raise ValueError(f"need 1 <= k <= n <= 255, got k={k} n={n}")
    data = np.frombuffer(payload, dtype=np.uint8)
    stripe = max(1, -(-len(data) // k))
    padded = np.zeros(k * stripe, dtype=np.uint8)
    padded[:len(data)] = data
    stripes = padded.reshape(k, stripe)
    frags: List[bytes] = []
    for j in range(n):
        x = j + 1
        acc = np.zeros(stripe, dtype=np.uint8)
        coeff = 1
        for i in range(k):
            acc ^= _GF_MUL[coeff][stripes[i]]
            coeff = _gf_mul(coeff, x)
        frags.append(_EC_HEADER.pack(_EC_MAGIC, k, n, j, len(payload))
                     + acc.tobytes())
    return frags


def ec_decode(fragments: Sequence[bytes]) -> bytes:
    """Reconstruct the payload from any >= k surviving fragments.

    Headers are self-describing; duplicates and fragments from a different
    (k, n) geometry are rejected.  Raises ``ValueError`` when fewer than k
    distinct fragments survive — the caller's signal that the epoch's data
    really is gone.
    """
    seen: Dict[int, np.ndarray] = {}
    geometry = None
    for frag in fragments:
        if len(frag) < _EC_HEADER.size:
            raise ValueError("truncated erasure fragment")
        magic, k, n, j, orig_len = _EC_HEADER.unpack(
            frag[:_EC_HEADER.size])
        if magic != _EC_MAGIC:
            raise ValueError(f"bad fragment magic {magic!r}")
        if geometry is None:
            geometry = (k, n, orig_len)
        elif geometry != (k, n, orig_len):
            raise ValueError(f"mixed fragment geometries: {geometry} "
                             f"vs {(k, n, orig_len)}")
        seen.setdefault(j, np.frombuffer(frag[_EC_HEADER.size:],
                                         dtype=np.uint8))
    if geometry is None:
        raise ValueError("no fragments")
    k, n, orig_len = geometry
    if len(seen) < k:
        raise ValueError(f"need {k} distinct fragments, "
                         f"have {len(seen)} of {n}")
    rows = sorted(seen.items())[:k]
    # Solve A·D = F by Gauss-Jordan over GF(256); row ops on the fragment
    # byte vectors ride the product table.
    A = [[pow_gf(j + 1, i) for i in range(k)] for j, _ in rows]
    F = np.stack([body.copy() for _, body in rows])
    for col in range(k):
        pivot = next(r for r in range(col, k) if A[r][col] != 0)
        A[col], A[pivot] = A[pivot], A[col]
        F[[col, pivot]] = F[[pivot, col]]
        inv = _gf_inv(A[col][col])
        A[col] = [_gf_mul(inv, v) for v in A[col]]
        F[col] = _GF_MUL[inv][F[col]]
        for r in range(k):
            f = A[r][col]
            if r == col or f == 0:
                continue
            A[r] = [a ^ _gf_mul(f, b) for a, b in zip(A[r], A[col])]
            F[r] ^= _GF_MUL[f][F[col]]
    return F.reshape(-1).tobytes()[:orig_len]


def pow_gf(x: int, e: int) -> int:
    """x**e in GF(256) (e >= 0)."""
    out = 1
    for _ in range(e):
        out = _gf_mul(out, x)
    return out
