"""Shard (de)serialization: pytree leaves ↔ bytes, and host partitioning.

Format: npz of path-keyed arrays (fast, dependency-free, self-describing).
``partition_leaves`` deterministically assigns leaf paths to hosts by a
size-balanced greedy rule, so a restore can reassemble the full tree from
any historical host count — this is what makes restarts *elastic*.
"""
from __future__ import annotations

import io
from typing import Dict, List, Sequence, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def pack_tree(tree, keys: Sequence[str] | None = None) -> bytes:
    """Serialize (a subset of) a pytree's leaves."""
    flat = _flatten(tree)
    if keys is not None:
        flat = {k: flat[k] for k in keys}
    buf = io.BytesIO()
    np.savez(buf, **flat)
    return buf.getvalue()


def unpack_tree(payload: bytes) -> Dict[str, np.ndarray]:
    buf = io.BytesIO(payload)
    with np.load(buf) as z:
        return {k: z[k] for k in z.files}


def merge_into_tree(tree, flat: Dict[str, np.ndarray]):
    """Write flat path->array entries back into a template pytree."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key in flat:
            arr = flat[key]
            out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree), out)


def partition_leaves(tree, n_hosts: int) -> List[List[str]]:
    """Deterministic size-balanced assignment of leaf paths to hosts."""
    flat = _flatten(tree)
    items = sorted(flat.items(), key=lambda kv: (-kv[1].nbytes, kv[0]))
    buckets: List[List[str]] = [[] for _ in range(n_hosts)]
    loads = [0] * n_hosts
    for key, arr in items:
        i = loads.index(min(loads))
        buckets[i].append(key)
        loads[i] += max(1, arr.nbytes)
    return buckets
