"""Cornus-committed distributed checkpointing (the paper → framework bridge).

A checkpoint epoch is a distributed transaction: every host uploads its shard
set to disaggregated storage, then CAS-writes VOTE-YES into its transaction-
state slot via LogOnce().  The epoch is committed iff ALL hosts' votes are
durable — no coordinator decision record exists (paper §3.1), so a dead
coordinator can never wedge the fleet, and any host (or a restarting job) can
resolve an in-flight epoch in bounded time with the termination protocol.
"""
from .shards import (ec_decode, ec_encode, pack_tree, partition_leaves,
                     unpack_tree)
from .commit import CheckpointOutcome, CornusCheckpointer
from .restore import fetch_payloads, latest_committed, restore_params

__all__ = ["pack_tree", "unpack_tree", "partition_leaves",
           "ec_encode", "ec_decode",
           "CornusCheckpointer", "CheckpointOutcome", "latest_committed",
           "restore_params", "fetch_payloads"]
