"""Non-blocking restore: find the newest COMMITTED epoch and load it.

A restarting fleet must never block on an epoch left in-flight by a crash
(the 2PC failure mode in paper Fig 2b).  ``latest_committed`` walks epochs
newest-first; UNDETERMINED epochs are *resolved* — not waited on — with the
termination protocol, which either confirms the collective COMMIT or forces
ABORT in bounded time (Theorem 4).  Elasticity: shards are reassembled from
whatever host partitioning wrote them, so the restored fleet size may differ
from the writing fleet.
"""
from __future__ import annotations

import os
import re
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.state import Decision
from .commit import CornusCheckpointer, _txn
from .shards import merge_into_tree, unpack_tree


def list_epochs(store, hosts: Sequence[str]) -> List[int]:
    """All epoch ids any host has a state record for (FileStore layout)."""
    seen = set()
    root = getattr(store, "root", None)
    if root is not None:
        for h in hosts:
            d = os.path.join(root, "state", h)
            if os.path.isdir(d):
                for name in os.listdir(d):
                    m = re.fullmatch(r"e(\d+)", name)
                    if m:
                        seen.add(int(m.group(1)))
    else:  # MemoryStore
        for (partition, txn), _ in store.snapshot().items():
            m = re.fullmatch(r"e(\d+)", txn)
            if m:
                seen.add(int(m.group(1)))
    return sorted(seen, reverse=True)


def latest_committed(store, hosts: Sequence[str],
                     resolver_host: str = "restore") -> Optional[int]:
    ck = CornusCheckpointer(store, resolver_host, hosts)
    for epoch in list_epochs(store, hosts):
        d = ck.global_decision(epoch)
        if d == Decision.UNDETERMINED:
            # In-flight epoch from a crashed run: resolve, don't wait.
            d, _ = ck.terminate(epoch)
        if d == Decision.COMMIT:
            return epoch
    return None


def restore_params(store, hosts: Sequence[str], epoch: int, template):
    """Reassemble the full tree from every host's shard payload."""
    flat: Dict[str, np.ndarray] = {}
    for h in hosts:
        try:
            payload = store.get_data(h, _txn(epoch))
        except FileNotFoundError:
            continue
        flat.update(unpack_tree(payload))
    return merge_into_tree(template, flat)
