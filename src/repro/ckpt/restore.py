"""Non-blocking restore: find the newest COMMITTED epoch and load it.

A restarting fleet must never block on an epoch left in-flight by a crash
(the 2PC failure mode in paper Fig 2b).  ``latest_committed`` walks epochs
newest-first; UNDETERMINED epochs are *resolved* — not waited on — with the
termination protocol, which either confirms the collective COMMIT or forces
ABORT in bounded time (Theorem 4).  Elasticity: shards are reassembled from
whatever host partitioning wrote them, so the restored fleet size may differ
from the writing fleet.

Erasure-coded epochs (``CornusCheckpointer(ec_k=...)``) restore from any
``k`` surviving replica volumes: ``fetch_payloads`` tries the plain payload
path first, then gathers fragments from whatever volumes still hold them
and decodes — volumes may keep dying *between* per-host reads (the
``after_host`` hook is how tests kill them mid-restore) and the restore
still succeeds as long as each host's fragment count stays >= k.
"""
from __future__ import annotations

import os
import re
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core.state import Decision
from .commit import CornusCheckpointer, _ec_name, _txn
from .shards import ec_decode, merge_into_tree, unpack_tree


def list_epochs(store, hosts: Sequence[str]) -> List[int]:
    """All epoch ids any host has a state record for (FileStore layout)."""
    seen = set()
    root = getattr(store, "root", None)
    if root is not None:
        for h in hosts:
            d = os.path.join(root, "state", h)
            if os.path.isdir(d):
                for name in os.listdir(d):
                    m = re.fullmatch(r"e(\d+)", name)
                    if m:
                        seen.add(int(m.group(1)))
    else:  # MemoryStore
        for (partition, txn), _ in store.snapshot().items():
            m = re.fullmatch(r"e(\d+)", txn)
            if m:
                seen.add(int(m.group(1)))
    return sorted(seen, reverse=True)


def latest_committed(store, hosts: Sequence[str],
                     resolver_host: str = "restore") -> Optional[int]:
    ck = CornusCheckpointer(store, resolver_host, hosts)
    for epoch in list_epochs(store, hosts):
        d = ck.global_decision(epoch)
        if d == Decision.UNDETERMINED:
            # In-flight epoch from a crashed run: resolve, don't wait.
            d, _ = ck.terminate(epoch)
        if d == Decision.COMMIT:
            return epoch
    return None


def _host_payload(store, host: str, epoch: int) -> bytes:
    """One host's shard payload: plain path first, then erasure fragments
    gathered from whichever replica volumes still hold them."""
    try:
        return store.get_data(host, _txn(epoch))
    except FileNotFoundError:
        if not hasattr(store, "alive_replicas"):
            raise
    frags = []
    for r in store.alive_replicas():
        got = r.get_data(host, _ec_name(epoch))
        if got is not None:
            frags.append(got[1])
    if not frags:
        raise FileNotFoundError(f"no volume holds a fragment of "
                                f"{host}/{_txn(epoch)}")
    try:
        return ec_decode(frags)
    except ValueError as e:
        # Fewer than k fragments survived: for the caller this is the
        # same condition as a missing plain payload.
        raise FileNotFoundError(
            f"unrecoverable erasure-coded payload "
            f"{host}/{_txn(epoch)}: {e}") from e


def fetch_payloads(store, hosts: Sequence[str], epoch: int,
                   after_host: Optional[Callable[[str], None]] = None
                   ) -> Dict[str, bytes]:
    """Every recoverable host payload for ``epoch``.  ``after_host`` runs
    between per-host reads — the failure-injection point for tests that
    kill volumes *mid-restore*."""
    out: Dict[str, bytes] = {}
    for h in hosts:
        try:
            out[h] = _host_payload(store, h, epoch)
        except FileNotFoundError:
            pass
        if after_host is not None:
            after_host(h)
    return out


def restore_params(store, hosts: Sequence[str], epoch: int, template):
    """Reassemble the full tree from every host's shard payload."""
    flat: Dict[str, np.ndarray] = {}
    for payload in fetch_payloads(store, hosts, epoch).values():
        flat.update(unpack_tree(payload))
    return merge_into_tree(template, flat)
