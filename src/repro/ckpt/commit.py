"""Cornus atomic commit for checkpoint epochs (live deployment of §3.3).

This is the *deployed* protocol — the same Algorithm-1 semantics the sim in
``repro.core.protocol`` models, but running over real threads and a real
CAS store (``FileStore``: O_EXCL create-if-absent, or ``MemoryStore`` in
tests).  Partition names are host ids; the transaction id is the epoch.

Walkthrough of one epoch on host h (Algorithm 1, participant side):
  1. upload shard payload            → store.put_data(h, "e<N>", bytes)
  2. resp = LogOnce(h, "e<N>", VOTE_YES)
     · resp == ABORT: a peer's termination protocol already gave up on us
       (we were a straggler) — drop the epoch, keep training.
  3. anyone — the coordinator-role host, a peer, or a restarting job —
     resolves the epoch by reading/forcing the collective votes:
       all VOTE_YES/COMMIT → COMMIT;  any ABORT → ABORT;
       missing vote → LogOnce(p, e, ABORT)  [CAS race is safe by log-once]

There is NO commit record for the epoch as a whole: commit == the collective
vote state, exactly the paper's latency optimization — save() returns as
soon as this host's vote is durable + the collective state is resolved, with
no extra decision write on the critical path.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from ..core.control import LeaseKeeper, QuorumUnavailable
from ..core.state import Decision, Vote
from ..core.storage import FileStore, MemoryStore
from .shards import ec_encode


@dataclass
class CheckpointOutcome:
    epoch: int
    decision: Decision
    vote_ms: float = 0.0          # upload + LogOnce (this host's prepare)
    resolve_ms: float = 0.0       # collective-state resolution
    forced_aborts: int = 0        # stragglers we CAS-aborted


def _txn(epoch: int) -> str:
    return f"e{epoch:012d}"


def _ec_name(epoch: int) -> str:
    # Distinct from the plain payload path: a store can hold both (e.g. a
    # migration rewrites old epochs), and a restore tries plain first.
    return f"{_txn(epoch)}.ec"


class CornusCheckpointer:
    """One per host.  ``hosts`` lists every participant host id."""

    def __init__(self, store, host: str, hosts: Sequence[str],
                 straggler_timeout_s: float = 30.0,
                 poll_interval_s: float = 0.02,
                 lease_duration_s: float = 5.0,
                 ec_k: Optional[int] = None):
        self.store = store
        self.host = host
        self.hosts = list(hosts)
        self.timeout = straggler_timeout_s
        self.poll = poll_interval_s
        # k-of-n erasure coding of shard payloads: fragment i lands on
        # replica volume i, so a committed epoch survives n-k lost volumes
        # at n/k× storage instead of full replication's n×.  Needs a store
        # with addressable replica volumes (the quorum-replicated store).
        if ec_k is not None and not hasattr(store, "replicas"):
            raise ValueError(
                "ec_k needs a replicated store: fragments are placed one "
                "per replica volume")
        self.ec_k = ec_k
        # Leadership-lease upkeep: against a lease-capable store (the
        # replicated quorum store) the long-lived committer holds the epoch
        # ballot, so its LogOnce writes ride the phase-1-free fast path.
        # On a store with no lease API — or when renewal can't reach a
        # quorum, or a live peer holds the lease — ``ensure()`` returns
        # None and every write takes the full-prepare slow path: strictly
        # a performance knob, never a correctness gate.
        self.lease = LeaseKeeper(store, holder=host,
                                 duration_s=lease_duration_s)

    def _writer(self) -> str:
        """Identity to stamp on storage writes: the lease holder when we
        hold a live lease (fast-path accepts), else this host (slow path)."""
        lease = self.lease.ensure()
        return lease.holder if lease is not None else self.host

    # -- participant side ---------------------------------------------------
    def _put_payload(self, epoch: int, payload: bytes) -> None:
        if self.ec_k is None:
            self.store.put_data(self.host, _txn(epoch), payload)
            return
        replicas = self.store.replicas
        alive = self.store.alive_replicas()
        if len(alive) < self.ec_k:
            raise QuorumUnavailable(
                f"{len(alive)}/{len(replicas)} volumes alive, erasure "
                f"coding needs >= k={self.ec_k} fragments placed")
        frags = ec_encode(payload, self.ec_k, len(replicas))
        for r in alive:
            r.put_data(self.host, _ec_name(epoch), frags[r.index])

    def vote(self, epoch: int, payload: bytes) -> Vote:
        """Upload this host's shards, then CAS the VOTE-YES."""
        self._put_payload(epoch, payload)
        return self.store.log_once(self.host, _txn(epoch), Vote.VOTE_YES,
                                   writer=self._writer())

    # -- collective resolution (termination protocol §3.3) -------------------
    def read_states(self, epoch: int) -> Dict[str, Optional[Vote]]:
        return {h: self.store.read_state(h, _txn(epoch)) for h in self.hosts}

    def global_decision(self, epoch: int) -> Decision:
        states = self.read_states(epoch)
        votes = list(states.values())
        if any(v == Vote.ABORT for v in votes):
            return Decision.ABORT
        if all(v in (Vote.VOTE_YES, Vote.COMMIT) for v in votes):
            return Decision.COMMIT
        return Decision.UNDETERMINED

    def terminate(self, epoch: int) -> (Decision, int):
        """Force a decision NOW: CAS ABORT into every missing vote slot.

        Safe under arbitrary concurrency — log-once means the first writer
        wins and everyone converges on the same collective state (Lemma 1).
        """
        forced = 0
        results: List[Vote] = []
        writer = self._writer()
        for h in self.hosts:
            r = self.store.log_once(h, _txn(epoch), Vote.ABORT,
                                    writer=writer)
            if r == Vote.ABORT and \
                    self.store.read_state(h, _txn(epoch)) == Vote.ABORT:
                forced += 1
            results.append(r)
        if any(r == Vote.ABORT for r in results):
            return Decision.ABORT, forced
        return Decision.COMMIT, forced

    def resolve(self, epoch: int, deadline_s: Optional[float] = None
                ) -> (Decision, int):
        """Wait for the collective vote; past the straggler deadline, run the
        termination protocol instead of blocking (paper Theorem 4)."""
        deadline = time.monotonic() + (deadline_s if deadline_s is not None
                                       else self.timeout)
        while True:
            d = self.global_decision(epoch)
            if d != Decision.UNDETERMINED:
                return d, 0
            if time.monotonic() >= deadline:
                return self.terminate(epoch)
            time.sleep(self.poll)

    # -- the full per-host save path -----------------------------------------
    def save(self, epoch: int, payload: bytes,
             straggler_timeout_s: Optional[float] = None
             ) -> CheckpointOutcome:
        t0 = time.monotonic()
        my_vote = self.vote(epoch, payload)
        t1 = time.monotonic()
        if my_vote == Vote.ABORT:
            # A peer already aborted this epoch on our behalf — we were the
            # straggler. Training continues; the epoch is simply not durable.
            return CheckpointOutcome(epoch, Decision.ABORT,
                                     vote_ms=(t1 - t0) * 1e3)
        decision, forced = self.resolve(epoch, straggler_timeout_s)
        t2 = time.monotonic()
        return CheckpointOutcome(epoch, decision,
                                 vote_ms=(t1 - t0) * 1e3,
                                 resolve_ms=(t2 - t1) * 1e3,
                                 forced_aborts=forced)


class AsyncCheckpointer:
    """Overlap checkpoint commits with training: save() returns immediately,
    outcomes are collected on join() or the next save."""

    def __init__(self, inner: CornusCheckpointer):
        self.inner = inner
        self._thread: Optional[threading.Thread] = None
        self.outcomes: List[CheckpointOutcome] = []
        self._lock = threading.Lock()

    def save(self, epoch: int, payload: bytes) -> None:
        self.join()

        def run():
            out = self.inner.save(epoch, payload)
            with self._lock:
                self.outcomes.append(out)

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def join(self) -> List[CheckpointOutcome]:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._lock:
            return list(self.outcomes)
