"""Mixture-of-Experts with sort-based ragged dispatch + expert parallelism.

Production formulation (DeepSeek/MaxText-style), not the quadratic one-hot
dispatch einsum — the router's top-k assignments are sorted by expert id,
scattered into per-expert capacity buffers, all-to-all'd to the expert-owning
shards along the "model"/"expert" mesh axis, processed by the local experts,
and combined back.  With no active mesh rules (CPU smoke tests) the identical
math runs on a single shard without collectives.

FLOP cost is the true sparse cost  O(tokens · k · d · f)  — this matters for
the roofline's compute term (a one-hot dispatch einsum would report
O(tokens · E · C · d) fake FLOPs).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..launch.sharding import constrain, current_rules
from .config import ModelConfig
from .layers import DOWN_W, UP_W, PSpec, dense


def moe_specs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, e = cfg.d_model, cfg.n_experts
    f = cfg.expert_d_ff or cfg.d_ff
    specs = {
        "router": PSpec((d, e), (None, "model"), scale=1.0 / math.sqrt(d)),
        "w_gate": PSpec((e, d, f), ("expert", "fsdp", None)),
        "w_up": PSpec((e, d, f), ("expert", "fsdp", None)),
        "w_down": PSpec((e, f, d), ("expert", None, "fsdp")),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        specs.update({
            "ws_gate": PSpec((d, fs), ("fsdp", "model")),
            "ws_up": PSpec((d, fs), ("fsdp", "model")),
            "ws_down": PSpec((fs, d), ("model", "fsdp")),
        })
    return specs


def _expert_ffn(w, tokens):
    """tokens: (E_local, C, D) -> (E_local, C, D)."""
    h = jnp.einsum("ecd,edf->ecf", tokens, w["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", tokens, w["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, w["w_down"])


def _route(cfg: ModelConfig, router_w, x):
    """x: (T, D) -> gates (T, k), expert ids (T, k), aux loss scalar."""
    logits = dense(x, router_w).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.experts_per_token)  # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * Σ_e mean_load_e * mean_prob_e
    e = cfg.n_experts
    load = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    load = load / jnp.maximum(load.sum(), 1.0)
    aux = e * jnp.sum(load * probs.mean(0))
    return gates, ids, aux


def _fill_capacity_buffers(x, gates, ids, n_experts: int, capacity: int):
    """Scatter (T,D) tokens into (E, C, D) buffers, dropping overflow.

    Returns buffers, plus (slot_ids, keep) to invert the scatter at combine.
    """
    t, k = ids.shape
    flat_ids = ids.reshape(-1)                                # (T*k,)
    # Rank of each assignment within its expert, computed via sort.
    order = jnp.argsort(flat_ids, stable=True)                # (T*k,)
    sorted_ids = flat_ids[order]
    # position-in-expert for the sorted sequence:
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(n_experts))
    pos_sorted = jnp.arange(t * k) - seg_start[sorted_ids]
    inv = jnp.argsort(order, stable=True)
    pos = pos_sorted[inv]                                     # (T*k,)
    keep = pos < capacity
    slot = jnp.where(keep, flat_ids * capacity + pos, n_experts * capacity)
    src = jnp.repeat(x, k, axis=0)                            # (T*k, D)
    buf = jnp.zeros((n_experts * capacity + 1, x.shape[-1]), x.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], src, 0))
    return buf[:-1].reshape(n_experts, capacity, -1), slot, keep


def _combine(expert_out, slot, keep, gates, t: int, k: int):
    """Gather (E,C,D) outputs back to (T, D) with top-k gate weighting.

    Gates stay fp32 for the weighted sum but the RESULT is cast back to the
    activation dtype: leaking fp32 here promoted the whole residual stream
    (and every SPMD all-reduce on it) to fp32 after the first MoE layer —
    observed as 2× collective wire bytes on kimi-k2 (EXPERIMENTS §Perf).
    """
    dt = expert_out.dtype
    flat = expert_out.reshape(-1, expert_out.shape[-1])
    flat = jnp.concatenate([flat, jnp.zeros_like(flat[:1])], axis=0)
    picked = flat[jnp.where(keep, slot, flat.shape[0] - 1)]   # (T*k, D)
    out = (picked.reshape(t, k, -1).astype(jnp.float32)
           * gates[..., None]).sum(axis=1)
    return out.astype(dt)


def moe_apply(cfg: ModelConfig, params, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss)."""
    b, s, d = x.shape
    rules = current_rules()
    xf = x.reshape(b * s, d)
    gates, ids, aux = _route(cfg, params["router"], xf)
    e, k = cfg.n_experts, cfg.experts_per_token

    ep = rules.axis_size("expert") if rules else 1
    if rules and ep > 1 and e % ep == 0:
        out = _moe_shardmap(cfg, params, xf, gates, ids, rules, ep)
    else:
        cap = max(k, int(cfg.capacity_factor * (b * s) * k / e))
        buf, slot, keep = _fill_capacity_buffers(xf, gates, ids, e, cap)
        expert_out = _expert_ffn(params, buf)
        out = _combine(expert_out, slot, keep, gates, b * s, k)

    if cfg.n_shared_experts:
        h = jax.nn.silu(dense(xf, params["ws_gate"], UP_W)) * \
            dense(xf, params["ws_up"], UP_W)
        out = out + dense(h, params["ws_down"], DOWN_W)
    return out.reshape(b, s, d), aux.astype(jnp.float32)


def _moe_shardmap(cfg: ModelConfig, params, xf, gates, ids, rules, ep: int):
    """Expert-parallel path: tokens sharded on batch axes, experts on the
    "model" axis; dispatch/return via all-to-all inside shard_map."""
    e, k = cfg.n_experts, cfg.experts_per_token
    mesh = rules.mesh
    batch_axes = rules.logical["batch"]
    model_axes = rules.logical["expert"]
    t_total = xf.shape[0]
    dp = rules.axis_size("batch")
    # Token sharding for dispatch: prefer batch+model axes (every chip routes
    # its own slice), fall back to batch-only, then fully replicated — the
    # expert weights stay sharded in all three regimes, so memory is safe
    # even for 1-token long-context decode.
    if t_total % (dp * ep) == 0:
        tok_axes: tuple = tuple(batch_axes) + tuple(model_axes)
    elif t_total % dp == 0:
        tok_axes = tuple(batch_axes)
    else:
        tok_axes = ()
    t_local = max(1, t_total // max(
        1, (dp * ep) if len(tok_axes) > len(batch_axes) else
        (dp if tok_axes else 1)))
    cap = max(k, int(cfg.capacity_factor * t_local * k / e))

    tok_spec = P(tok_axes if len(tok_axes) > 1 else
                 (tok_axes[0] if tok_axes else None))
    w_spec = P(model_axes[0])

    def local(xl, gl, il, wg, wu, wd):
        buf, slot, keep = _fill_capacity_buffers(xl, gl, il, e, cap)
        # (E, C, D) -> all-to-all over experts -> (E/ep, C*ep, D)
        buf = jax.lax.all_to_all(buf, model_axes[0], split_axis=0,
                                 concat_axis=1, tiled=True)
        out = _expert_ffn({"w_gate": wg, "w_up": wu, "w_down": wd}, buf)
        out = jax.lax.all_to_all(out, model_axes[0], split_axis=1,
                                 concat_axis=0, tiled=True)
        return _combine(out, slot, keep, gl, xl.shape[0], k)

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec, w_spec, w_spec, w_spec),
        out_specs=tok_spec, check_vma=False,
    )(xf, gates, ids, params["w_gate"], params["w_up"], params["w_down"])
