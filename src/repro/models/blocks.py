"""Decoder block kinds: attn / attn_local / mamba / mlstm / slstm (+ FFN/MoE).

Every kind implements:
  specs(cfg)                      -> PSpec tree for one layer
  apply(cfg, params, x, ctx)     -> (x_out, cache_out, aux)
with ``ctx`` carrying mode ("train" | "prefill" | "decode"), positions,
rope theta, window, and the layer's incoming cache.  Caches are pytrees so
the LM can stack them across scan periods.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..launch.sharding import constrain
from .config import ModelConfig
from .layers import (DOWN_W, UP_W, PSpec, apply_mrope, apply_rope,
                     attention, dense, rms_norm, swiglu)
from .moe import moe_apply, moe_specs

SSM_CHUNK = 64      # mamba: tokens per associative-scan chunk
MLSTM_CHUNK = 256   # mLSTM: chunkwise-parallel block size


@dataclass
class Ctx:
    mode: str                       # train | prefill | decode
    positions: jax.Array            # (B,S) int32 or (3,B,S) for mrope
    theta: float
    window: int = 0                 # 0 = global attention
    cache: Any = None               # layer cache (decode/prefill)
    pos_offset: Any = 0             # scalar or array: absolute pos of x[0]
    max_len: int = 0                # cache capacity


def _head_axes(n: int, hd: int, model_min: int = 16):
    """Q / attention-output sharding: heads on the TP axis.

    GSPMD pads head counts that don't divide the axis (36 heads -> 48 lanes,
    8 heads -> 16 half-empty lanes); the padding waste only touches the
    attention einsums, never the big MLP matmuls.  KV activations are kept
    REPLICATED (they are G× smaller than Q under GQA) — sharding them on a
    different dim than Q provokes involuntary full rematerialization in the
    SPMD partitioner (observed: +60 GB/device of all-reduce on llama3.2).
    """
    return ("batch", None, "model", None)


KV_REPLICATED = ("batch", None, None, None)


# ===========================================================================
# Attention (+ local window variant)
# ===========================================================================
def attn_specs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, hd = cfg.d_model, cfg.hd
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    s = {
        "ln": PSpec((d,), (None,), init="zeros"),
        "wq": PSpec((d, nq * hd), ("fsdp", "model")),
        "wk": PSpec((d, nkv * hd), ("fsdp", "model")),
        "wv": PSpec((d, nkv * hd), ("fsdp", "model")),
        "wo": PSpec((nq * hd, d), ("model", "fsdp")),
    }
    if cfg.qk_norm:
        s["q_norm"] = PSpec((hd,), (None,), init="zeros")
        s["k_norm"] = PSpec((hd,), (None,), init="zeros")
    if cfg.post_norm:
        s["post_ln"] = PSpec((d,), (None,), init="zeros")
    return s


def attn_cache_shape(cfg: ModelConfig, batch: int, max_len: int):
    nkv, hd = cfg.n_kv_heads, cfg.hd
    # Sequence-sharded KV cache (flash-decode): batch holds "data", so the
    # cache seq dim takes "model"; at batch=1 it takes both axes.
    kv_axes = ("batch", "cache_seq_full" if batch == 1 else "cache_seq",
               None, None)
    return {
        "k": PSpec((batch, max_len, nkv, hd), kv_axes, init="zeros"),
        "v": PSpec((batch, max_len, nkv, hd), kv_axes, init="zeros"),
    }


def attn_apply(cfg: ModelConfig, p, x, ctx: Ctx):
    B, S, D = x.shape
    nq, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = dense(h, p["wq"], UP_W).reshape(B, S, nq, hd)
    k = dense(h, p["wk"], UP_W).reshape(B, S, nkv, hd)
    v = dense(h, p["wv"], UP_W).reshape(B, S, nkv, hd)
    if ctx.mode == "decode":
        # Flash-decode sharding: the 1-token q is tiny — REPLICATE it and
        # keep the cache sequence-sharded; sharding q on heads while the
        # cache shards on seq made XLA all-gather the whole cache
        # (observed: 53 GB/device/step on gemma2 decode_32k).
        q = constrain(q, ("batch", None, None, None))
    else:
        q = constrain(q, _head_axes(nq, hd))
    k = constrain(k, KV_REPLICATED)
    v = constrain(v, KV_REPLICATED)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope:
        q = apply_mrope(q, ctx.positions, ctx.theta, cfg.mrope_sections)
        k = apply_mrope(k, ctx.positions, ctx.theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, ctx.positions, ctx.theta)
        k = apply_rope(k, ctx.positions, ctx.theta)

    new_cache = None
    if ctx.mode == "decode":
        cache = ctx.cache
        pos = ctx.pos_offset
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
        new_cache = {"k": ck, "v": cv}
        o = attention(q, ck, cv, causal=False, window=ctx.window,
                      cap=cfg.attn_softcap, q_offset=pos, kv_len=pos + S)
    else:
        o = attention(q, k, v, causal=True, window=ctx.window,
                      cap=cfg.attn_softcap)
        if ctx.mode == "prefill":
            pad = ctx.max_len - S
            new_cache = {
                "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            }
    o = constrain(o, _head_axes(nq, hd))
    out = dense(o.reshape(B, S, nq * hd), p["wo"], DOWN_W)
    if cfg.post_norm:
        out = rms_norm(out, p["post_ln"], cfg.norm_eps)
    return out, new_cache


# ===========================================================================
# Mamba (selective SSM) — jamba's mixer
# ===========================================================================
def mamba_specs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    dt_rank = max(1, d // 16)
    return {
        "ln": PSpec((d,), (None,), init="zeros"),
        "w_in": PSpec((d, 2 * di), ("fsdp", "model")),
        "conv": PSpec((cfg.ssm_conv, di), (None, "model"), scale=0.1),
        "w_bcdt": PSpec((di, 2 * n + dt_rank), ("model", None)),
        "w_dt": PSpec((dt_rank, di), (None, "model"), scale=0.5),
        "dt_bias": PSpec((di,), ("model",), init="zeros"),
        "a_log": PSpec((di, n), ("model", None), init="zeros"),
        "d_skip": PSpec((di,), ("model",), init="ones"),
        "w_out": PSpec((di, d), ("model", "fsdp")),
    }


def mamba_cache_shape(cfg: ModelConfig, batch: int, _max_len: int):
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": PSpec((batch, cfg.ssm_conv - 1, di), ("batch", None, "model"),
                      init="zeros"),
        "ssm": PSpec((batch, di, cfg.ssm_state), ("batch", "model", None),
                     init="zeros", dtype="float32"),
    }


def _ssm_scan(u, dt, a, b, c, h0):
    """Chunked selective scan.  u,dt:(B,S,di)  b,c:(B,S,N)  a:(di,N).

    Outer lax.scan over chunks carries the (B,di,N) state; inside a chunk the
    linear recurrence h_t = Ā_t h_{t-1} + B̄_t u_t runs as an associative
    scan, so only (chunk,B,di,N) is ever materialized.
    """
    B, S, di = u.shape
    n = a.shape[-1]
    c_len = min(SSM_CHUNK, S)
    n_chunks = -(-S // c_len)
    pad = n_chunks * c_len - S
    u_, dt_, b_, c_ = (jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
                       for t in (u, dt, b, c))

    abar = jnp.exp(dt_[..., None] * a)                        # (B,S',di,N)
    bbar = dt_[..., None] * b_[:, :, None, :] * u_[..., None]  # (B,S',di,N)
    abar = abar.reshape(B, n_chunks, c_len, di, n).transpose(1, 0, 2, 3, 4)
    bbar = bbar.reshape(B, n_chunks, c_len, di, n).transpose(1, 0, 2, 3, 4)
    cc = c_.reshape(B, n_chunks, c_len, n).transpose(1, 0, 2, 3)

    def chunk_step(h, inp):
        ab, bb, cb = inp                                       # (B,c,di,N)…

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_sc, b_sc = jax.lax.associative_scan(
            combine, (ab, bb), axis=1)
        hs = b_sc + a_sc * h[:, None]                          # (B,c,di,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, cb)
        return hs[:, -1], y

    h_last, ys = jax.lax.scan(chunk_step, h0, (abar, bbar, cc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * c_len, di)[:, :S]
    return y, h_last


def mamba_apply(cfg: ModelConfig, p, x, ctx: Ctx):
    B, S, D = x.shape
    di = cfg.ssm_expand * D
    n = cfg.ssm_state
    dt_rank = max(1, D // 16)
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    xz = dense(h, p["w_in"], UP_W)
    xs, z = jnp.split(xz, 2, axis=-1)                          # (B,S,di)
    xs = constrain(xs, ("batch", None, "model"))

    # Causal conv1d over time (kernel ssm_conv).
    if ctx.mode == "decode":
        prev = ctx.cache["conv"]                               # (B,K-1,di)
        xin = jnp.concatenate([prev, xs], axis=1)
        new_conv = xin[:, -(cfg.ssm_conv - 1):]
    else:
        xin = jnp.pad(xs, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
        new_conv = xin[:, xin.shape[1] - (cfg.ssm_conv - 1):]
    xc = sum(xin[:, i:i + (xs.shape[1])] * p["conv"][i]
             for i in range(cfg.ssm_conv))
    xc = jax.nn.silu(xc)

    bcdt = dense(xc, p["w_bcdt"])
    b_in, c_in, dt_in = jnp.split(bcdt, [n, 2 * n], axis=-1)
    dt = jax.nn.softplus(dense(dt_in, p["w_dt"]) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    h0 = ctx.cache["ssm"].astype(jnp.float32) if ctx.mode == "decode" else \
        jnp.zeros((B, di, n), jnp.float32)
    y, h_last = _ssm_scan(xc.astype(jnp.float32), dt.astype(jnp.float32),
                          a, b_in.astype(jnp.float32),
                          c_in.astype(jnp.float32), h0)
    y = (y.astype(x.dtype) + xc * p["d_skip"]) * jax.nn.silu(z)
    out = dense(y, p["w_out"], DOWN_W)
    new_cache = None
    if ctx.mode in ("decode", "prefill"):
        new_cache = {"conv": new_conv, "ssm": h_last.astype(jnp.float32)}
    return out, new_cache


# ===========================================================================
# xLSTM: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (recurrent)
# ===========================================================================
def mlstm_specs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)
    return {
        "ln": PSpec((d,), (None,), init="zeros"),
        "w_up": PSpec((d, 2 * di), ("fsdp", "model")),
        "wq": PSpec((di, di), ("model", None)),
        "wk": PSpec((di, di), ("model", None)),
        "wv": PSpec((di, di), ("model", None)),
        "w_if": PSpec((di, 2 * cfg.n_heads), ("model", None), scale=0.1),
        "out_norm": PSpec((di,), ("model",), init="zeros"),
        "w_down": PSpec((di, d), ("model", "fsdp")),
    }


def mlstm_cache_shape(cfg: ModelConfig, batch: int, _max_len: int):
    d = cfg.d_model
    di = int(cfg.mlstm_proj_factor * d)
    hd = di // cfg.n_heads
    return {
        "C": PSpec((batch, cfg.n_heads, hd, hd), ("batch", None, None, None),
                   init="zeros", dtype="float32"),
        "n": PSpec((batch, cfg.n_heads, hd), ("batch", None, None),
                   init="zeros", dtype="float32"),
    }


def _mlstm_cell(q, k, v, i_gate, f_gate, c0, n0):
    """Chunkwise-parallel gated linear attention.

    q,k,v: (B,S,H,hd)   i,f: (B,S,H) in (0,1)   c0: (B,H,hd,hd)
    Decays stay in log space so chunk ratios never overflow.
    """
    B, S, H, hd = q.shape
    c_len = min(MLSTM_CHUNK, S)
    n_chunks = -(-S // c_len)
    pad = n_chunks * c_len - S
    q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
               for t in (q, k, v))
    i_gate, f_gate = (jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
                      for t in (i_gate, f_gate))

    def resh(t):
        s = t.shape
        return t.reshape((B, n_chunks, c_len) + s[2:]).swapaxes(0, 1)

    qs, ks, vs, is_, fs = map(resh, (q, k, v, i_gate, f_gate))
    scale = 1.0 / math.sqrt(hd)

    def chunk(carry, inp):
        c_state, n_state = carry                 # (B,H,hd,hd), (B,H,hd)
        qb, kb, vb, ib, fb = inp
        logf = jnp.log(fb + 1e-8)                # (B,c,H) ≤ 0
        cum = jnp.cumsum(logf, axis=1)           # within-chunk decay
        # inter-chunk: y_inter_t = decay_t · q_t C_prev
        decay_t = jnp.exp(cum)[..., None]
        y_inter = jnp.einsum("bshd,bhde->bshe", qb * scale, c_state) * decay_t
        # intra-chunk: masked scores with decay ratio exp(cum_t - cum_s)·i_s
        ratio = cum[:, :, None, :] - cum[:, None, :, :]        # (B,t,s,H)
        mask = jnp.tril(jnp.ones((c_len, c_len), bool))
        w = jnp.where(mask[None, :, :, None], jnp.exp(ratio), 0.0)
        sc = jnp.einsum("bshd,bthd->bsth", qb * scale, kb)
        p_ = sc * w * ib[:, None, :, :]
        y_intra = jnp.einsum("bsth,bthd->bshd", p_, vb)
        # state update: C = A·C + Σ_s exp(cum_c - cum_s)·i_s k_s v_sᵀ
        rem = jnp.exp(cum[:, -1:, :] - cum) * ib               # (B,c,H)
        c_new = c_state * jnp.exp(cum[:, -1])[..., None, None] + \
            jnp.einsum("bshd,bshe,bsh->bhde", kb, vb, rem)
        n_new = n_state * jnp.exp(cum[:, -1])[..., None] + \
            jnp.einsum("bshd,bsh->bhd", kb, rem)
        return (c_new, n_new), y_inter + y_intra

    (c_last, n_last), ys = jax.lax.scan(
        chunk, (c0, n0), (qs, ks, vs, is_, fs))
    y = ys.swapaxes(0, 1).reshape(B, n_chunks * c_len, H, hd)[:, :S]
    return y, c_last, n_last


def mlstm_apply(cfg: ModelConfig, p, x, ctx: Ctx):
    B, S, D = x.shape
    H = cfg.n_heads
    di = int(cfg.mlstm_proj_factor * D)
    hd = di // H
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    up, z = jnp.split(dense(h, p["w_up"], UP_W), 2, axis=-1)
    q = dense(up, p["wq"]).reshape(B, S, H, hd)
    k = dense(up, p["wk"]).reshape(B, S, H, hd) / math.sqrt(hd)
    v = dense(up, p["wv"]).reshape(B, S, H, hd)
    gates = dense(up, p["w_if"]).reshape(B, S, H, 2)
    i_gate = jax.nn.sigmoid(gates[..., 0])
    f_gate = jax.nn.sigmoid(gates[..., 1] + 3.0)  # bias toward remembering
    if ctx.mode == "decode":
        c0 = ctx.cache["C"]
        n0 = ctx.cache["n"]
    else:
        c0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
    y, c_last, n_last = _mlstm_cell(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        i_gate.astype(jnp.float32), f_gate.astype(jnp.float32), c0, n0)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = dense(y, p["w_down"], DOWN_W)
    cache = {"C": c_last, "n": n_last} if ctx.mode in ("decode", "prefill") \
        else None
    return out, cache


def slstm_specs(cfg: ModelConfig) -> Dict[str, PSpec]:
    d = cfg.d_model
    fh = int(cfg.slstm_proj_factor * d)
    hd = d // cfg.n_heads
    return {
        "ln": PSpec((d,), (None,), init="zeros"),
        "w_gates": PSpec((d, 4 * d), ("fsdp", "model")),
        "r_gates": PSpec((cfg.n_heads, hd, 4 * hd), (None, None, None),
                         scale=0.3),
        "ln_ff": PSpec((d,), (None,), init="zeros"),
        "w_ff1": PSpec((d, fh), ("fsdp", "model")),
        "w_ff2": PSpec((fh, d), ("model", "fsdp")),
    }


def slstm_cache_shape(cfg: ModelConfig, batch: int, _max_len: int):
    d = cfg.d_model
    ax = ("batch", "model")
    return {k: PSpec((batch, d), ax, init="zeros", dtype="float32")
            for k in ("c", "n", "h", "m")}


def slstm_apply(cfg: ModelConfig, p, x, ctx: Ctx):
    B, S, D = x.shape
    H = cfg.n_heads
    hd = D // H
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    gx = dense(xin, p["w_gates"], UP_W).astype(jnp.float32)          # (B,S,4D)

    if ctx.mode == "decode" and ctx.cache is not None:
        state0 = tuple(ctx.cache[k].astype(jnp.float32)
                       for k in ("c", "n", "h", "m"))
    else:
        state0 = tuple(jnp.zeros((B, D), jnp.float32) for _ in range(4))

    r = p["r_gates"].astype(jnp.float32)

    def step(state, gx_t):
        c, n, hprev, m = state
        hh = hprev.reshape(B, H, hd)
        rec = jnp.einsum("bhd,hde->bhe", hh, r).reshape(B, 4 * D)
        it, ft, zt, ot = jnp.split(gx_t + rec, 4, axis=-1)
        m_new = jnp.maximum(ft + m, it)          # exp-gate stabilizer
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + m - m_new)
        c_new = f_ * c + i_ * jnp.tanh(zt)
        n_new = f_ * n + i_
        h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    state, hs = jax.lax.scan(step, state0, gx.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)                      # (B,S,D)
    out = x + y
    # feed-forward sub-block
    f = rms_norm(out, p["ln_ff"], cfg.norm_eps)
    f = dense(jax.nn.gelu(dense(f, p["w_ff1"], UP_W)), p["w_ff2"], DOWN_W)
    cache = None
    if ctx.mode in ("decode", "prefill"):
        cache = dict(zip(("c", "n", "h", "m"), state))
    return out + f - x, cache  # block returns delta (residual added by LM)


# ===========================================================================
# FFN / MoE wrapper
# ===========================================================================
def ffn_specs(cfg: ModelConfig, is_moe: bool) -> Dict[str, PSpec]:
    d = cfg.d_model
    s = {"ln": PSpec((d,), (None,), init="zeros")}
    if is_moe:
        s["moe"] = moe_specs(cfg)
    else:
        s.update({
            "w_gate": PSpec((d, cfg.d_ff), ("fsdp", "model")),
            "w_up": PSpec((d, cfg.d_ff), ("fsdp", "model")),
            "w_down": PSpec((cfg.d_ff, d), ("model", "fsdp")),
        })
    if cfg.post_norm:
        s["post_ln"] = PSpec((d,), (None,), init="zeros")
    return s


def ffn_apply(cfg: ModelConfig, p, x, is_moe: bool):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    if is_moe:
        out, aux = moe_apply(cfg, p["moe"], h)
    else:
        out, aux = swiglu(h, p["w_gate"], p["w_up"], p["w_down"]), 0.0
    if cfg.post_norm:
        out = rms_norm(out, p["post_ln"], cfg.norm_eps)
    return out, aux


# ===========================================================================
# Kind registry
# ===========================================================================
MIXERS = {
    "attn": (attn_specs, attn_apply, attn_cache_shape),
    "attn_local": (attn_specs, attn_apply, attn_cache_shape),
    "mamba": (mamba_specs, mamba_apply, mamba_cache_shape),
    "mlstm": (mlstm_specs, mlstm_apply, mlstm_cache_shape),
    "slstm": (slstm_specs, slstm_apply, slstm_cache_shape),
}


def layer_specs(cfg: ModelConfig, layer_idx: int) -> Dict[str, Any]:
    kind = cfg.full_pattern[layer_idx]
    specs = {"mixer": MIXERS[kind][0](cfg)}
    if kind in ("attn", "attn_local", "mamba") and \
            (cfg.d_ff > 0 or cfg.is_moe_layer(layer_idx)):
        specs["ffn"] = ffn_specs(cfg, cfg.is_moe_layer(layer_idx))
    return specs


def layer_apply(cfg: ModelConfig, kind: str, is_moe: bool, params, x,
                ctx: Ctx):
    """One full layer: mixer + optional FFN, with residuals."""
    mix_out, new_cache = MIXERS[kind][1](cfg, params["mixer"], x, ctx)
    x = x + mix_out * cfg.residual_scale
    aux = 0.0
    if "ffn" in params:
        ffn_out, aux = ffn_apply(cfg, params["ffn"], x, is_moe)
        x = x + ffn_out * cfg.residual_scale
    x = constrain(x, ("batch", "seq", None))  # "seq" maps to the TP axis
    return x, new_cache, aux                   # only under the sp profile
